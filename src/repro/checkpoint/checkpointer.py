"""Checkpointing: async, sharded, rotated — the restart half of fault
tolerance.

Layout per step:  <dir>/step_<N>/
    manifest.json            tree structure + per-leaf metadata
    <leafkey>.npy            one file per leaf (host-gathered)
    COMMIT                   written last — a checkpoint without COMMIT is
                             torn and ignored by restore (crash-safe)

Restore is mesh-agnostic: leaves are loaded on host and re-placed with the
*current* shardings, so a 512-chip checkpoint restores onto a shrunk or
grown mesh (elastic rescale path).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


def _key_of(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        """Snapshot on host, then write asynchronously (training continues
        while the write is in flight — compute/IO overlap)."""
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host_leaves = [(_key_of(p), np.asarray(v)) for p, v in flat]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {}
            for key, arr in host_leaves:
                fn = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                manifest[key] = {"file": fn, "shape": list(arr.shape),
                                 "dtype": str(arr.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest}, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save and not block:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, abstract_state: Any,
                shardings: Optional[Any] = None) -> Any:
        """Load ``step`` into the structure of ``abstract_state``; leaves are
        device_put with ``shardings`` when given (mesh-agnostic restore)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(shardings)[0]
        leaves = []
        for i, (p, ref) in enumerate(flat):
            key = _key_of(p)
            meta = manifest[key]
            arr = np.load(os.path.join(d, meta["file"]))
            want_dtype = getattr(ref, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(abstract_state), leaves)

    def restore_leaf(self, step: int, key: str) -> np.ndarray:
        """Load ONE leaf of a committed checkpoint by its manifest key —
        the elastic-recovery path: a rank died, only its chunks need
        restoring, and re-reading the whole tree would stall recovery on
        I/O proportional to the world size instead of the loss."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        if key not in manifest:
            raise KeyError(f"checkpoint step {step} has no leaf {key!r}; "
                           f"has {sorted(manifest)[:8]}...")
        return np.load(os.path.join(d, manifest[key]["file"]))

    def restore_latest(self, abstract_state: Any,
                       shardings: Optional[Any] = None) -> Any:
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        return self.restore(step, abstract_state, shardings)
