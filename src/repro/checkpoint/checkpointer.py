"""Checkpointing: async, sharded, rotated — the restart half of fault
tolerance.

Layout per step:  <dir>/step_<N>/
    manifest.json            tree structure + per-leaf metadata (+ digest)
    <leafkey>.npy            one file per leaf (host-gathered)
    COMMIT                   written last — a checkpoint without COMMIT is
                             torn and ignored by restore (crash-safe)

Restore is mesh-agnostic: leaves are loaded on host and re-placed with the
*current* shardings, so a 512-chip checkpoint restores onto a shrunk or
grown mesh (elastic rescale path).

Integrity: each leaf's fold64 content digest is computed at save time
(once, from the already-host-gathered array) and recorded in the
manifest. Every restore path re-digests the loaded bytes and validates
shape/dtype against the manifest — a silently bit-rotted or truncated
leaf raises ``CheckpointIntegrityError`` instead of feeding garbage back
into the job. ``restore_leaf_fallback`` turns that detection into
recovery: walk committed steps newest → oldest and return the first
copy of the leaf that verifies. Manifests written before digests existed
restore fine (the digest check is skipped when the key is absent).

Async saves are no longer fire-and-forget: a failed background write is
recorded and re-raised at the next ``wait()`` or ``save()`` — the
caller that believes a checkpoint exists must find out it does not.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.integrity import digest_array


class CheckpointIntegrityError(RuntimeError):
    """A checkpoint leaf failed digest or shape/dtype validation."""


def _key_of(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) or "leaf"


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 digest: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self.digest = digest
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.stats = {"ckpt_verify_fail": 0, "save_errors": 0}
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, block: bool = False) -> None:
        """Snapshot on host, then write asynchronously (training continues
        while the write is in flight — compute/IO overlap). A pending
        failure from an earlier async write is raised here first: the
        caller must not keep rotating checkpoints on top of a save
        pipeline that is silently broken."""
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host_leaves = [(_key_of(p), np.asarray(v)) for p, v in flat]
        self.wait()

        def write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {}
            for key, arr in host_leaves:
                fn = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
                np.save(os.path.join(tmp, fn), arr)
                entry = {"file": fn, "shape": list(arr.shape),
                         "dtype": str(arr.dtype)}
                if self.digest:
                    entry["digest"] = digest_array(arr)
                manifest[key] = entry
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": manifest}, f)
            with open(os.path.join(tmp, "COMMIT"), "w") as f:
                f.write("ok")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        def write_guarded():
            try:
                write()
            except BaseException as e:  # surfaced at next wait()/save()
                self.stats["save_errors"] += 1
                self._error = e

        if self.async_save and not block:
            self._thread = threading.Thread(target=write_guarded, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint save failed: {err!r}") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "COMMIT")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def _verified_leaf(self, step: int, key: str, meta: Dict,
                       path: str) -> np.ndarray:
        """Load one leaf and validate it against its manifest entry:
        shape and dtype must match exactly, and (when the manifest
        carries one) the fold64 digest of the loaded bytes must equal
        the digest recorded at save time."""
        arr = np.load(path)
        if (list(arr.shape) != list(meta["shape"])
                or str(arr.dtype) != meta["dtype"]):
            self.stats["ckpt_verify_fail"] += 1
            raise CheckpointIntegrityError(
                f"checkpoint step {step} leaf {key!r}: file has "
                f"shape={arr.shape} dtype={arr.dtype}, manifest says "
                f"shape={tuple(meta['shape'])} dtype={meta['dtype']}")
        want = meta.get("digest")
        if want is not None and digest_array(arr) != want:
            self.stats["ckpt_verify_fail"] += 1
            raise CheckpointIntegrityError(
                f"checkpoint step {step} leaf {key!r}: content digest "
                f"mismatch (bit rot or torn write)")
        return arr

    def restore(self, step: int, abstract_state: Any,
                shardings: Optional[Any] = None) -> Any:
        """Load ``step`` into the structure of ``abstract_state``; leaves are
        device_put with ``shardings`` when given (mesh-agnostic restore).
        Every leaf is digest/shape/dtype-verified before placement."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_state)
        shard_flat = None
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(shardings)[0]
        leaves = []
        for i, (p, ref) in enumerate(flat):
            key = _key_of(p)
            meta = manifest[key]
            arr = self._verified_leaf(step, key, meta,
                                      os.path.join(d, meta["file"]))
            want_dtype = getattr(ref, "dtype", arr.dtype)
            arr = arr.astype(want_dtype)
            if shard_flat is not None:
                arr = jax.device_put(arr, shard_flat[i])
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(abstract_state), leaves)

    def restore_leaf(self, step: int, key: str) -> np.ndarray:
        """Load ONE leaf of a committed checkpoint by its manifest key —
        the elastic-recovery path: a rank died, only its chunks need
        restoring, and re-reading the whole tree would stall recovery on
        I/O proportional to the world size instead of the loss. The leaf
        is digest/shape/dtype-verified before it is handed back."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)["leaves"]
        if key not in manifest:
            raise KeyError(f"checkpoint step {step} has no leaf {key!r}; "
                           f"has {sorted(manifest)[:8]}...")
        meta = manifest[key]
        return self._verified_leaf(step, key, meta,
                                   os.path.join(d, meta["file"]))

    def restore_leaf_fallback(self, key: str) -> Tuple[int, np.ndarray]:
        """Detection → recovery: return ``(step, leaf)`` from the NEWEST
        committed step whose copy of ``key`` verifies, skipping corrupted
        or missing copies. Raises ``CheckpointIntegrityError`` only when
        every retained step fails."""
        steps = self.all_steps()
        last_err: Optional[BaseException] = None
        for step in reversed(steps):
            try:
                return step, self.restore_leaf(step, key)
            except (CheckpointIntegrityError, KeyError, OSError,
                    ValueError) as e:
                last_err = e
        raise CheckpointIntegrityError(
            f"no committed step holds a valid copy of leaf {key!r} "
            f"(searched {len(steps)} steps)") from last_err

    def restore_latest(self, abstract_state: Any,
                       shardings: Optional[Any] = None) -> Any:
        step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        return self.restore(step, abstract_state, shardings)
