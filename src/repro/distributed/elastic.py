"""Elastic scaling + fault handling (large-scale runnability layer).

A pod/rank loss is handled as: detect (missed heartbeat) → shrink the worker
set → replay the owner map against the new world → restore chunk data from
the last checkpoint (or from surviving replicas) → continue. Growth is the
same flow without restore. Straggler mitigation reuses the same machinery
with fractional "slowdown" loads feeding the greedy rebalancer — the
over-decomposed chunks are the unit of migration, exactly the paper's
argument for over-decomposition.

Two layers live here:

``ElasticController`` — pure control logic (no I/O, no transport). Health
bookkeeping runs on an **injectable monotonic clock** (``clock=``, default
``time.monotonic``): wall-clock NTP jumps can never mass-declare failures,
and tests drive detection with a fake clock.

``ElasticRuntime`` — binds the controller to a live ``Cluster``: heartbeats
ride the billed control VC as periodic 0-byte control messages
(``Rank.enable_heartbeat``), ``poll()`` fuses three straggler/failure
signals (heartbeat gap, ``InterconnectModel`` EWMA latency outliers,
net-lane backlog), and detection executes plans FOR REAL — survivors sweep
the dead peer (``Rank.remove_peer``), lost chunks are restored from the
checkpoint (or a surviving replica) into consumer-routed rendezvous
streams, stragglers have chunks live-migrated off them while they keep
computing, and the owner map / residency ledgers are replayed against the
new world. ``epoch`` increments after every world change so drivers can
re-plan mid-iteration.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import sanitizer
from repro.distributed import handlers as H
from repro.distributed.mobile_object import OwnerMap, rebalance_greedy


@dataclasses.dataclass
class WorkerHealth:
    last_heartbeat: float
    slowdown: float = 1.0        # >1 = straggler
    alive: bool = True


class ElasticController:
    """Tracks worker health; emits migration/remap plans. Pure control logic
    (no I/O) so it is unit-testable and reusable by the launcher. All
    timestamps come from the injected monotonic ``clock`` — never from
    wall-clock ``time.time()``, which jumps under NTP adjustment."""

    def __init__(self, workers: Sequence[int], heartbeat_timeout: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.health: Dict[int, WorkerHealth] = {
            w: WorkerHealth(self.clock()) for w in workers}
        self.timeout = heartbeat_timeout

    # -- health -------------------------------------------------------------
    def heartbeat(self, worker: int, slowdown: float = 1.0,
                  now: Optional[float] = None) -> None:
        h = self.health[worker]
        h.last_heartbeat = now if now is not None else self.clock()
        h.slowdown = slowdown
        h.alive = True

    def detect_failures(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else self.clock()
        dead = []
        for w, h in self.health.items():
            if h.alive and now - h.last_heartbeat > self.timeout:
                h.alive = False
                dead.append(w)
        return dead

    def alive_workers(self) -> List[int]:
        return [w for w, h in self.health.items() if h.alive]

    # -- plans ----------------------------------------------------------
    def shrink_plan(self, owner: OwnerMap, dead: Sequence[int]
                    ) -> List[Tuple[int, int, int]]:
        """Reassign every chunk owned by dead workers round-robin over the
        survivors. Returns [(oid, old, new)]; data for these chunks must be
        restored from checkpoint (the old rank is gone)."""
        alive = self.alive_workers()
        if not alive:
            raise RuntimeError("no surviving workers")
        plan = []
        i = 0
        for d in dead:
            for oid in owner.owned_by(d):
                dst = alive[i % len(alive)]
                owner.migrate(oid, dst)
                plan.append((oid, d, dst))
                i += 1
        return plan

    def grow_plan(self, owner: OwnerMap, new_workers: Sequence[int],
                  chunk_load: Optional[Dict[int, float]] = None
                  ) -> List[Tuple[int, int, int]]:
        for w in new_workers:
            self.health[w] = WorkerHealth(self.clock())
        loads = self.effective_loads(owner, chunk_load)
        cl = chunk_load or {}
        return rebalance_greedy(loads, owner, cl,
                                max_moves=max(8, len(owner) // 4))

    def straggler_plan(self, owner: OwnerMap,
                       chunk_load: Optional[Dict[int, float]] = None,
                       max_moves: Optional[int] = None
                       ) -> List[Tuple[int, int, int]]:
        loads = self.effective_loads(owner, chunk_load)
        if max_moves is None:
            max_moves = len(owner) // 4 or 1
        return rebalance_greedy(loads, owner, chunk_load or {},
                                max_moves=max_moves)

    def effective_loads(self, owner: OwnerMap,
                        chunk_load: Optional[Dict[int, float]] = None
                        ) -> Dict[int, float]:
        cl = chunk_load or {}
        loads: Dict[int, float] = {w: 0.0 for w in self.alive_workers()}
        for oid, rank in owner.items():
            if rank in loads:
                loads[rank] += cl.get(oid, 1.0) * self.health[rank].slowdown
        return loads


# ---------------------------------------------------------------------------
# transport bindings: heartbeat sink + chunk-restore landing
# ---------------------------------------------------------------------------

@H.handler(name="elastic_heartbeat")
def _elastic_heartbeat(ctx, obj):
    """Monitor-side heartbeat sink: a 0-byte control-VC message from a
    worker's pump loop arrived. Timestamped with the ElasticRuntime's own
    injectable clock at arrival (the controller never sees send-side
    wall-clock)."""
    er = getattr(ctx.rank.cluster, "_elastic", None)
    if er is not None:
        er._on_heartbeat(ctx.message.user["worker"])


@H.handler(name="elastic_restore")
def _elastic_restore(ctx, obj):
    """Landing half of a chunk migration/restore: register the payload
    under its global key on the new owner and notify the coordinator.
    Payloads arrive consumer-routed (device hint from the owner map) and —
    above the eager threshold — as credit-windowed rendezvous streams."""
    u = ctx.message.user or {}
    key = u.get("key")
    if key is not None and obj is not None:
        ctx.rank.register_object(key, obj)
    ctx.rank.stats["chunks_migrated"] += 1
    er = getattr(ctx.rank.cluster, "_elastic", None)
    if er is not None:
        er._on_restore(u.get("token"),
                       obj.nbytes if obj is not None else 0)


class ElasticRuntime:
    """The detect → drain → migrate → resume loop on a live ``Cluster``.

    ``owner`` maps chunk oid → rank; ``key_fn(oid)`` names the chunk in
    each rank's object registry; ``restore_fn(oid)`` produces the chunk's
    last committed bytes (checkpoint read) when no surviving replica
    exists, and ``recompute_fn(oid)`` is the last line of defence when
    the checkpoint read itself fails (corrupted/missing leaf) — e.g. a
    lineage replay or an application-level recompute. ``poll()`` is the
    whole loop body — callable inline for deterministic tests, or from
    the background monitor (``start()``).

    World changes (``recover``/``drain``/``grow``) run under ``_lock``,
    finish all data movement (``quiesce``) and only then bump ``epoch`` —
    a driver that plans an iteration under ``hold()`` sees a consistent
    owner map with no migration in flight."""

    def __init__(self, cluster, owner: OwnerMap, *,
                 key_fn: Optional[Callable[[int], Any]] = None,
                 restore_fn: Optional[Callable[[int], np.ndarray]] = None,
                 recompute_fn: Optional[Callable[[int], np.ndarray]] = None,
                 chunk_load: Optional[Dict[int, float]] = None,
                 clock: Callable[[], float] = time.monotonic,
                 monitor: int = 0,
                 heartbeat_interval_s: Optional[float] = None,
                 heartbeat_timeout_s: Optional[float] = None,
                 straggler_factor: float = 25.0,
                 drain_cooldown_s: float = 1.0,
                 quiesce_timeout_s: float = 60.0):
        cfg = cluster.ranks[monitor].runtime.cfg
        self.cluster = cluster
        self.owner = owner
        self.key_fn = key_fn or (lambda oid: ("chunk", oid))
        self.restore_fn = restore_fn
        self.recompute_fn = recompute_fn
        self.chunk_load = chunk_load
        self.clock = clock
        self.monitor = monitor
        self.interval = heartbeat_interval_s or cfg.heartbeat_interval_s
        self.timeout = heartbeat_timeout_s or cfg.heartbeat_timeout_s
        self.straggler_factor = straggler_factor
        self.drain_cooldown_s = drain_cooldown_s
        self.quiesce_timeout_s = quiesce_timeout_s
        self.controller = ElasticController(
            [r.rank for r in cluster.ranks],
            heartbeat_timeout=self.timeout, clock=clock)
        self.epoch = 0
        self._lock = sanitizer.make_rlock("ElasticRuntime._lock")
        self._beats: List[Tuple[int, float]] = []
        self._beats_lock = sanitizer.make_lock("ElasticRuntime._beats_lock")
        self._tokens = itertools.count()
        self._landings: Dict[int, threading.Event] = {}
        self._pending: List[Tuple[threading.Event, Any, Any, bool]] = []
        self._last_drain: Dict[int, float] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self.stats: Dict[str, Any] = {
            "recoveries": 0, "drains": 0, "grows": 0,
            "chunks_migrated": 0, "bytes_migrated": 0,
            "recovery_stall_s": 0.0, "dead": [], "stragglers": [],
            "straggler_signals": {}, "poll_errors": 0,
            "restore_fallbacks": 0,
        }
        cluster._elastic = self
        for r in cluster.ranks:
            r.enable_heartbeat(monitor, self.interval)

    # -- transport callbacks (pump threads) ----------------------------
    def _on_heartbeat(self, worker: int) -> None:
        with self._beats_lock:
            self._beats.append((worker, self.clock()))

    def _on_restore(self, token: Optional[int], nbytes: int) -> None:
        self.stats["bytes_migrated"] += nbytes
        ev = self._landings.pop(token, None) if token is not None else None
        if ev is not None:
            ev.set()

    # -- monitor loop --------------------------------------------------
    def start(self, period: Optional[float] = None) -> None:
        """Run ``poll()`` on a background monitor thread every ``period``
        seconds (default: the heartbeat interval)."""
        if self._thread is not None:
            return
        self._stop_evt.clear()
        period = period or self.interval

        def loop():
            while not self._stop_evt.wait(period):
                try:
                    self.poll()
                except Exception:
                    self.stats["poll_errors"] += 1

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="elastic-monitor")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop_evt.set()
        self._thread.join(timeout=10)
        self._thread = None

    def close(self) -> None:
        """Stop monitoring and detach from the cluster: heartbeats off,
        backref cleared. The cluster itself stays usable."""
        self.stop()
        for r in self.cluster.ranks:
            r._hb_dst = None
        if getattr(self.cluster, "_elastic", None) is self:
            self.cluster._elastic = None

    def hold(self):
        """Context: block world changes while a driver plans/executes an
        iteration phase against the current owner map."""
        return self._lock

    def quiesce(self, timeout: Optional[float] = None) -> None:
        """Wait until every initiated migration landed at its new owner,
        then replay the residency ledger on each source rank (the chunk
        left; its replicas must not count against that rank)."""
        timeout = timeout or self.quiesce_timeout_s
        with self._lock:
            pending, self._pending = self._pending, []
            for ev, src_rank, key, drop_src in pending:
                if not ev.wait(timeout):
                    raise TimeoutError(
                        f"elastic migration of {key!r} from rank "
                        f"{src_rank.rank} did not land within {timeout:.0f}s")
                if drop_src:
                    obj = src_rank.objects.pop(key, None)
                    if obj is not None:
                        src_rank.runtime.residency.forget(obj)

    # -- detection -----------------------------------------------------
    def _slowdown(self, w: int, gap: float) -> Tuple[float, Dict[str, float]]:
        """Fuse the three straggler signals into one slowdown factor:
        heartbeat gap (liveness), EWMA latency outlier ratio on the
        worker's links toward the monitor (the interconnect model sees a
        frozen rank's delayed traffic), and the worker's net-lane backlog
        (work piling up behind a slow pump)."""
        gap_ratio = gap / self.interval if self.interval > 0 else 1.0
        alive = [x for x in self.controller.alive_workers()
                 if x != self.monitor]
        ratios = self.cluster.topology.latency_outliers(alive, self.monitor)
        lat_ratio = ratios.get(w, 1.0)
        r = self.cluster.ranks[w]
        backlog = r._net_send.backlog() + r._net_recv.backlog()
        score = max(1.0, gap_ratio, lat_ratio * (1.0 + backlog))
        return score, {"gap_ratio": gap_ratio, "latency_ratio": lat_ratio,
                       "backlog": float(backlog)}

    def poll(self) -> Dict[str, Any]:
        """One monitor sweep: drain heartbeat arrivals into the
        controller, score stragglers, detect failures, and execute
        recovery / straggler drains. Returns what happened."""
        with self._lock:
            with self._beats_lock:
                beats, self._beats = self._beats, []
            for worker, t in beats:
                if worker in self.controller.health:
                    self.controller.heartbeat(worker, now=t)
            now = self.clock()
            mon = self.cluster.ranks[self.monitor]
            stragglers = []
            for w in self.controller.alive_workers():
                if w == self.monitor:
                    continue
                h = self.controller.health[w]
                gap = now - h.last_heartbeat
                if gap > 1.5 * self.interval:
                    mon.stats["heartbeats_missed"] += 1
                score, signals = self._slowdown(w, gap)
                h.slowdown = score
                if score >= self.straggler_factor and gap <= self.timeout:
                    cool = self._last_drain.get(w, -1e9)
                    if now - cool >= self.drain_cooldown_s:
                        stragglers.append((w, score, signals))
            dead = self.controller.detect_failures(now)
            events: Dict[str, Any] = {"dead": dead, "drained": []}
            if dead:
                self.recover(dead)
                return events
            for w, score, signals in stragglers:
                moved = self.drain(w)
                if moved:
                    self._last_drain[w] = now
                    self.stats["stragglers"].append(w)
                    self.stats["straggler_signals"][w] = signals
                    events["drained"].append((w, moved))
            return events

    # -- world changes -------------------------------------------------
    def _bump_epoch(self) -> None:
        """Commit a world change: bump the epoch AND drop every rank's
        compiled task graph — replay plans captured placements and
        residency under the old world, and a migrated/restored chunk
        invalidates both (drivers' epoch-redo loops re-trace on the new
        topology)."""
        self.epoch += 1
        for r in self.cluster.ranks:
            r.runtime.invalidate_traces()
            if r.runtime.lineage is not None:
                # records stay (generation checks keep them safe); new
                # ones carry the new epoch for forensics
                r.runtime.lineage.bump_epoch()

    def _alive_ranks(self, exclude: Sequence[int] = ()) -> List[Any]:
        alive = set(self.controller.alive_workers()) - set(exclude)
        return [r for r in self.cluster.ranks if r.rank in alive]

    def _migrate(self, src_rank, dst: int, key: Any, obj, oid: int,
                 drop_src: bool = True) -> None:
        token = next(self._tokens)
        ev = threading.Event()
        self._landings[token] = ev
        self._pending.append((ev, src_rank, key, drop_src))
        src_rank.send(dst, "elastic_restore", obj,
                      user={"key": key, "token": token, "oid": oid},
                      consumer_device=self.owner.device_hint(oid))

    def recover(self, dead: Sequence[int]) -> List[Tuple[int, int, int]]:
        """Execute the shrink: survivors sweep the dead peers' rendezvous
        state, the owner map is replayed over the survivors, and each lost
        chunk is restored — from a surviving replica when one exists
        (another rank already registered the key), else from
        ``restore_fn`` (checkpoint) — streamed to its new owner. The
        monitor rank's ``recovery_stall_s`` records the full detect-side
        stall; ``epoch`` bumps once everything landed."""
        with self._lock:
            t0 = self.clock()
            for d in dead:
                if d in self.controller.health:
                    self.controller.health[d].alive = False
            survivors = self._alive_ranks()
            for d in dead:
                for r in survivors:
                    r.remove_peer(d)
            plan = self.controller.shrink_plan(self.owner, dead)
            mon = self.cluster.ranks[self.monitor]
            for oid, old, new in plan:
                key = self.key_fn(oid)
                replica = next((r for r in survivors if key in r.objects),
                               None)
                if replica is not None:
                    if replica.rank != new:
                        self._migrate(replica, new, key,
                                      replica.objects[key], oid)
                    continue
                # no surviving replica: checkpoint first, then lineage
                # recompute (the checkpoint itself may be corrupted or
                # missing — integrity validation raises rather than
                # restoring garbage), then give up loudly
                arr = None
                restore_err: Optional[BaseException] = None
                if self.restore_fn is not None:
                    try:
                        arr = np.asarray(self.restore_fn(oid))
                    except Exception as e:
                        restore_err = e
                if arr is None and self.recompute_fn is not None:
                    arr = np.asarray(self.recompute_fn(oid))
                    self.stats["restore_fallbacks"] += 1
                if arr is None:
                    raise RuntimeError(
                        f"chunk {oid} lost with rank {old}: no surviving "
                        "replica, no restorable checkpoint "
                        f"({restore_err!r}), and no recompute_fn "
                        "configured") from restore_err
                obj = mon.runtime.hetero_object(arr)
                self._migrate(mon, new, key, obj, oid, drop_src=False)
            self.quiesce()
            stall = self.clock() - t0
            mon.stats["recovery_stall_s"] += stall
            self.stats["recoveries"] += 1
            self.stats["recovery_stall_s"] += stall
            self.stats["dead"].extend(int(d) for d in dead)
            self._bump_epoch()
            return plan

    def drain(self, straggler: int,
              max_moves: Optional[int] = None) -> List[Tuple[int, int, int]]:
        """Live-migrate chunks off a slow-but-alive rank: the controller's
        slowdown-inflated loads feed the greedy rebalancer, and each moved
        chunk streams from the straggler to its new owner as a rendezvous
        stream WHILE the straggler keeps computing its remaining chunks —
        the paper's over-decomposition argument made operational."""
        with self._lock:
            if max_moves is None:
                owned = len(self.owner.owned_by(straggler))
                max_moves = max(1, owned // 2)
            plan = self.controller.straggler_plan(
                self.owner, self.chunk_load, max_moves=max_moves)
            # straggler_plan already remapped the owner map for every
            # planned move; only the straggler's moves are executed here,
            # so roll the others back or the map would point at ranks
            # that never received the data
            keep = []
            for oid, src, dst in plan:
                if src == straggler:
                    keep.append((oid, src, dst))
                else:
                    self.owner.migrate(oid, src)
            plan = keep
            for oid, src, dst in plan:
                key = self.key_fn(oid)
                src_rank = self.cluster.ranks[src]
                obj = src_rank.objects.get(key)
                if obj is None:      # data not registered: undo the remap
                    self.owner.migrate(oid, src)
                    continue
                self._migrate(src_rank, dst, key, obj, oid)
            self.quiesce()
            if plan:
                self.stats["drains"] += 1
                self.stats["chunks_migrated"] += len(plan)
                self._bump_epoch()
            return plan

    def grow(self, new_workers: Sequence[int]
             ) -> List[Tuple[int, int, int]]:
        """A rank (re)joined: sweep its stale protocol state, fold it back
        into the health set, and rebalance chunks onto it with live
        migrations from their current owners."""
        with self._lock:
            for w in new_workers:
                r = self.cluster.ranks[w]
                r.reset_peer_state()
                # Chunks registered before the rank left are stale: the
                # survivors restored them elsewhere and kept computing. If
                # they stayed registered, a later recovery could mistake
                # them for live replicas and resurrect old data.
                for oid, own in list(self.owner.items()):
                    if own != w:
                        obj = r.objects.pop(self.key_fn(oid), None)
                        if obj is not None:
                            r.runtime.residency.forget(obj)
            plan = self.controller.grow_plan(self.owner, new_workers,
                                             self.chunk_load)
            for oid, src, dst in plan:
                key = self.key_fn(oid)
                src_rank = self.cluster.ranks[src]
                obj = src_rank.objects.get(key)
                if obj is None:
                    self.owner.migrate(oid, src)
                    continue
                self._migrate(src_rank, dst, key, obj, oid)
            self.quiesce()
            if plan:
                self.stats["grows"] += 1
                self.stats["chunks_migrated"] += len(plan)
                self._bump_epoch()
            return plan

    def report(self) -> Dict[str, Any]:
        mon = self.cluster.ranks[self.monitor]
        rep = {
            "elastic": dict(self.stats),
            "monitor_stats": {k: mon.stats[k] for k in
                              ("heartbeats_missed", "recovery_stall_s",
                               "retries", "chunks_migrated")},
        }
        san = sanitizer.current()
        if san is not None:
            rep["sanitizer"] = san.stats_snapshot()
        return rep
