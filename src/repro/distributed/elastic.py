"""Elastic scaling + fault handling (large-scale runnability layer).

A pod/rank loss is handled as: detect (missed heartbeat) → shrink the worker
set → replay the owner map against the new world → restore chunk data from
the last checkpoint (or from surviving replicas) → continue. Growth is the
same flow without restore. Straggler mitigation reuses the same machinery
with fractional "slowdown" loads feeding the greedy rebalancer — the
over-decomposed chunks are the unit of migration, exactly the paper's
argument for over-decomposition.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.distributed.mobile_object import OwnerMap, rebalance_greedy


@dataclasses.dataclass
class WorkerHealth:
    last_heartbeat: float
    slowdown: float = 1.0        # >1 = straggler
    alive: bool = True


class ElasticController:
    """Tracks worker health; emits migration/remap plans. Pure control logic
    (no I/O) so it is unit-testable and reusable by the launcher."""

    def __init__(self, workers: Sequence[int], heartbeat_timeout: float = 10.0):
        self.health: Dict[int, WorkerHealth] = {
            w: WorkerHealth(time.time()) for w in workers}
        self.timeout = heartbeat_timeout

    # -- health -------------------------------------------------------------
    def heartbeat(self, worker: int, slowdown: float = 1.0,
                  now: Optional[float] = None) -> None:
        h = self.health[worker]
        h.last_heartbeat = now if now is not None else time.time()
        h.slowdown = slowdown
        h.alive = True

    def detect_failures(self, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        dead = []
        for w, h in self.health.items():
            if h.alive and now - h.last_heartbeat > self.timeout:
                h.alive = False
                dead.append(w)
        return dead

    def alive_workers(self) -> List[int]:
        return [w for w, h in self.health.items() if h.alive]

    # -- plans ----------------------------------------------------------
    def shrink_plan(self, owner: OwnerMap, dead: Sequence[int]
                    ) -> List[Tuple[int, int, int]]:
        """Reassign every chunk owned by dead workers round-robin over the
        survivors. Returns [(oid, old, new)]; data for these chunks must be
        restored from checkpoint (the old rank is gone)."""
        alive = self.alive_workers()
        if not alive:
            raise RuntimeError("no surviving workers")
        plan = []
        i = 0
        for d in dead:
            for oid in owner.owned_by(d):
                dst = alive[i % len(alive)]
                owner.migrate(oid, dst)
                plan.append((oid, d, dst))
                i += 1
        return plan

    def grow_plan(self, owner: OwnerMap, new_workers: Sequence[int],
                  chunk_load: Optional[Dict[int, float]] = None
                  ) -> List[Tuple[int, int, int]]:
        for w in new_workers:
            self.health[w] = WorkerHealth(time.time())
        loads = self.effective_loads(owner, chunk_load)
        cl = chunk_load or {}
        return rebalance_greedy(loads, owner, cl,
                                max_moves=max(8, len(owner) // 4))

    def straggler_plan(self, owner: OwnerMap,
                       chunk_load: Optional[Dict[int, float]] = None
                       ) -> List[Tuple[int, int, int]]:
        loads = self.effective_loads(owner, chunk_load)
        return rebalance_greedy(loads, owner, chunk_load or {},
                                max_moves=len(owner) // 4 or 1)

    def effective_loads(self, owner: OwnerMap,
                        chunk_load: Optional[Dict[int, float]] = None
                        ) -> Dict[int, float]:
        cl = chunk_load or {}
        loads: Dict[int, float] = {w: 0.0 for w in self.alive_workers()}
        for oid, rank in owner.items():
            if rank in loads:
                loads[rank] += cl.get(oid, 1.0) * self.health[rank].slowdown
        return loads
