"""Runtime collectives over rendezvous streams (ISSUE 9, paper §4.2).

The paper's distributed claim — pipelined chunk streaming beating
monolithic transfers on large messages while small-message overhead
stays under 10% — is a point-to-point property. ``CollectiveGroup``
extends it to multi-party reductions by COMPOSING the existing
machinery instead of bypassing it:

* **Large payloads** (above ``RuntimeConfig.coll_ring_cutover_bytes``)
  run as pipelined chunked rings: a reduce-scatter phase of chained
  ``Rank.reduce_into`` rendezvous streams (each hop's per-chunk adds are
  fused on the consumer device's transfer lane, so chunk k+1's network
  receive overlaps chunk k's reduction) followed by an allgather phase
  of chained ``Rank.put`` streams. With R parties each of the R segment
  chains runs concurrently at a different ring offset, so every link
  carries traffic the whole time — the classic bandwidth-optimal ring,
  built from credit-windowed streams.
* **Small payloads** run as eager binomial trees (latency-bound regime):
  contributions combine up the tree, the result fans back down.
* **Topology**: the ring neighbor order and tree shape come from the
  ``InterconnectModel`` EWMA link estimates (``ring_order`` /
  ``tree_order``), hierarchically — members sharing a node first chain-
  reduce onto one leader per node, only leaders run the inter-node ring,
  then leaders fan the result back out. Shapes are FROZEN at group
  creation: a drifting estimate must not re-order reductions between two
  identical calls.
* **Determinism**: every reduction order is fixed by the schedule, never
  by arrival order — tree combines wait for ALL children and fold them
  in ascending position order; ring chains are sequenced hop-by-hop by
  completion handlers. ``oracle_allreduce`` replays the exact schedule
  single-threaded in numpy; results are bitwise-identical to it.
* **Elasticity**: ops are tag-scoped and epoch-stamped. The driver polls
  ``epoch_fn`` while waiting; an ``ElasticRuntime`` epoch bump
  mid-collective aborts cleanly (``CollectiveAborted``, accumulator keys
  unregistered so straggling streams land in the void, per-rank
  ``coll_aborts`` counted) and the caller re-runs after recovery.

Hop sequencing is continuation-driven: each hop's ``on_done`` handler
fires on the RECEIVING rank and issues the next hop from there — no
driver round-trips mid-chain, and since every chain is a linear sequence
of independent streams there is no waits-for cycle to deadlock under the
AIMD credit controller.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import sanitizer
from repro.distributed.handlers import handler

__all__ = ["CollectiveGroup", "CollectiveAborted"]


class CollectiveAborted(RuntimeError):
    """An in-flight collective was aborted by an elastic epoch bump; the
    caller re-runs it (same group, fresh tag) after recovery."""


def _segment_bounds(n: int, parts: int) -> List[tuple]:
    """Contiguous near-equal split of ``n`` elements (uneven-friendly:
    the same convention jacobi uses for slab bounds)."""
    return [(p * n // parts, (p + 1) * n // parts) for p in range(parts)]


def _tree_parent(p: int) -> int:
    """Binomial-tree parent of position ``p`` (> 0): clear the lowest
    set bit — the standard MPI binomial shape."""
    return p & (p - 1)


def _tree_children(p: int, size: int) -> List[int]:
    """Binomial-tree children of position ``p`` in a ``size``-wide tree,
    ascending. Position 0 fans to 1, 2, 4, …; an internal position p
    fans to p+1, p+2, … below its own lowest set bit."""
    out, bit = [], 1
    lim = (p & -p) if p else size
    while bit < lim:
        c = p + bit
        if c < size:
            out.append(c)
        bit <<= 1
    return out


def _host_value(obj) -> np.ndarray:
    """Private host copy of a hetero_object's current value."""
    fut = obj.request_host(write=False)
    arr = np.array(fut.get())
    obj.release()
    return arr


def _engine_for(ctx, user) -> Optional["CollectiveGroup"]:
    reg = getattr(ctx.rank.cluster, "_coll_groups", None)
    if reg is None or not user:
        return None
    return reg.get(user.get("gid"))


@handler(name="coll_hop")
def _coll_hop(ctx, obj):
    """Completion continuation of one ring/chain hop (``on_done`` of a
    collective put / reduce_into): runs on the receiving rank, hands the
    hop back to the group engine, which issues the next hop from here."""
    eng = _engine_for(ctx, ctx.user)
    if eng is not None:
        eng._on_hop(ctx.rank, ctx.user)


@handler(name="coll_tree_up")
def _coll_tree_up(ctx, obj):
    """One child's contribution arriving at its binomial-tree parent."""
    eng = _engine_for(ctx, ctx.user)
    if eng is not None:
        eng._on_tree_up(ctx.rank, ctx.user, obj)


@handler(name="coll_tree_down")
def _coll_tree_down(ctx, obj):
    """Reduced result fanning back down the binomial tree."""
    eng = _engine_for(ctx, ctx.user)
    if eng is not None:
        eng._on_tree_down(ctx.rank, ctx.user, obj)


class CollectiveGroup:
    """Collective communicator over a set of cluster ranks.

    ``members`` — participating rank ids (default: all ranks).
    ``nodes`` — optional ``{rank: node_id}`` placement; members sharing a
    node reduce locally onto one leader before the inter-node ring.
    ``epoch_fn`` — elastic epoch source (e.g. ``lambda: elastic.epoch``);
    a bump observed mid-collective raises ``CollectiveAborted``.

    All ops take one driver-side array per member (aligned with
    ``group.members``) and return one result per member; ``reduce``
    returns the result only at ``root`` (None elsewhere)."""

    def __init__(self, cluster, members: Optional[Sequence[int]] = None,
                 nodes: Optional[Dict[int, Any]] = None,
                 epoch_fn=None, timeout_s: float = 120.0):
        self.cluster = cluster
        self.members: List[int] = sorted(
            members if members is not None else range(len(cluster.ranks)))
        if not self.members:
            raise ValueError("collective group needs at least one member")
        self.nodes = {m: (nodes.get(m, m) if nodes else m)
                      for m in self.members}
        self.epoch_fn = epoch_fn if epoch_fn is not None else (lambda: 0)
        self.timeout_s = timeout_s
        cfg = cluster.ranks[self.members[0]].runtime.cfg
        self.cutover_bytes = cfg.coll_ring_cutover_bytes
        self.tag_space = cfg.coll_tag_space
        by_node: Dict[Any, List[int]] = {}
        for m in self.members:
            by_node.setdefault(self.nodes[m], []).append(m)
        # leader = smallest member of each node (deterministic)
        self._node_members = {k: sorted(v) for k, v in by_node.items()}
        self.leaders = sorted(v[0] for v in self._node_members.values())
        # ring/tree shapes FROZEN at group creation from the current EWMA
        # table (see module docstring: determinism beats freshness here)
        self.ring: List[int] = cluster.topology.ring_order(self.leaders)
        self.ring_m: List[int] = cluster.topology.ring_order(self.members)
        self._tree_cache: Dict[int, List[int]] = {}
        self._tag_counter = itertools.count()
        self._lock = sanitizer.make_lock("CollectiveGroup._lock")
        self._ops: Dict[int, Dict[str, Any]] = {}
        reg = getattr(cluster, "_coll_groups", None)
        if reg is None:
            reg = cluster._coll_groups = {}
        self.gid = len(reg)
        reg[self.gid] = self

    # -- plumbing ------------------------------------------------------
    def _tree(self, root: int) -> List[int]:
        order = self._tree_cache.get(root)
        if order is None:
            order = self.cluster.topology.tree_order(root, self.members)
            self._tree_cache[root] = order
        return order

    def _new_op(self, kind: str) -> Dict[str, Any]:
        with self._lock:
            tag = next(self._tag_counter) % self.tag_space
            if tag in self._ops:
                raise RuntimeError(
                    f"collective tag space exhausted: {len(self._ops)} "
                    f"ops in flight with coll_tag_space={self.tag_space}")
            op = {"tag": tag, "kind": kind, "epoch": self.epoch_fn(),
                  "done": threading.Event(), "err": None, "aborted": False,
                  "lock": sanitizer.make_lock("CollectiveGroup.op_lock"),
                  "keys": {m: [] for m in self.members}}
            self._ops[tag] = op
        return op

    def _op_for(self, user) -> Optional[Dict[str, Any]]:
        """Resolve a handler invocation to its live op — stale tags (op
        finished/aborted) and stale epochs drop silently."""
        if not user:
            return None
        with self._lock:
            op = self._ops.get(user.get("tag"))
        if op is None or op["aborted"] or op["epoch"] != user.get("e"):
            return None
        return op

    def _user(self, op: Dict[str, Any], ph: str, **kw) -> Dict[str, Any]:
        u = {"gid": self.gid, "tag": op["tag"], "e": op["epoch"], "ph": ph}
        u.update(kw)
        return u

    def _key(self, op: Dict[str, Any], sfx: Any):
        return ("coll", self.gid, op["tag"], sfx)

    def _register(self, op: Dict[str, Any], member: int, sfx: Any,
                  arr: np.ndarray) -> None:
        rank = self.cluster.ranks[member]
        key = self._key(op, sfx)
        rank.register_object(key, rank.runtime.hetero_object(np.array(arr)))
        op["keys"][member].append(key)

    def _obj(self, member: int, op: Dict[str, Any], sfx: Any):
        return self.cluster.ranks[member].objects[self._key(op, sfx)]

    def _cleanup(self, op: Dict[str, Any]) -> None:
        for m, keys in op["keys"].items():
            rank = self.cluster.ranks[m]
            for key in keys:
                rank.objects.pop(key, None)
        with self._lock:
            self._ops.pop(op["tag"], None)

    def _abort(self, op: Dict[str, Any]) -> None:
        """Epoch bump / timeout mid-collective: mark the op dead so late
        handler continuations drop, unregister every accumulator key so
        straggling streams land in the void (the messaging layer no-ops
        a put/reduce against an unregistered key), and count the abort
        on every member."""
        with op["lock"]:
            op["aborted"] = True
        self._cleanup(op)
        for m in self.members:
            self.cluster.ranks[m].stats["coll_aborts"] += 1

    def _fail(self, op: Dict[str, Any], exc: BaseException) -> None:
        op["err"] = exc
        op["done"].set()

    def _await(self, op: Dict[str, Any]) -> None:
        deadline = time.perf_counter() + self.timeout_s
        try:
            while not op["done"].wait(0.005):
                if self.epoch_fn() != op["epoch"]:
                    raise CollectiveAborted(
                        f"{op['kind']} (tag {op['tag']}) aborted: epoch "
                        f"moved {op['epoch']} -> {self.epoch_fn()} "
                        "mid-collective")
                if time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"collective {op['kind']} (tag {op['tag']}) did "
                        f"not complete within {self.timeout_s:.0f}s")
        except (CollectiveAborted, TimeoutError):
            self._abort(op)
            raise
        if op["err"] is not None:
            err, op["err"] = op["err"], None
            self._cleanup(op)
            raise RuntimeError(
                f"collective {op['kind']} (tag {op['tag']}) failed") \
                from err

    def _check_inputs(self, inputs: Sequence[Any]) -> List[np.ndarray]:
        if len(inputs) != len(self.members):
            raise ValueError(
                f"expected {len(self.members)} inputs (one per member "
                f"{self.members}), got {len(inputs)}")
        arrs = [np.asarray(x) for x in inputs]
        s0, d0 = arrs[0].shape, arrs[0].dtype
        for a in arrs[1:]:
            if a.shape != s0 or a.dtype != d0:
                raise ValueError(
                    f"collective inputs must agree on shape/dtype: "
                    f"{(s0, d0)} vs {(a.shape, a.dtype)}")
        return arrs

    # -- handler continuations -----------------------------------------
    def _on_hop(self, rank, user) -> None:
        op = self._op_for(user)
        if op is None:
            return
        try:
            ph = user["ph"]
            if ph == "intra":
                self._intra_done(op, user)
            elif ph == "rs":
                self._rs_done(op, user)
            elif ph == "ag":
                self._ag_done(op, user)
            elif ph == "chain":
                self._chain_done(op, user)
            else:                      # "bcast" | "gather": count-only
                self._count_done(op)
        except BaseException as e:     # surface on the driver, not pump
            self._fail(op, e)

    def _count_done(self, op: Dict[str, Any], ring_part: bool = False
                    ) -> None:
        st = op["ring_st"]
        with op["lock"]:
            st["left"] -= 1
            left = st["left"]
            if ring_part:
                st["ring_left"] -= 1
                ring_left = st["ring_left"]
            else:
                ring_left = None
        if ring_left == 0 and st.get("bcast", False):
            self._start_bcast(op)
        if left == 0:
            op["done"].set()

    # intra-node chain: members of one node fold into the leader, one
    # segment chain at a time, ascending member order (deterministic)
    def _issue_intra(self, op: Dict[str, Any], node: Any, g: int) -> None:
        st = op["ring_st"]
        mems = self._node_members[node]
        m = mems[st["intra_cursor"][(node, g)]]
        self.cluster.ranks[m].reduce_into(
            mems[0], self._key(op, g), st["src"][(m, g)],
            on_done="coll_hop",
            user=self._user(op, "intra", node=node, seg=g))

    def _intra_done(self, op: Dict[str, Any], user) -> None:
        st = op["ring_st"]
        node, g = user["node"], user["seg"]
        mems = self._node_members[node]
        with op["lock"]:
            st["intra_cursor"][(node, g)] += 1
            nxt = st["intra_cursor"][(node, g)]
            st["intra_left"] -= 1
            st["left"] -= 1
            barrier_clear = st["intra_left"] == 0
            left = st["left"]
        if nxt < len(mems):
            self._issue_intra(op, node, g)
        if barrier_clear:
            # ring hops must not land on a leader whose intra chain is
            # still folding (the add order would depend on arrival):
            # the ring phase starts only once EVERY node's chains are in
            if st["ring_left"]:
                self._start_ring(op)
            elif st.get("bcast", False):
                self._start_bcast(op)
        if left == 0:
            op["done"].set()

    # ring reduce-scatter: segment g's chain starts at position g+1 and
    # closes at position g, which then owns the fully reduced segment
    def _issue_rs(self, op: Dict[str, Any], g: int, h: int) -> None:
        st = op["ring_st"]
        ring = st["ring"]
        R = len(ring)
        sp, rp = ring[(g + 1 + h) % R], ring[(g + 2 + h) % R]
        self.cluster.ranks[sp].reduce_into(
            rp, self._key(op, g), self._obj(sp, op, g),
            on_done="coll_hop", user=self._user(op, "rs", seg=g, h=h))

    def _start_ring(self, op: Dict[str, Any]) -> None:
        for g in range(len(op["ring_st"]["bounds"])):
            self._issue_rs(op, g, 0)

    def _rs_done(self, op: Dict[str, Any], user) -> None:
        st = op["ring_st"]
        R = len(st["ring"])
        g, h = user["seg"], user["h"]
        if h < R - 2:
            self._issue_rs(op, g, h + 1)
        else:
            kind = op["kind"]
            if kind == "ring_allreduce":
                self._issue_ag(op, g, 0)   # seg g final here: gather it
            elif kind == "ring_reduce":
                root = st["root"]
                if st["ring"][g] != root:
                    self.cluster.ranks[st["ring"][g]].put(
                        root, self._key(op, g),
                        self._obj(st["ring"][g], op, g),
                        on_done="coll_hop",
                        user=self._user(op, "gather", seg=g))
        self._count_done(op, ring_part=True)

    # ring allgather: position g's final segment travels g→g+1→…,
    # overwriting (put) every accumulator it passes through
    def _issue_ag(self, op: Dict[str, Any], g: int, h: int) -> None:
        st = op["ring_st"]
        ring = st["ring"]
        R = len(ring)
        sp, rp = ring[(g + h) % R], ring[(g + 1 + h) % R]
        self.cluster.ranks[sp].put(
            rp, self._key(op, g), self._obj(sp, op, g),
            on_done="coll_hop", user=self._user(op, "ag", seg=g, h=h))

    def _ag_done(self, op: Dict[str, Any], user) -> None:
        R = len(op["ring_st"]["ring"])
        g, h = user["seg"], user["h"]
        if h < R - 2:
            self._issue_ag(op, g, h + 1)
        self._count_done(op, ring_part=True)

    # put chains for broadcast/allgather: block b originates at ring
    # position start and travels R-1 hops around
    def _issue_chain(self, op: Dict[str, Any], b: int, h: int) -> None:
        st = op["ring_st"]
        ring = st["ring"]
        R = len(ring)
        blk = st["blocks"][b]
        sp = ring[(blk["start"] + h) % R]
        rp = ring[(blk["start"] + h + 1) % R]
        self.cluster.ranks[sp].put(
            rp, self._key(op, blk["sfx"]), self._obj(sp, op, blk["sfx"]),
            on_done="coll_hop", user=self._user(op, "chain", b=b, h=h))

    def _chain_done(self, op: Dict[str, Any], user) -> None:
        R = len(op["ring_st"]["ring"])
        b, h = user["b"], user["h"]
        if h < R - 2:
            self._issue_chain(op, b, h + 1)
        self._count_done(op)

    # leaders fan the finished vector out to their node's members
    def _start_bcast(self, op: Dict[str, Any]) -> None:
        st = op["ring_st"]
        nseg = len(st["bounds"])
        for mems in self._node_members.values():
            leader = mems[0]
            for m in mems[1:]:
                for g in range(nseg):
                    self.cluster.ranks[leader].put(
                        m, self._key(op, g), self._obj(leader, op, g),
                        on_done="coll_hop",
                        user=self._user(op, "bcast", seg=g))

    # -- binomial tree (small-payload path) ----------------------------
    def _send_up(self, op: Dict[str, Any], p: int,
                 acc: Optional[np.ndarray] = None) -> None:
        st = op["tree"]
        order = st["order"]
        arr = st["local"][p] if acc is None else acc
        rank = self.cluster.ranks[order[p]]
        rank.send(order[_tree_parent(p)], "coll_tree_up",
                  rank.runtime.hetero_object(arr),
                  user=self._user(op, "up", cpos=p, pos=_tree_parent(p)))

    def _on_tree_up(self, rank, user, obj) -> None:
        op = self._op_for(user)
        if op is None:
            return
        try:
            arr = _host_value(obj)
            st = op["tree"]
            p = user["pos"]
            with op["lock"]:
                st["contrib"][p][user["cpos"]] = arr
                ready = len(st["contrib"][p]) == st["need"][p]
            if not ready:
                return
            # deterministic combine: local value first, then children in
            # ascending position order — arrival order is irrelevant
            acc = st["local"][p]
            for c in sorted(st["contrib"][p]):
                acc = acc + st["contrib"][p][c]
                rank.stats["coll_bytes_reduced"] += int(arr.nbytes)
            if p == 0:
                st["res"][0] = acc
                if st["down_left"] == 0:
                    op["done"].set()
                else:
                    self._send_down(op, 0, acc)
            else:
                self._send_up(op, p, acc)
        except BaseException as e:
            self._fail(op, e)

    def _send_down(self, op: Dict[str, Any], p: int,
                   arr: np.ndarray) -> None:
        st = op["tree"]
        order = st["order"]
        rank = self.cluster.ranks[order[p]]
        for c in _tree_children(p, len(order)):
            rank.send(order[c], "coll_tree_down",
                      rank.runtime.hetero_object(arr),
                      user=self._user(op, "down", pos=c))

    def _on_tree_down(self, rank, user, obj) -> None:
        op = self._op_for(user)
        if op is None:
            return
        try:
            arr = _host_value(obj)
            st = op["tree"]
            p = user["pos"]
            self._send_down(op, p, arr)
            with op["lock"]:
                st["res"][p] = arr
                st["down_left"] -= 1
                last = st["down_left"] == 0
            if last:
                op["done"].set()
        except BaseException as e:
            self._fail(op, e)

    def _run_tree(self, arrs: List[np.ndarray], root: int,
                  kind: str, down: bool,
                  seed: Optional[np.ndarray] = None) -> Dict[int, Any]:
        """Shared binomial-tree driver. ``down=False`` reduces to the
        root only; ``seed`` (broadcast) skips the up phase entirely and
        fans ``seed`` down from the root. Returns ``{position: array}``."""
        order = self._tree(root)
        R = len(order)
        op = self._new_op(kind)
        idx = {m: i for i, m in enumerate(self.members)}
        st = {
            "order": order,
            "local": {p: arrs[idx[order[p]]] for p in range(R)}
            if arrs else {},
            "contrib": {p: {} for p in range(R)},
            "need": {p: len(_tree_children(p, R)) for p in range(R)},
            "res": {},
            "down_left": (R - 1) if down else 0,
        }
        op["tree"] = st
        if seed is not None:
            st["res"][0] = seed
            self._send_down(op, 0, seed)
        else:
            for p in range(1, R):
                if st["need"][p] == 0:
                    self._send_up(op, p)
            if st["need"][0] == 0:     # degenerate: can't happen, R >= 2
                st["res"][0] = st["local"][0]
                op["done"].set()
        self._await(op)
        res = dict(st["res"])
        self._cleanup(op)
        return {order[p]: v for p, v in res.items()}

    # -- public ops ----------------------------------------------------
    def allreduce(self, inputs: Sequence[Any],
                  average: bool = False) -> List[np.ndarray]:
        """Every member contributes one array, every member receives the
        (identically grouped, bit-deterministic) sum — binomial tree at
        or below the cutover, hierarchical pipelined ring above it.
        ``average=True`` divides the result by the member count
        (driver-side, after the deterministic sum)."""
        arrs = self._check_inputs(inputs)
        shape = arrs[0].shape
        n = len(self.members)
        if n == 1:
            outs = [arrs[0].copy()]
        elif arrs[0].nbytes <= self.cutover_bytes:
            by_member = self._run_tree(arrs, self.members[0],
                                       "tree_allreduce", down=True)
            outs = [by_member[m] for m in self.members]
        else:
            outs = self._ring_allreduce(arrs)
        outs = [o.reshape(shape) for o in outs]
        if average:
            outs = [(o / n).astype(o.dtype, copy=False) for o in outs]
        return outs

    def _ring_allreduce(self, arrs: List[np.ndarray]) -> List[np.ndarray]:
        flats = {m: arrs[i].reshape(-1)
                 for i, m in enumerate(self.members)}
        ring = self.ring
        R = len(ring)
        leaders = set(ring)
        N = flats[self.members[0]].size
        bounds = _segment_bounds(N, R)
        dtype = flats[self.members[0]].dtype
        op = self._new_op("ring_allreduce")
        intra_total = sum(
            (len(v) - 1) * R for v in self._node_members.values())
        bcast_total = sum(
            (len(v) - 1) * R for v in self._node_members.values())
        ring_total = 2 * R * (R - 1)
        st = {
            "ring": ring, "bounds": bounds,
            "intra_cursor": {}, "src": {},
            "intra_left": intra_total,
            "ring_left": ring_total,
            "left": intra_total + ring_total + bcast_total,
            "bcast": bcast_total > 0,
        }
        op["ring_st"] = st
        # one accumulator object per (member, segment): leaders start at
        # their own slice, non-leaders at zeros (the bcast landing slot)
        for m in self.members:
            for g, (lo, hi) in enumerate(bounds):
                init = flats[m][lo:hi] if m in leaders \
                    else np.zeros(hi - lo, dtype)
                self._register(op, m, g, init)
        # non-leader contributions travel as plain source objects
        for node, mems in self._node_members.items():
            for m in mems[1:]:
                rank = self.cluster.ranks[m]
                for g, (lo, hi) in enumerate(bounds):
                    st["src"][(m, g)] = rank.runtime.hetero_object(
                        np.array(flats[m][lo:hi]))
                for g in range(R):
                    st["intra_cursor"][(node, g)] = 1
        if intra_total:
            for node, mems in self._node_members.items():
                if len(mems) > 1:
                    for g in range(R):
                        self._issue_intra(op, node, g)
        else:
            self._start_ring(op)
        self._await(op)
        outs = []
        for m in self.members:
            segs = [_host_value(self._obj(m, op, g)) for g in range(R)]
            outs.append(np.concatenate(segs) if R > 1 else segs[0])
        self._cleanup(op)
        return outs

    def reduce(self, inputs: Sequence[Any],
               root: int) -> List[Optional[np.ndarray]]:
        """Sum every member's array at ``root`` (None elsewhere): tree-up
        below the cutover, ring reduce-scatter + segment gather above."""
        arrs = self._check_inputs(inputs)
        if root not in self.members:
            raise ValueError(f"root {root} not in members {self.members}")
        shape = arrs[0].shape
        if len(self.members) == 1:
            return [arrs[0].copy()]
        if arrs[0].nbytes <= self.cutover_bytes:
            by_member = self._run_tree(arrs, root, "tree_reduce",
                                       down=False)
            return [by_member[root].reshape(shape) if m == root else None
                    for m in self.members]
        flats = {m: arrs[i].reshape(-1)
                 for i, m in enumerate(self.members)}
        ring = self.ring_m
        R = len(ring)
        N = flats[root].size
        bounds = _segment_bounds(N, R)
        op = self._new_op("ring_reduce")
        st = {"ring": ring, "bounds": bounds, "root": root,
              "intra_left": 0,
              "ring_left": R * (R - 1) + (R - 1),
              "left": R * (R - 1) + (R - 1),
              "bcast": False}
        op["ring_st"] = st
        for m in self.members:
            for g, (lo, hi) in enumerate(bounds):
                self._register(op, m, g, flats[m][lo:hi])
        self._start_ring(op)
        self._await(op)
        segs = [_host_value(self._obj(root, op, g)) for g in range(R)]
        out = (np.concatenate(segs) if R > 1 else segs[0]).reshape(shape)
        self._cleanup(op)
        return [out if m == root else None for m in self.members]

    def broadcast(self, x: Any, root: int) -> List[np.ndarray]:
        """Every member receives ``root``'s array: binomial tree below
        the cutover, segmented pipelined ring of put chains above."""
        arr = np.asarray(x)
        if root not in self.members:
            raise ValueError(f"root {root} not in members {self.members}")
        if len(self.members) == 1:
            return [arr.copy()]
        if arr.nbytes <= self.cutover_bytes:
            by_member = self._run_tree([], root, "tree_bcast", down=True,
                                       seed=arr)
            return [np.array(by_member[m]) for m in self.members]
        flat = arr.reshape(-1)
        ring = self.ring_m
        i = ring.index(root)
        ring = ring[i:] + ring[:i]      # root leads the chain
        R = len(ring)
        bounds = _segment_bounds(flat.size, R)
        op = self._new_op("ring_bcast")
        st = {"ring": ring, "bounds": bounds,
              "blocks": [{"sfx": g, "start": 0} for g in range(R)],
              "left": R * (R - 1)}
        op["ring_st"] = st
        for m in self.members:
            for g, (lo, hi) in enumerate(bounds):
                init = flat[lo:hi] if m == root \
                    else np.zeros(hi - lo, flat.dtype)
                self._register(op, m, g, init)
        for b in range(R):
            self._issue_chain(op, b, 0)
        self._await(op)
        outs = []
        for m in self.members:
            segs = [_host_value(self._obj(m, op, g)) for g in range(R)]
            outs.append((np.concatenate(segs) if R > 1 else segs[0])
                        .reshape(arr.shape))
        self._cleanup(op)
        return outs

    def allgather(self, blocks: Sequence[Any]) -> List[np.ndarray]:
        """Every member contributes a (possibly different-length) 1-D
        block; every member receives the concatenation in member order.
        Ring of put chains: member q's block enters at q's ring position
        and travels R-1 hops."""
        arrs = [np.asarray(b).reshape(-1) for b in blocks]
        if len(arrs) != len(self.members):
            raise ValueError(
                f"expected {len(self.members)} blocks, got {len(arrs)}")
        if len(self.members) == 1:
            return [arrs[0].copy()]
        ring = self.ring_m
        R = len(ring)
        pos = {m: i for i, m in enumerate(ring)}
        op = self._new_op("allgather")
        st = {"ring": ring, "blocks": [], "left": R * (R - 1)}
        op["ring_st"] = st
        for q_i, q in enumerate(self.members):
            for m in self.members:
                init = arrs[q_i] if m == q \
                    else np.zeros(arrs[q_i].size, arrs[q_i].dtype)
                self._register(op, m, ("b", q), init)
            st["blocks"].append({"sfx": ("b", q), "start": pos[q]})
        for b in range(len(st["blocks"])):
            self._issue_chain(op, b, 0)
        self._await(op)
        outs = []
        for m in self.members:
            outs.append(np.concatenate(
                [_host_value(self._obj(m, op, ("b", q)))
                 for q in self.members]))
        self._cleanup(op)
        return outs

    def reduce_scatter(self, inputs: Sequence[Any]) -> List[np.ndarray]:
        """Sum across members, scatter the segments: member at ring
        position g receives segment g of the reduced vector (flattened;
        the ring reduce-scatter phase alone)."""
        arrs = self._check_inputs(inputs)
        flats = {m: arrs[i].reshape(-1)
                 for i, m in enumerate(self.members)}
        if len(self.members) == 1:
            return [flats[self.members[0]].copy()]
        ring = self.ring_m
        R = len(ring)
        N = flats[self.members[0]].size
        bounds = _segment_bounds(N, R)
        op = self._new_op("reduce_scatter")
        st = {"ring": ring, "bounds": bounds, "intra_left": 0,
              "ring_left": R * (R - 1), "left": R * (R - 1),
              "bcast": False}
        op["ring_st"] = st
        for m in self.members:
            for g, (lo, hi) in enumerate(bounds):
                self._register(op, m, g, flats[m][lo:hi])
        self._start_ring(op)
        self._await(op)
        pos = {m: i for i, m in enumerate(ring)}
        outs = [_host_value(self._obj(m, op, pos[m]))
                for m in self.members]
        self._cleanup(op)
        return outs

    # -- single-rank oracles (bit-determinism contract) ----------------
    def oracle_allreduce(self, inputs: Sequence[Any],
                         average: bool = False) -> List[np.ndarray]:
        """Replay allreduce's exact reduction schedule single-threaded in
        numpy — the reference the runtime result is bitwise-identical
        to. Same cutover, same tree shape, same ring order, same operand
        order per add."""
        arrs = self._check_inputs(inputs)
        shape = arrs[0].shape
        n = len(self.members)
        if n == 1:
            out = arrs[0].copy()
        elif arrs[0].nbytes <= self.cutover_bytes:
            out = self._oracle_tree(arrs, self.members[0])
        else:
            out = self._oracle_ring(
                {m: arrs[i].reshape(-1) for i, m in
                 enumerate(self.members)}, hierarchical=True)
        out = out.reshape(shape)
        if average:
            out = (out / n).astype(out.dtype, copy=False)
        return [out.copy() for _ in self.members]

    def oracle_reduce(self, inputs: Sequence[Any], root: int
                      ) -> np.ndarray:
        arrs = self._check_inputs(inputs)
        shape = arrs[0].shape
        if len(self.members) == 1:
            return arrs[0].copy()
        if arrs[0].nbytes <= self.cutover_bytes:
            return self._oracle_tree(arrs, root).reshape(shape)
        return self._oracle_ring(
            {m: arrs[i].reshape(-1) for i, m in enumerate(self.members)},
            hierarchical=False).reshape(shape)

    def oracle_reduce_scatter(self, inputs: Sequence[Any]
                              ) -> List[np.ndarray]:
        arrs = self._check_inputs(inputs)
        flats = {m: arrs[i].reshape(-1)
                 for i, m in enumerate(self.members)}
        if len(self.members) == 1:
            return [flats[self.members[0]].copy()]
        full = self._oracle_ring(flats, hierarchical=False)
        ring = self.ring_m
        pos = {m: i for i, m in enumerate(ring)}
        bounds = _segment_bounds(full.size, len(ring))
        return [full[bounds[pos[m]][0]:bounds[pos[m]][1]].copy()
                for m in self.members]

    def _oracle_tree(self, arrs: List[np.ndarray],
                     root: int) -> np.ndarray:
        order = self._tree(root)
        idx = {m: i for i, m in enumerate(self.members)}
        R = len(order)

        def subtree(p: int) -> np.ndarray:
            acc = arrs[idx[order[p]]]
            for c in _tree_children(p, R):
                acc = acc + subtree(c)
            return acc

        return subtree(0)

    def _oracle_ring(self, flats: Dict[int, np.ndarray],
                     hierarchical: bool) -> np.ndarray:
        if hierarchical:
            acc_by = {}
            for mems in self._node_members.values():
                acc = flats[mems[0]].copy()
                for m in mems[1:]:
                    acc = acc + flats[m]    # intra: base + incoming
                acc_by[mems[0]] = acc
            ring = self.ring
        else:
            acc_by = {m: flats[m] for m in flats}
            ring = self.ring_m
        R = len(ring)
        if R == 1:
            return acc_by[ring[0]]
        out = np.empty_like(acc_by[ring[0]])
        for g, (lo, hi) in enumerate(_segment_bounds(out.size, R)):
            acc = acc_by[ring[(g + 1) % R]][lo:hi]
            for k in range(2, R + 1):
                # ring hop: the RECEIVER's accumulator is the left
                # operand (slab + chunk), matching the fused reduce
                acc = acc_by[ring[(g + k) % R]][lo:hi] + acc
            out[lo:hi] = acc
        return out

    def describe(self) -> Dict[str, Any]:
        """Shape snapshot for reports/benchmarks."""
        return {"members": list(self.members),
                "leaders": list(self.leaders),
                "ring": list(self.ring),
                "member_ring": list(self.ring_m),
                "cutover_bytes": self.cutover_bytes,
                "tag_space": self.tag_space}
