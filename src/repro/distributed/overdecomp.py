"""Over-decomposition planner (paper §4.4).

Splits a d-dimensional domain into od × n_workers chunks so each worker owns
od chunks: while chunk i computes, chunk i+1's halos are in flight. Provides
the chunk geometry, neighbour topology, and the microbatch analogue for LM
training (global_batch → od microbatches).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Chunk:
    cid: int
    grid_pos: Tuple[int, ...]        # position in the chunk grid
    lo: Tuple[int, ...]              # inclusive start per dim
    hi: Tuple[int, ...]              # exclusive end per dim

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(h - l for l, h in zip(self.lo, self.hi, strict=True))


@dataclasses.dataclass(frozen=True)
class DecompPlan:
    domain: Tuple[int, ...]
    chunk_grid: Tuple[int, ...]
    chunks: Tuple[Chunk, ...]
    over_decomposition: int
    n_workers: int

    def neighbors(self, cid: int) -> Dict[str, Optional[int]]:
        """Face neighbours (±each dim) in the chunk grid, None at boundary."""
        c = self.chunks[cid]
        out: Dict[str, Optional[int]] = {}
        grid = np.array(self.chunk_grid)
        pos = np.array(c.grid_pos)
        strides = np.cumprod([1] + list(grid[::-1][:-1]))[::-1]
        for d in range(len(grid)):
            for sign, tag in ((-1, f"lo{d}"), (+1, f"hi{d}")):
                q = pos.copy()
                q[d] += sign
                if 0 <= q[d] < grid[d]:
                    out[tag] = int((q * strides).sum())
                else:
                    out[tag] = None
        return out

    def owner_of(self, cid: int) -> int:
        return min(cid * self.n_workers // len(self.chunks),
                   self.n_workers - 1)


def _factor_grid(n: int, ndim: int, domain: Sequence[int]) -> Tuple[int, ...]:
    """Near-cubic chunk grid with prod == n, biased to larger domain dims."""
    grid = [1] * ndim
    rem = n
    f = 2
    factors = []
    while rem > 1:
        while rem % f == 0:
            factors.append(f)
            rem //= f
        f += 1
    for p in sorted(factors, reverse=True):
        i = int(np.argmax([domain[d] / grid[d] for d in range(ndim)]))
        grid[i] *= p
    return tuple(grid)


def plan_decomposition(domain: Sequence[int], n_workers: int,
                       over_decomposition: int = 1) -> DecompPlan:
    ndim = len(domain)
    n_chunks = n_workers * over_decomposition
    grid = _factor_grid(n_chunks, ndim, domain)
    assert all(domain[d] % grid[d] == 0 for d in range(ndim)), \
        (domain, grid, "domain must divide the chunk grid")
    sizes = [domain[d] // grid[d] for d in range(ndim)]
    chunks = []
    for cid, pos in enumerate(itertools.product(*[range(g) for g in grid])):
        lo = tuple(pos[d] * sizes[d] for d in range(ndim))
        hi = tuple((pos[d] + 1) * sizes[d] for d in range(ndim))
        chunks.append(Chunk(cid, tuple(pos), lo, hi))
    return DecompPlan(tuple(domain), grid, tuple(chunks),
                      over_decomposition, n_workers)


def microbatch_plan(global_batch: int, over_decomposition: int) -> List[int]:
    """LM-training analogue: microbatch sizes per accumulation step."""
    assert global_batch % over_decomposition == 0
    return [global_batch // over_decomposition] * over_decomposition
