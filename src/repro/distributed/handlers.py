"""Handler registry — PREMA's remote method invocations (paper §1.1).

Handlers are named host functions invoked on the owner of a mobile object,
possibly on a remote rank. ``@handler`` registers by name so every rank
resolves the same code from message metadata (the moral equivalent of
DEFINE_MP_HANDLER in Fig. 5).
"""
from __future__ import annotations

from typing import Callable, Dict

_REGISTRY: Dict[str, Callable] = {}


def handler(fn: Callable = None, *, name: str = None):
    def wrap(f):
        key = name or f.__name__
        if key in _REGISTRY and _REGISTRY[key] is not f:
            raise ValueError(f"handler {key!r} already registered")
        _REGISTRY[key] = f
        f.handler_name = key
        return f
    if fn is not None:
        return wrap(fn)
    return wrap


def resolve(name: str) -> Callable:
    return _REGISTRY[name]


def registered() -> Dict[str, Callable]:
    return dict(_REGISTRY)
