"""Handler registry — PREMA's remote method invocations (paper §1.1).

Handlers are named host functions invoked on the owner of a mobile object,
possibly on a remote rank. ``@handler`` registers by name so every rank
resolves the same code from message metadata (the moral equivalent of
DEFINE_MP_HANDLER in Fig. 5).

A handler may declare a consumer **device-type affinity**
(``@handler(name=..., device_type="gpu")``): the receiving rank routes
incoming payloads for that handler onto a device of that type (least
loaded, per the residency ledger) instead of the global least-loaded
fallback — the coarse-grained half of consumer-routed delivery; the fine
half is the per-message ``consumer_device`` hint.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

_REGISTRY: Dict[str, Callable] = {}
_AFFINITY: Dict[str, str] = {}


def handler(fn: Callable = None, *, name: str = None,
            device_type: Optional[str] = None):
    def wrap(f):
        key = name or f.__name__
        if key in _REGISTRY and _REGISTRY[key] is not f:
            raise ValueError(f"handler {key!r} already registered")
        _REGISTRY[key] = f
        if device_type is not None:
            _AFFINITY[key] = device_type
        f.handler_name = key
        return f
    if fn is not None:
        return wrap(fn)
    return wrap


def resolve(name: str) -> Callable:
    return _REGISTRY[name]


def affinity(name: Optional[str]) -> Optional[str]:
    """Device type the named handler wants its payloads landed on."""
    return _AFFINITY.get(name) if name else None


def registered() -> Dict[str, Callable]:
    return dict(_REGISTRY)
