"""Message-driven distributed runtime (PREMA layer, paper §3.2).

Faithful reproduction of the messaging semantics on an in-process "cluster":
each rank runs a message-pump thread with its own heterogeneous tasking
Runtime, and inter-rank messages follow the paper's two-phase protocol —

  sender:   (1) async read-access request on the hetero_object
            (2) push {future, metadata} to the outgoing pending queue
            (3) pump polls the queue
            (4) when the future completes, send metadata msg + payload msg
            (5) release access
  receiver: (1) receive metadata  (2) prepare buffer  (3) receive payload
            (4) request device allocation  (5) run the user handler

Two payload paths are modeled, matching §3.2.3: HOST_STAGED (device→host →
network → host→device) and DIRECT (device→device; "GPU-aware interconnect").
The DIRECT path is real, not simulated: the sender snapshots the freshest
*device* copy via ``Runtime._request_device_view`` (jax arrays are immutable,
so no staging copy is needed), the payload travels as that device array, and
the receiver lands it with one Device API ``transfer`` onto its own device —
no host copy is materialized on either side. Per-path traffic is accounted
in ``Rank.stats`` (``bytes_d2d`` vs ``bytes_staged``).

Protocol split (paper §4.2.2–§4.2.3): payloads at or below
``RuntimeConfig.eager_threshold`` travel EAGERLY — one metadata message
plus one monolithic payload message, with ≤512B payloads inlined in the
metadata. Larger payloads (including oversized ``Rank.put`` bodies)
switch to a RENDEZVOUS protocol: the sender announces the message (RTS),
the receiver prepares a consumer-routed landing device and replies ready
(CTS) carrying an initial CREDIT WINDOW sized from the link's measured
bandwidth-delay product, and the sender streams the payload in chunks
sized from the same measurements (``Cluster.topology``, refined from
every delivery).

Progress is completion-driven, never blocking (paper §5–6: control
messages stay cheap while payloads stream). All sender-side streaming
runs on the rank's ``net-send`` progress-engine lane — the message pump
only parks payloads and forwards credits, so a large stream never
head-of-line blocks unrelated messages. The credit window keeps ≥2
chunks in flight per stream: each chunk the receiver finishes uploading
returns one credit, and the sender's lane advances the stream the moment
a credit arrives instead of waiting for the whole previous chunk's
round trip. Arriving chunks are handed straight to the landing device's
transfer lane (receive of chunk k+1 overlaps the upload of chunk k), and
stream completion — waiting out the tail uploads and invoking the
handler — runs on the rank's ``net-recv`` lane, off the pump.
Host-staged chunks travel through pooled staging buffers that return to
the sender's pool once the receiver's upload completes (the RDMA
buffer-recycle analogue). ``Rank.stats`` records ``eager``/``rendezvous``
message counts, ``chunks_out``/``chunks_in``, ``max_window`` (most
chunks ever in flight in one stream) and ``overlap_bytes`` — chunk
uploads that had fully completed before the last chunk arrived, i.e.
copies hidden entirely behind the network.

Flow control is ADAPTIVE and receiver-paced (unless ``net_window`` pins
it): every credit decision consults ``InterconnectModel.window_chunks``
as an AIMD controller fed with the receiver's live transfer-lane backlog
and landing-slab occupancy — both of which also travel back to the
sender in the credit message, alongside the receiver's cumulative
completed-upload count (``acked``) and the new window target. When the
receiver's lane backs up the controller halves the window (min 1) and
the receiver *withholds* credits (``credits_deferred``); when the lane
drains ahead of arrival it widens back toward the BDP ceiling and grants
the accumulated credits in one coalesced message (fewer control messages
than naive per-chunk crediting — which matters, because the simulated
control channel has a finite drain rate and bills credit chatter). The
sender honors shrink directly: ``_advance_stream`` holds chunks — even
with banked credits — while ``sent − acked`` is at or above the
receiver's latest window.

On a real TPU pod the network step lowers to ICI collectives
(see distributed/collectives.py); this layer is the host-side control plane
and the single-node multi-device execution engine.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import json
import os
import queue
import random
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core import HeteroObject, Runtime, RuntimeConfig
from repro.core import clock, sanitizer
from repro.core.device_api import transfer as d2d_transfer
from repro.core.futures import HFuture
from repro.core.hetero_object import HOST
from repro.core.integrity import digest_array
from repro.core.progress import ProgressEngine
from repro.core.topology import InterconnectModel
from repro.distributed import handlers as H

INLINE_PAYLOAD_BYTES = 512
# rendezvous chunk-size clamp: the bandwidth-delay product drives the
# size, but a degenerate estimate must not collapse to per-byte messages
# or a single unpipelined chunk
MIN_CHUNK_BYTES = 64 << 10
MAX_CHUNK_BYTES = 4 << 20
_msg_ids = itertools.count()
_FLUSH = object()            # pump wake-up sentinel (not a Message)

# message classes (shared by the simulated wire's virtual channels and
# the receive-side inbox ordering): control traffic never waits behind
# payloads, eager payloads never wait behind a streamed bulk window
PRIO_CONTROL = 0
PRIO_EAGER = 1
PRIO_BULK = 2
_CONTROL_KINDS = frozenset({"cts", "ack", "credit", "get", "nack"})
# bounded memory for the reliability layer's duplicate-suppression set
_SEEN_CAP = 2048


def msg_priority(msg: "Message", nbytes: int) -> int:
    # a metadata message with its payload inlined (≤ INLINE_PAYLOAD_BYTES)
    # is control-sized — it rides the control VC the way real fabrics
    # send sub-MTU inline messages (paper §4.2.3 small-message path)
    if nbytes == 0 or msg.inline is not None \
            or msg.kind in _CONTROL_KINDS:
        return PRIO_CONTROL
    return PRIO_BULK if msg.kind == "chunk" else PRIO_EAGER

_slab_updater_fn = None


def _slab_updater():
    """Jitted donated scatter: write a chunk into the landing slab at an
    element offset, reusing the slab's buffer (donation) so the per-chunk
    cost is chunk-sized, never slab-sized. One compilation per
    (slab, chunk) shape pair — chunk sizes are power-of-two quantized
    (InterconnectModel.chunk_bytes) precisely so this cache hits."""
    global _slab_updater_fn
    if _slab_updater_fn is None:
        import jax
        _slab_updater_fn = jax.jit(
            lambda slab, chunk, off:
            jax.lax.dynamic_update_slice(slab, chunk, (off,)),
            donate_argnums=0)
    return _slab_updater_fn


_slab_reducer_fn = None


def _slab_reducer():
    """Jitted donated fused reduce-scatter for op='reduce' rendezvous
    streams (runtime collectives): read the chunk-sized window of the
    accumulator slab at the element offset, add the incoming chunk, and
    write it back in place — the per-hop reduction the pipelined-ring
    collectives fuse onto the consumer device's transfer lane, so chunk
    k+1's network receive overlaps chunk k's add. Donation keeps the
    per-chunk cost chunk-sized, exactly like ``_slab_updater``."""
    global _slab_reducer_fn
    if _slab_reducer_fn is None:
        import jax
        _slab_reducer_fn = jax.jit(
            lambda slab, chunk, off:
            jax.lax.dynamic_update_slice(
                slab,
                jax.lax.dynamic_slice(slab, (off,), chunk.shape) + chunk,
                (off,)),
            donate_argnums=0)
    return _slab_reducer_fn


@dataclasses.dataclass
class Message:
    msg_id: int
    # 'meta' | 'payload' | 'cts' | 'chunk' | 'credit' | 'put' | 'get'
    # | 'ack'
    kind: str
    src: int
    dst: int
    handler: Optional[str] = None
    payload_shape: Optional[Tuple[int, ...]] = None
    payload_dtype: Optional[str] = None
    inline: Optional[bytes] = None
    payload: Optional[np.ndarray] = None     # "network" buffer
    object_key: Optional[Any] = None
    reply_to: Optional[int] = None
    user: Optional[Dict[str, Any]] = None
    path: str = "host"         # 'host' (staged) | 'direct'
    # receiver device the payload's consumer task will run on, when the
    # sender knows it (consumer-routed delivery, ROADMAP follow-up d)
    consumer_device: Optional[int] = None
    # -- rendezvous protocol fields --
    protocol: str = "eager"    # 'eager' | 'rdzv'
    op: str = "send"           # what a rendezvous stream completes into:
    #                            'send' (handler invocation) | 'put'
    #                            (overwrite the keyed target object) |
    #                            'reduce' (accumulate INTO the keyed
    #                            target: chunks add into the landing slab
    #                            instead of rebinding it — collectives)
    seq: Optional[int] = None  # chunk index within a rendezvous stream
    offset: Optional[int] = None   # chunk start, in elements
    nchunks: Optional[int] = None
    total_bytes: Optional[int] = None
    # credit-based flow control: the CTS carries the initial window (how
    # many chunks may be in flight); each 'credit' message returns one or
    # more (the receiver coalesces grants when it re-widens the window)
    credits: int = 0
    # -- adaptive flow-control feedback (receiver → sender) --
    # the receiver's current window target; the sender holds chunks while
    # sent − acked ≥ window even if it has banked credits (honors shrink)
    window: Optional[int] = None
    # cumulative chunk uploads the receiver has completed for this stream
    # (keeps the sender's in-flight accounting exact across deferrals)
    acked: int = 0
    # the receiver's transfer-lane backlog and landing-slab occupancy at
    # grant time — the congestion signals the controller fed on
    rx_queue: int = 0
    rx_slab_bytes: int = 0
    # -- reliability layer (engaged by Cluster.fault_injector) --
    # the receiver must acknowledge delivery; the sender retransmits with
    # backoff until the ack arrives or the retry budget is spent
    ack_req: bool = False
    # 'nack' only: chunk seqs the receiver is still missing mid-stream
    missing: Optional[Tuple[int, ...]] = None
    # -- end-to-end integrity --
    # content digest of the payload/inline/chunk bytes, computed once at
    # serialization (host-visible bytes only; DIRECT device arrays are
    # in-process immutable references and carry None). Verified on every
    # receive under cfg.verify_payloads: a mismatch is treated as
    # never-arrived and the reliability layer retransmits.
    digest: Optional[int] = None


class Rank:
    """One simulated process: message pump + local tasking runtime."""

    def __init__(self, cluster: "Cluster", rank: int,
                 rt_config: Optional[RuntimeConfig] = None):
        self.cluster = cluster
        self.rank = rank
        self.runtime = Runtime(rt_config or RuntimeConfig())
        # priority inbox (receive-side virtual channels): control
        # messages outrank eager payloads outrank bulk chunks, so a
        # small message is never stuck behind a streamed window that
        # already landed in the inbox; FIFO within a class
        self.inbox: "queue.PriorityQueue" = queue.PriorityQueue()
        self._inbox_seq = itertools.count()
        self.outgoing: List[Tuple[HFuture, Message, HeteroObject]] = []
        self._out_lock = sanitizer.make_lock("Rank._out_lock")
        self._pending_meta: Dict[int, Message] = {}
        # rendezvous bookkeeping: outgoing stream state (parked payload,
        # window credits, send cursor) per msg_id — mutated ONLY on the
        # net-send lane after the RTS — in-progress incoming reassembly
        # state per msg_id, and streamed pool buffers awaiting the
        # receiver's completion ack (keyed with the peer they are parked
        # for, so a peer-removal sweep can release exactly its buffers)
        self._rdzv_out: Dict[int, Dict[str, Any]] = {}
        self._rdzv_in: Dict[int, Dict[str, Any]] = {}
        self._rdzv_bufs: Dict[int, Tuple[int, np.ndarray]] = {}
        # -- reliability layer (off unless Cluster.fault_injector engaged
        # it): unacked reliable sends awaiting receiver acks, fully
        # transmitted rendezvous streams awaiting their completion ack
        # (kept resendable for NACK recovery), and the bounded
        # duplicate-suppression set of completed deliveries
        self._reliability = False
        self._unacked: Dict[int, Dict[str, Any]] = {}
        self._unacked_lock = sanitizer.make_lock("Rank._unacked_lock")
        self._rdzv_sent: Dict[int, Dict[str, Any]] = {}
        self._seen: Set[int] = set()
        self._seen_order: "collections.deque[int]" = collections.deque()
        # heartbeat emission (enable_heartbeat): monitor rank + cadence
        self._hb_dst: Optional[int] = None
        self._hb_every = 0.0
        self._hb_next = 0.0
        self._tick_next = 0.0
        # typed progress-engine lanes on the runtime's shared reactor:
        # net-send streams rendezvous chunks (the pump never transmits a
        # payload window itself), net-recv completes incoming streams
        # (tail-upload waits + handler invocation, off the pump)
        self._net_send = self.runtime.engine.lane("net-send", rank)
        self._net_recv = self.runtime.engine.lane("net-recv", rank)
        # >0 while any thread is mid-flush or mid-handler: work extracted
        # from the queues but not yet re-registered anywhere the barrier
        # can see (closes the idle-looking window between popping a
        # message/send and its effects landing). A COUNTER, not a flag:
        # eager sends flush inline on the caller thread, concurrently
        # with the pump's own flush/handle cycle.
        self._active = 0
        self._active_lock = sanitizer.make_lock("Rank._active_lock")
        self.objects: Dict[Any, HeteroObject] = {}   # global ptr -> object
        # handler name -> local device id: where this rank wants payloads
        # for that handler landed (consumer routing, set via route_to)
        self.routes: Dict[str, int] = {}
        self.stats = {"sent": 0, "received": 0, "bytes_out": 0,
                      "bytes_d2d": 0, "bytes_staged": 0,
                      # small host-path payloads upgraded to DIRECT
                      # because a device replica existed (ROADMAP 5a)
                      "direct_upgrades": 0,
                      "eager": 0, "rendezvous": 0,
                      "chunks_out": 0, "chunks_in": 0, "overlap_bytes": 0,
                      "credits_in": 0, "max_window": 0,
                      # adaptive flow control (receiver side): window
                      # retargets, credits withheld under backlog, the
                      # smallest window granted, and the deepest
                      # transfer-lane backlog seen at a credit decision
                      "window_adjusts": 0, "credits_deferred": 0,
                      "window_min": 0, "rx_queue_peak": 0,
                      # pump handler exceptions routed to the error sink
                      "handler_errors": 0,
                      # -- fault tolerance / elasticity --
                      # reliability-layer retransmissions, duplicates
                      # suppressed, sends abandoned after the retry
                      # budget, heartbeats emitted; the elastic layer
                      # fills in missed beats, chunks landed here by
                      # migration, and the cumulative recovery stall
                      "retries": 0, "dup_dropped": 0, "send_failures": 0,
                      "heartbeats_out": 0, "heartbeats_missed": 0,
                      "chunks_migrated": 0, "recovery_stall_s": 0.0,
                      # -- end-to-end integrity --
                      # payload/inline/chunk digest mismatches detected
                      # (each treated as never-arrived → retransmitted),
                      # and the subset that were rendezvous chunks
                      "checksum_fail": 0, "chunks_rejected": 0,
                      # -- runtime collectives (collectives_rt) --
                      # bytes folded into accumulators on this rank
                      # (eager adds + fused reduce-stream chunks), the
                      # deepest op='reduce' chunk pipeline observed, and
                      # collectives aborted here by an epoch bump
                      "coll_bytes_reduced": 0,
                      "coll_chunks_in_flight_peak": 0,
                      "coll_aborts": 0}
        # bounded trace of swallowed pump-handler errors (strict mode
        # re-raises the first at the next Cluster.barrier)
        self._errors: List[BaseException] = []
        self._stop = False
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name=f"prema-rank{rank}")
        self._thread.start()

    # ------------------------------------------------------------------
    # public API (paper: mp_send with hetero_object argument)
    # ------------------------------------------------------------------
    def _device_resident_small(self, obj: HeteroObject) -> bool:
        """ROADMAP 5a upgrade predicate: the payload is small enough for
        the eager path AND a device replica exists — or is about to, via
        a pending writer whose output lands on a device (``last_writer``
        is cleared on task completion, so non-None means in flight)."""
        if obj.nbytes > self.runtime.cfg.eager_threshold:
            return False
        if self.runtime.residency.devices_of(obj):
            return True
        return obj.last_writer is not None

    def send(self, dst: int, handler_name: str, obj: Optional[HeteroObject]
             = None, user: Optional[Dict[str, Any]] = None,
             path: str = "host",
             consumer_device: Optional[int] = None) -> HFuture:
        """One-sided async handler invocation with optional hetero_object
        payload. ``consumer_device`` names the receiver device the payload's
        consumer task will run on, when known — DIRECT payloads then land
        there with a single transfer. Returns a future completed when the
        message has been handed to the network (not when the handler ran)."""
        fut = HFuture()
        meta = Message(msg_id=next(_msg_ids), kind="meta", src=self.rank,
                       dst=dst, handler=handler_name, user=user, path=path,
                       consumer_device=consumer_device)
        if obj is None:
            if self._reliability:
                meta.ack_req = True
                self._track_unacked([meta])
            self.cluster.deliver(meta)
            self.stats["sent"] += 1
            fut.set_result(None)
            return fut
        meta.payload_shape = tuple(obj.shape)
        meta.payload_dtype = np.dtype(obj.dtype).str
        # ROADMAP 5a: small payloads with a live (or pending) device replica
        # skip the host bounce — upgrade to the DIRECT device-view path.
        # Stale residency is harmless: a HOST-only view at flush time
        # degrades the message back to host staging.
        if path == "host" and self._device_resident_small(obj):
            path = meta.path = "direct"
            self.stats["direct_upgrades"] += 1
        # (1) async access request; payload follows when ready. DIRECT sends
        # take a device view (no host staging, §3.2.3 Fig. 7); host-staged
        # sends pin a host copy as before (Fig. 6).
        if path == "direct":
            access = self.runtime._request_device_view(obj)
        else:
            access = obj.request_host(write=False)

        def on_ready(_):
            with self._out_lock:
                self.outgoing.append((access, meta, obj))
            # flush inline: when the payload is already available (the
            # common fast path) the message reaches the network on THIS
            # thread — no pump wake-up on the latency path. Safe from any
            # thread: extraction is serialized by _out_lock and in-flight
            # work is accounted by the _active counter.
            self._flush_outgoing()
            fut.set_result(None)

        access.add_done_callback(on_ready)
        return fut

    def put(self, dst: int, object_key: Any, data: HeteroObject,
            on_done: Optional[str] = None, path: str = "host",
            consumer_device: Optional[int] = None,
            user: Optional[Dict[str, Any]] = None) -> HFuture:
        """Remote put: overwrite the target's hetero_object (paper §4.2.4:
        reuses existing, pinned target memory — no receiver allocation).
        ``path='direct'`` ships the freshest device copy with no host
        staging on either side (consumer-routed: the payload lands on
        ``consumer_device``, else a device already holding the target).
        Payloads above the eager threshold chunk-stream through the same
        credit-windowed rendezvous path as large sends (ROADMAP follow-up
        b) — the stream completes into the target object instead of a
        handler allocation. ``user`` rides to the ``on_done`` handler's
        context (the collectives engine threads hop metadata through it)."""
        return self._put_like(dst, object_key, data, "put", on_done, path,
                              consumer_device, user)

    def reduce_into(self, dst: int, object_key: Any, data: HeteroObject,
                    on_done: Optional[str] = None, path: str = "host",
                    consumer_device: Optional[int] = None,
                    user: Optional[Dict[str, Any]] = None) -> HFuture:
        """Remote accumulate: add this rank's ``data`` INTO the target's
        keyed hetero_object instead of overwriting it — the collective
        stream variant of ``put`` (runtime collectives, ISSUE 9). Large
        payloads ride the same credit-windowed rendezvous path, but the
        receiver initializes the landing slab from the target's current
        value and every chunk is a fused chunk-sized add on the landing
        device's transfer lane (``_slab_reducer``), so chunk k+1's
        network receive overlaps chunk k's reduction; the finished slab
        rebinds as the target's only valid copy. Small payloads add on
        the receiver's host copy. The in-flight chunk window is capped by
        ``RuntimeConfig.coll_max_inflight_chunks`` on top of the AIMD
        controller. A ``reduce_into`` against an unregistered key is
        dropped on the receiver (aborted collective): the stream still
        completes and acks, nothing is mutated."""
        return self._put_like(dst, object_key, data, "reduce", on_done,
                              path, consumer_device, user)

    def _put_like(self, dst: int, object_key: Any, data: HeteroObject,
                  op: str, on_done: Optional[str], path: str,
                  consumer_device: Optional[int],
                  user: Optional[Dict[str, Any]]) -> HFuture:
        fut = HFuture()
        if path == "host" and self._device_resident_small(data):
            path = "direct"          # ROADMAP 5a, same upgrade as send()
            self.stats["direct_upgrades"] += 1
        if path == "direct":
            access = self.runtime._request_device_view(data)
        else:
            access = data.request_host(write=False)

        def on_ready(_):
            used_path = path
            pooled = False
            thr = self.runtime.cfg.eager_threshold
            if path == "direct":
                space, arr = access.get()
                if space == HOST:          # no device copy: degrade
                    used_path = "host"
            else:
                src = np.asarray(access.get())
                if src.nbytes > thr and self.runtime.staging.enabled:
                    arr = self.runtime.staging.acquire(src.shape, src.dtype)
                    np.copyto(arr, src)
                    pooled = True
                else:
                    arr = np.array(src)
                data.release()
            key = "bytes_d2d" if used_path == "direct" else "bytes_staged"
            self.stats[key] += arr.nbytes
            if arr.nbytes > thr:
                meta = Message(msg_id=next(_msg_ids), kind="meta",
                               src=self.rank, dst=dst, op=op,
                               object_key=object_key, handler=on_done,
                               path=used_path, user=user,
                               consumer_device=consumer_device,
                               payload_shape=tuple(arr.shape),
                               payload_dtype=np.dtype(arr.dtype).str)
                self._start_rendezvous(meta, arr, arr.nbytes, pooled)
                fut.set_result(None)
                return
            msg = Message(msg_id=next(_msg_ids), kind="put", src=self.rank,
                          dst=dst, op=op, object_key=object_key,
                          payload=arr, handler=on_done, path=used_path,
                          user=user, consumer_device=consumer_device,
                          digest=self._digest_for(arr))
            if self._reliability:
                msg.ack_req = True
                self._track_unacked([msg])
            self.cluster.deliver(msg)
            self.stats["sent"] += 1
            self.stats["bytes_out"] += arr.nbytes
            fut.set_result(None)

        access.add_done_callback(on_ready)
        return fut

    def get(self, dst: int, object_key: Any, handler_name: str,
            path: str = "host",
            consumer_device: Optional[int] = None) -> HFuture:
        """Remote get: ask ``dst`` for object data; handler runs locally
        with the received hetero_object. ``path``/``consumer_device``
        shape the REPLY: a direct reply travels device-to-device and
        lands consumer-routed on this rank (large replies chunk-stream
        through the rendezvous protocol like any other send)."""
        fut = HFuture()
        msg = Message(msg_id=next(_msg_ids), kind="get", src=self.rank,
                      dst=dst, object_key=object_key, handler=handler_name,
                      path=path, consumer_device=consumer_device)
        self.cluster.deliver(msg)
        self.stats["sent"] += 1
        fut.set_result(None)
        return fut

    def register_object(self, key: Any, obj: HeteroObject) -> None:
        self.objects[key] = obj

    def route_to(self, handler_name: str, device_id: int) -> None:
        """Declare that payloads for ``handler_name`` will be consumed by
        tasks on local ``device_id`` — incoming DIRECT payloads land there
        directly instead of on the least-loaded fallback."""
        self.routes[handler_name] = device_id

    def enable_heartbeat(self, monitor: int,
                         interval_s: Optional[float] = None) -> None:
        """Emit a 0-byte ``elastic_heartbeat`` control message to rank
        ``monitor`` every ``interval_s`` (default
        ``RuntimeConfig.heartbeat_interval_s``) from the pump loop. The
        heartbeat rides the billed control VC like any other control
        message — liveness signalling is not free on a congested link,
        which is exactly why the elastic layer also reads latency/backlog
        telemetry instead of trusting heartbeat timing alone."""
        self._hb_every = interval_s if interval_s is not None \
            else self.runtime.cfg.heartbeat_interval_s
        self._hb_dst = monitor
        self._hb_next = 0.0

    # -- end-to-end integrity (content digests at every boundary) ------
    def _digest_for(self, data: Any) -> Optional[int]:
        """Sender-side content digest, computed ONCE at serialization for
        host-visible bytes (np payloads, inline bytes, chunk views).
        DIRECT device-array payloads carry None: they cross the
        in-process 'wire' as immutable jax references — there are no wire
        bytes to flip, and hashing them would force a device→host
        readback on the zero-copy path."""
        if not self.runtime.cfg.verify_payloads:
            return None
        if isinstance(data, (np.ndarray, bytes, bytearray, memoryview)):
            return digest_array(data)
        return None

    def _verify(self, msg: Message, data: Any) -> bool:
        """Receiver-side digest check. False means the bytes are to be
        treated as NEVER-ARRIVED — the caller drops them without acking
        or recording progress, and the reliability layer's retransmission
        (or the stalled-stream NACK) brings the clean bytes back, so
        corruption surfaces as a retry, never a hang or a wrong answer."""
        if msg.digest is None or not self.runtime.cfg.verify_payloads:
            return True
        if digest_array(data) == msg.digest:
            return True
        self.stats["checksum_fail"] += 1
        return False

    # -- reliability layer (retry / ack / nack; fault-injection mode) ---
    def _track_unacked(self, msgs: List[Message]) -> None:
        """Register a reliable send: ``msgs`` (a meta and its optional
        payload half) are retransmitted together on a backoff schedule
        until the receiver's delivery ack clears them."""
        m0 = msgs[0]
        with self._unacked_lock:
            self._unacked[m0.msg_id] = {
                "msgs": list(msgs), "dst": m0.dst, "attempts": 0,
                "deadline": time.perf_counter()
                + self.runtime.cfg.retry_backoff_s}

    def _ack_unacked(self, msg_id: int) -> None:
        with self._unacked_lock:
            self._unacked.pop(msg_id, None)

    def _mark_done(self, msg: Message, ack: bool = True) -> None:
        """Delivery completed under the reliability layer: remember the
        msg_id (bounded) so a straggling retransmission is suppressed as
        a duplicate, and ack the sender when it asked."""
        if not self._reliability:
            return
        if msg.msg_id not in self._seen:
            self._seen.add(msg.msg_id)
            self._seen_order.append(msg.msg_id)
            while len(self._seen_order) > _SEEN_CAP:
                self._seen.discard(self._seen_order.popleft())
        if ack and msg.ack_req:
            self.cluster.deliver(Message(msg_id=msg.msg_id, kind="ack",
                                         src=self.rank, dst=msg.src))

    def _tick(self) -> None:
        """Pump-loop housekeeping (throttled to ``retry_tick_s``): emit
        the periodic heartbeat, retransmit overdue unacked sends and
        rendezvous tails, and NACK incoming streams that stalled."""
        now = time.perf_counter()
        if now < self._tick_next:
            return
        self._tick_next = now + self.runtime.cfg.retry_tick_s
        if self._hb_dst is not None and now >= self._hb_next:
            self._hb_next = now + self._hb_every
            self.stats["heartbeats_out"] += 1
            self.cluster.deliver(Message(
                msg_id=next(_msg_ids), kind="meta", src=self.rank,
                dst=self._hb_dst, handler="elastic_heartbeat",
                user={"worker": self.rank}))
        if self._reliability:
            self._retry_unacked(now)
            self._retry_tails(now)
            self._nack_stalled_streams(now)

    def _retry_unacked(self, now: float) -> None:
        """Retransmit reliable sends whose ack is overdue, with
        exponential backoff; a send that exhausts ``send_retries`` is
        abandoned and counted in ``send_failures`` (the elastic layer —
        not the transport — decides what a persistent failure means)."""
        cfg = self.runtime.cfg
        with self._unacked_lock:
            items = list(self._unacked.items())
        gone = []
        for mid, st in items:
            if now < st["deadline"]:
                continue
            st["attempts"] += 1
            if st["attempts"] > cfg.send_retries:
                gone.append(mid)
                self.stats["send_failures"] += 1
                continue
            st["deadline"] = now + cfg.retry_backoff_s \
                * (cfg.retry_backoff_mult ** st["attempts"])
            self.stats["retries"] += 1
            for m in st["msgs"]:
                self.cluster.deliver(m)
        if gone:
            with self._unacked_lock:
                for mid in gone:
                    self._unacked.pop(mid, None)

    def _retry_tails(self, now: float) -> None:
        """A fully transmitted rendezvous stream whose completion ack is
        overdue gets its LAST chunk resent: if the tail chunk was lost
        the receiver can now finish; if only the ack was lost the
        receiver re-acks the orphan chunk (``_receive_chunk``), releasing
        the parked pool buffer either way."""
        cfg = self.runtime.cfg
        for mid, st in list(self._rdzv_sent.items()):
            if now < st["deadline"]:
                continue
            st["attempts"] += 1
            if st["attempts"] > cfg.send_retries:
                self._rdzv_sent.pop(mid, None)
                parked = self._rdzv_bufs.pop(mid, None)
                if parked is not None:
                    self.runtime.staging.release(parked[1])
                self.stats["send_failures"] += 1
                continue
            st["deadline"] = now + cfg.retry_backoff_s \
                * (cfg.retry_backoff_mult ** st["attempts"])
            meta, flat, elems = st["meta"], st["flat"], st["elems"]
            k = meta.nchunks - 1
            self.stats["retries"] += 1
            piece = flat[k * elems:(k + 1) * elems]
            self.cluster.deliver(Message(
                msg_id=mid, kind="chunk", src=self.rank, dst=meta.dst,
                seq=k, offset=k * elems, nchunks=meta.nchunks,
                payload=piece, path=meta.path,
                digest=self._digest_for(piece)))

    def _nack_stalled_streams(self, now: float) -> None:
        """Receiver-side loss recovery: an incomplete incoming stream
        that made no progress for a backoff interval gets a NACK naming
        the missing chunk seqs (capped) — the sender resends exactly
        those. A stream that stays dry past the retry budget is swept
        (the peer-loss path will also reap it)."""
        cfg = self.runtime.cfg
        for mid, st in list(self._rdzv_in.items()):
            meta = st["meta"]
            if st["arrived"] >= meta.nchunks:
                continue
            nacks = st.get("nacks", 0)
            backoff = cfg.retry_backoff_s * (cfg.retry_backoff_mult ** nacks)
            if now - st.get("last_progress", now) < backoff:
                continue
            st["nacks"] = nacks + 1
            st["last_progress"] = now
            if st["nacks"] > cfg.send_retries:
                self._rdzv_in.pop(mid, None)
                self.stats["send_failures"] += 1
                continue
            have = st["uploads"]
            missing = tuple(k for k in range(meta.nchunks)
                            if k not in have)[:64]
            if not missing:
                continue
            self.cluster.deliver(Message(
                msg_id=mid, kind="nack", src=self.rank, dst=meta.src,
                credits=len(missing), window=st["win"],
                acked=st["completed"], missing=missing))

    def _handle_nack(self, msg: Message) -> None:
        """Net-send lane only: the receiver is missing chunks. Already
        transmitted seqs are resent from the parked payload (live stream
        or awaiting-ack tail); never transmitted seqs mean the credits
        were lost — fold the NACK in as a credit grant so the stream
        moves again."""
        st = self._rdzv_out.get(msg.msg_id)
        flat = elems = meta = None
        if st is not None:
            meta, flat, elems = st["meta"], st["flat"], st["elems"]
            cutoff = st["next_seq"]
        else:
            sent = self._rdzv_sent.get(msg.msg_id)
            if sent is None:
                return
            meta, flat, elems = sent["meta"], sent["flat"], sent["elems"]
            cutoff = meta.nchunks
        fresh = 0
        for k in (msg.missing or ()):
            if k >= cutoff:
                fresh += 1
                continue
            self.stats["retries"] += 1
            self.stats["chunks_out"] += 1
            piece = flat[k * elems:(k + 1) * elems]
            self.cluster.deliver(Message(
                msg_id=msg.msg_id, kind="chunk", src=self.rank,
                dst=meta.dst, seq=k, offset=k * elems,
                nchunks=meta.nchunks, payload=piece, path=meta.path,
                digest=self._digest_for(piece)))
        if fresh and st is not None:
            self._advance_stream(msg.msg_id, fresh, window=msg.window,
                                 acked=msg.acked)

    def enqueue(self, item: Any, priority: int = PRIO_CONTROL) -> None:
        """Post a message (or pump sentinel) to this rank's inbox at the
        given virtual-channel priority; FIFO within a priority class."""
        self.inbox.put((priority, next(self._inbox_seq), item))

    def dispatch_control(self, msg: Message) -> bool:
        """Network-layer fast dispatch: stream-advance control messages
        (CTS, credits) post their job straight onto the net-send lane
        that consumes them, skipping the pump hop entirely — one fewer
        thread wake in the per-chunk credit loop, which is the loop's
        critical path. Returns True when the message was consumed."""
        if msg.kind == "cts" or msg.kind == "credit":
            if msg.kind == "cts":
                self._ack_unacked(msg.msg_id)   # RTS confirmed received
            if self._stop:
                return True        # rank leaving: drop stream advances
            try:
                self._net_send.submit(
                    lambda mid=msg.msg_id, c=msg.credits, w=msg.window,
                    a=msg.acked, init=(msg.kind == "cts"):
                    self._advance_stream(mid, c, window=w, acked=a,
                                         initial=init))
            except RuntimeError:   # lane stopped mid-shutdown: drop
                pass
            return True
        if msg.kind == "nack":
            if self._stop:
                return True
            try:
                self._net_send.submit(lambda m=msg: self._handle_nack(m))
            except RuntimeError:
                pass
            return True
        return False

    # ------------------------------------------------------------------
    # pump
    # ------------------------------------------------------------------
    def _busy_enter(self) -> None:
        with self._active_lock:
            self._active += 1

    def _busy_exit(self) -> None:
        with self._active_lock:
            self._active -= 1

    def _flush_outgoing(self):
        ready = []
        with self._out_lock:
            still = []
            for access, meta, obj in self.outgoing:
                if access.done():
                    if not ready:
                        self._busy_enter()   # visible before outgoing shrinks
                    ready.append((access, meta, obj))
                else:
                    still.append((access, meta, obj))
            self.outgoing = still
        if not ready:
            return
        try:
            self._flush_ready(ready)
        finally:
            self._busy_exit()

    def _flush_ready(self, ready) -> None:
        for access, meta, obj in ready:
            pooled = False
            if meta.path == "direct":
                # device-aware interconnect (§3.2.3 Fig. 7): the NIC reads
                # device memory directly — the payload stays a device array
                space, arr = access.get()   # arr: private on-device clone
                if space == HOST:
                    # no device copy existed; fall back to the staged path
                    # (arr is already a private host copy)
                    meta.path = "host"
            else:
                # host-staged (§3.2.3 Fig. 6): ONE staging copy. A payload
                # bound for the rendezvous protocol stages into a pooled
                # buffer — chunks are zero-copy windows into it (the NIC
                # reads the pinned buffer directly), and the buffer
                # returns to the pool on the receiver's completion ack
                src = np.asarray(access.get())
                rdzv = src.nbytes > self.runtime.cfg.eager_threshold
                if rdzv and self.runtime.staging.enabled:
                    arr = self.runtime.staging.acquire(src.shape, src.dtype)
                    np.copyto(arr, src)
                    pooled = True
                else:
                    arr = np.array(src)
                obj.release()
            nbytes = arr.nbytes
            if meta.path == "direct":
                self.stats["bytes_d2d"] += nbytes
            else:
                self.stats["bytes_staged"] += nbytes
            if nbytes > self.runtime.cfg.eager_threshold:
                self._start_rendezvous(meta, arr, nbytes, pooled)
                continue
            self.stats["eager"] += 1
            if self._reliability:
                meta.ack_req = True
            if meta.path != "direct" and nbytes <= INLINE_PAYLOAD_BYTES:
                meta.inline = np.asarray(arr).tobytes()  # §4.2.3 small msgs
                meta.digest = self._digest_for(meta.inline)
                if self._reliability:
                    self._track_unacked([meta])
                self.cluster.deliver(meta)
            else:
                payload = Message(msg_id=meta.msg_id, kind="payload",
                                  src=self.rank, dst=meta.dst, payload=arr,
                                  path=meta.path,
                                  digest=self._digest_for(arr))
                if self._reliability:
                    # meta+payload retransmit as a unit: whichever half
                    # was dropped, the receiver's pairing logic re-pairs
                    # and the duplicate half is suppressed
                    self._track_unacked([meta, payload])
                self.cluster.deliver(meta)
                self.cluster.deliver(payload)
            self.stats["sent"] += 1
            self.stats["bytes_out"] += nbytes

    # -- rendezvous protocol (sender side) -----------------------------
    def _start_rendezvous(self, meta: Message, arr: Any, nbytes: int,
                          pooled: bool = False) -> None:
        """RTS: announce the message, park the payload until the receiver
        signals CTS. Chunk size comes from the measured bandwidth-delay
        product of this rank pair (``Cluster.topology``). ``pooled`` marks
        a host payload staged in a StagingPool buffer — it is recycled
        when the receiver acks stream completion. All later stream state
        mutation happens on the net-send lane (CTS and credit arrivals
        are forwarded there), so no lock guards it."""
        chunk_b = self.runtime.cfg.chunk_bytes
        if chunk_b is None:
            target_s = self.runtime.cfg.chunk_target_ms / 1e3
            chunk_b = self.cluster.topology.chunk_bytes(
                self.rank, meta.dst, target_s,
                lo=MIN_CHUNK_BYTES, hi=MAX_CHUNK_BYTES)
        itemsize = np.dtype(meta.payload_dtype).itemsize
        elems = max(chunk_b // itemsize, 1)
        total_elems = nbytes // itemsize
        meta.protocol = "rdzv"
        meta.nchunks = max((total_elems + elems - 1) // elems, 1)
        meta.total_bytes = nbytes
        self._rdzv_out[meta.msg_id] = {
            "meta": meta, "flat": arr.reshape(-1), "arr": arr,
            "elems": elems, "pooled": pooled,
            "next_seq": 0,     # chunks handed to the network so far
            "credits": 0,      # window slots currently available
            "window": None,    # receiver's latest window target
            "acked": 0,        # receiver-reported completed uploads
        }
        self.stats["rendezvous"] += 1
        self.stats["sent"] += 1
        if self._reliability:
            # the RTS retransmits until the CTS clears it: a dropped
            # announcement (or a dropped CTS — the receiver re-CTSes a
            # duplicate RTS for a chunkless stream) cannot hang the send
            self._track_unacked([meta])
        self.cluster.deliver(meta)

    def _advance_stream(self, msg_id: int, credits: int,
                        window: Optional[int] = None, acked: int = 0,
                        initial: bool = False) -> None:
        """Net-send lane only. Fold ``credits`` into the stream's window
        and transmit every chunk the window now covers — the sender
        advances on per-chunk CTS credits, never on completion of the
        whole previous chunk, so ≥2 chunks stay in flight and the pump
        thread never transmits a payload window itself. The initial CTS
        grant opens the window.

        Adaptive shrink is honored here: each credit carries the
        receiver's latest window target and its cumulative completed
        uploads (``acked``), so the sender holds chunks — even with
        banked credits — while ``sent − acked`` is at or above the
        target. ``acked`` (not the credit count) keeps the in-flight
        accounting exact when the receiver defers credits under
        backlog."""
        state = self._rdzv_out.get(msg_id)
        if state is None:      # stream already fully handed to the network
            return
        state["credits"] += credits
        # VCs can reorder: each credit's acked is strictly newer than the
        # last (one per completed upload), so both acked and the window
        # target are accepted only from messages that ADVANCE the
        # completion count — a stale reordered grant must not re-widen a
        # window the receiver has since shrunk
        newer = acked > state["acked"]
        if newer:
            state["acked"] = acked
        if window and (initial or newer or state["window"] is None):
            state["window"] = window
        if not initial and credits:
            self.stats["credits_in"] += credits
        meta, flat, elems = state["meta"], state["flat"], state["elems"]
        while state["credits"] > 0 and state["next_seq"] < meta.nchunks:
            in_flight = state["next_seq"] - state["acked"]
            if state["window"] is not None \
                    and in_flight >= state["window"]:
                break          # receiver shrank the window: hold the rest
            k = state["next_seq"]
            piece = flat[k * elems:(k + 1) * elems]
            chunk = Message(msg_id=msg_id, kind="chunk", src=self.rank,
                            dst=meta.dst, seq=k, offset=k * elems,
                            nchunks=meta.nchunks, payload=piece,
                            path=meta.path,
                            digest=self._digest_for(piece))
            state["credits"] -= 1
            state["next_seq"] = k + 1
            self.stats["chunks_out"] += 1
            self.stats["bytes_out"] += piece.nbytes
            if in_flight + 1 > self.stats["max_window"]:
                self.stats["max_window"] = in_flight + 1
            self.cluster.deliver(chunk)
        if state["next_seq"] >= meta.nchunks:
            # stream fully transmitted: drop the send state; a pooled
            # staging buffer stays parked until the completion ack
            if state["pooled"]:
                self._rdzv_bufs[msg_id] = (meta.dst, state["arr"])
            if self._reliability:
                # keep the payload resendable until the completion ack:
                # a lost tail chunk (or a NACK) replays from here
                self._rdzv_sent[msg_id] = {
                    "meta": meta, "flat": flat, "elems": elems,
                    "dst": meta.dst, "attempts": 0,
                    "deadline": time.perf_counter()
                    + self.runtime.cfg.retry_backoff_s}
            del self._rdzv_out[msg_id]

    # -- rendezvous protocol (receiver side) ---------------------------
    def _transfer_backlog(self, dev: int) -> int:
        """Live queue depth of ``dev``'s transfer lane (jobs waiting
        behind the in-service one) — the drain-rate signal the adaptive
        credit controller feeds on."""
        if not self.runtime.cfg.transfer_thread:
            return 0
        ln = self.runtime.engine.peek("transfer", dev)
        return ln.backlog() if ln is not None else 0

    def _slab_bytes(self, exclude_mid: Optional[int] = None) -> int:
        """Landing-slab occupancy: bytes committed to OTHER in-progress
        incoming streams (the receiver-side memory concurrent windows
        are competing for). The deciding stream excludes itself — its
        slab is fully allocated at RTS no matter what the window does,
        so counting it would make any single stream larger than the slab
        limit collapse its own window to 1 for its whole lifetime."""
        return sum(st["meta"].total_bytes or 0
                   for mid, st in list(self._rdzv_in.items())
                   if mid != exclude_mid)

    def _prepare_rendezvous(self, meta: Message) -> None:
        """RTS received: pick the consumer-routed landing device, start
        allocating the flat landing slab ON that device (the allocation
        overlaps the CTS round-trip and the first chunk's network time),
        and signal CTS carrying the initial credit window — enough chunks
        in flight to cover the link's measured bandwidth-delay product
        (≥2, so the sender can always overlap chunk k+1's transmit with
        chunk k's upload here). With ``net_window=None`` the window is
        ADAPTIVE: the controller starts from the BDP but already folds in
        this rank's live transfer-lane backlog and slab occupancy, and
        every subsequent credit decision re-targets it mid-stream."""
        prior = self._rdzv_in.get(meta.msg_id)
        if prior is not None:       # retransmitted / duplicated RTS
            self.stats["dup_dropped"] += 1
            if prior["arrived"] == 0 and prior.get("cts") is not None:
                # no chunk ever arrived: the original CTS was likely
                # lost — resend it (double-granting is safe: the
                # sender's window-hold caps in-flight regardless)
                self.cluster.deliver(prior["cts"])
            return
        dev = self._landing_device(meta)
        rt = self.runtime
        chunk_b = max(meta.total_bytes // max(meta.nchunks, 1), 1)
        window = rt.cfg.net_window
        adaptive = window is None
        rx_queue, slab_bytes = 0, 0
        if adaptive:
            rx_queue = self._transfer_backlog(dev)
            slab_bytes = self._slab_bytes()
            window = self.cluster.topology.window_chunks(
                meta.src, self.rank, chunk_b,
                queue_depth=rx_queue, slab_bytes=slab_bytes)
        if meta.op == "reduce" and rt.cfg.coll_max_inflight_chunks:
            # every in-flight reduce chunk is a pending fused add on the
            # landing device's transfer lane: cap the pipeline depth so
            # accumulator-side device work stays bounded (satellite knob)
            window = min(window, rt.cfg.coll_max_inflight_chunks)
        window = max(1, min(window, meta.nchunks))
        state = {
            "meta": meta,
            "dev": dev,
            "uploads": {},           # seq -> (chunk-landed future, nbytes)
            "arrived": 0,
            "slab": None,            # device slab, chained through chunks
            # op='reduce' only: async device view of the target object —
            # the accumulator base the first chunk's lane job turns into
            # the landing slab (requested HERE, resolved off-lane, so the
            # transfer lane never deadlocks requesting it against itself)
            "reduce": meta.op == "reduce",
            "base_fut": None,
            # -- adaptive flow-control state --
            "adaptive": adaptive,
            "chunk_b": chunk_b,
            "win": window,           # current window target
            "outstanding": window,   # chunks granted but not yet uploaded
            "completed": 0,          # cumulative uploads retired (acked)
            # -- reliability layer --
            "cts": None,             # kept resendable for duplicate RTS
            "last_progress": time.perf_counter(),
            "nacks": 0,
        }
        device = rt._device(dev)
        if meta.nchunks > 1 and getattr(device, "jax_device", None) \
                is not None:
            total = meta.total_bytes // np.dtype(meta.payload_dtype).itemsize
            if state["reduce"]:
                # reduce stream: the slab must START as the target's
                # current value (the accumulator), not zeros. Request the
                # view now so it resolves while the CTS round-trips; the
                # first chunk's lane job materializes it on-device. A
                # missing target (collective aborted before the stream
                # opened) leaves base_fut None: chunks fall back to the
                # parts path and the finish drops the result harmlessly.
                target = self.objects.get(meta.object_key)
                if target is not None:
                    state["base_fut"] = rt._request_device_view(target)
            else:
                def init(device=device, total=total,
                         dtype=meta.payload_dtype):
                    import jax
                    import jax.numpy as jnp
                    with jax.default_device(device.jax_device):
                        state["slab"] = jnp.zeros(total,
                                                  dtype=np.dtype(dtype))
                # FIFO transfer lane: the init lands before any chunk
                # update
                rt._async_transfer(dev, init)
        self._rdzv_in[meta.msg_id] = state
        if window < self.stats["window_min"] or not self.stats["window_min"]:
            self.stats["window_min"] = window
        cts = Message(msg_id=meta.msg_id, kind="cts",
                      src=self.rank, dst=meta.src,
                      credits=window, window=window,
                      rx_queue=rx_queue, rx_slab_bytes=slab_bytes)
        state["cts"] = cts
        self.cluster.deliver(cts)

    def _return_credit(self, msg_id: int, dst: int,
                       state: Dict[str, Any]) -> None:
        """Transfer-lane completion callback: one chunk's device copy
        retired. A pinned window returns one credit per completion, as
        before. The adaptive path re-targets the window HERE — mid-stream
        — with the lane's live backlog and slab occupancy: under backlog
        it withholds the credit entirely (``credits_deferred``; the
        sender's window shrinks by attrition, min 1 because a grant
        always fires when nothing is outstanding), and when the lane has
        drained it grants the deficit in one coalesced credit carrying
        the new window, the cumulative ``acked`` count, and the raw
        congestion signals."""
        state["completed"] += 1
        state["outstanding"] -= 1
        meta = state["meta"]
        if state["arrived"] >= meta.nchunks:
            return     # stream fully arrived: no credits left to spend
        q = self._transfer_backlog(state["dev"])
        if q > self.stats["rx_queue_peak"]:
            self.stats["rx_queue_peak"] = q
        if not state["adaptive"]:
            self.cluster.deliver(Message(
                msg_id=msg_id, kind="credit", src=self.rank, dst=dst,
                credits=1, window=state["win"],
                acked=state["completed"], rx_queue=q))
            return
        slab = self._slab_bytes(exclude_mid=msg_id)
        target = self.cluster.topology.window_chunks(
            meta.src, self.rank, state["chunk_b"],
            queue_depth=q, slab_bytes=slab)
        cap = self.runtime.cfg.coll_max_inflight_chunks
        if state["reduce"] and cap:
            target = min(target, cap)   # reduce pipeline stays bounded
        target = max(target, 1)
        if target != state["win"]:
            self.stats["window_adjusts"] += 1
            state["win"] = target
            if target < self.stats["window_min"] \
                    or not self.stats["window_min"]:
                self.stats["window_min"] = target
        grant = target - state["outstanding"]
        if grant <= 0:
            self.stats["credits_deferred"] += 1
            return
        state["outstanding"] += grant
        self.cluster.deliver(Message(
            msg_id=msg_id, kind="credit", src=self.rank, dst=dst,
            credits=grant, window=target, acked=state["completed"],
            rx_queue=q, rx_slab_bytes=slab))

    def _receive_chunk(self, msg: Message) -> None:
        """One chunk arrived (possibly out of order): hand it straight to
        the landing device's transfer lane and return to the pump — the
        next chunk's network receive overlaps this chunk's device copy.
        Each chunk is scattered into the preallocated slab with a DONATED
        dynamic_update_slice, so the per-chunk device cost is chunk-sized
        (an un-donated assembly would copy the whole slab per chunk, and
        a concatenate at the end would re-copy the whole payload). When
        the upload completes, the flow-control credit decision runs
        (``_return_credit``) — the completion event that slides the
        sender's window forward, or deliberately lets it shrink."""
        state = self._rdzv_in.get(msg.msg_id)
        if state is None:
            if self._reliability and msg.msg_id in self._seen:
                # resent tail of a stream that already completed: the
                # completion ack was lost — re-ack so the sender releases
                # its parked buffer and retires the tail timer
                self.cluster.deliver(Message(msg_id=msg.msg_id, kind="ack",
                                             src=self.rank, dst=msg.src))
            return   # stream swept (peer removed) — drop the orphan chunk
        if msg.seq in state["uploads"]:
            self.stats["dup_dropped"] += 1   # duplicated/replayed chunk
            return
        if not self._verify(msg, msg.payload):
            # corrupted chunk = never arrived: no progress stamp, no
            # upload entry — the stalled-stream NACK re-requests exactly
            # this seq and the sender replays it from the parked payload
            self.stats["chunks_rejected"] += 1
            return
        state["last_progress"] = time.perf_counter()
        rt, dev = self.runtime, state["dev"]
        payload, offset = msg.payload, msg.offset
        direct = msg.path == "direct" and not isinstance(payload, np.ndarray)
        key = "bytes_d2d" if direct else "bytes_staged"
        self.stats[key] += payload.nbytes

        def fn():
            if state["slab"] is None and state["base_fut"] is not None:
                # first reduce chunk: turn the target's device view into
                # the accumulator slab, on the landing device. The future
                # resolves off-lane (task-completion callbacks), so this
                # wait cannot deadlock the transfer lane against itself.
                import jax
                base_fut = state["base_fut"]
                state["base_fut"] = None
                space, base = base_fut.get(
                    timeout=rt.cfg.rdzv_finish_timeout_s)
                rt.futures.release(base_fut)
                if space == HOST:
                    base = np.asarray(base)
                jdev = rt._device(dev).jax_device
                state["slab"] = jax.device_put(
                    base, jdev).reshape(-1).block_until_ready()
            if state["slab"] is not None:
                # scatter straight into the slab: the jitted update
                # consumes the (host-view or device) chunk synchronously,
                # so no alias into the sender's pooled buffer survives.
                # op='reduce' fuses the add here, on the transfer lane —
                # the per-hop reduction the ring collectives pipeline.
                src = payload if direct else np.asarray(payload)
                if state["reduce"]:
                    slab = _slab_reducer()(state["slab"], src, offset)
                    self.stats["coll_bytes_reduced"] += payload.nbytes
                else:
                    slab = _slab_updater()(state["slab"], src, offset)
                slab.block_until_ready()
                state["slab"] = slab
                return None
            if direct:
                return self._land_direct(payload, dev)
            # single-chunk / non-jax landing: the Device API upload's
            # aliasing guard gives us a private device copy of the view
            local = rt._device(dev).upload(np.asarray(payload))
            if hasattr(local, "block_until_ready"):
                local.block_until_ready()
            return local
        fut = rt._async_transfer(dev, fn)
        state["uploads"][msg.seq] = (fut, payload.nbytes)
        state["arrived"] += 1
        self.stats["chunks_in"] += 1
        if state["reduce"]:
            # pipeline-depth telemetry: reduce chunks arrived but not yet
            # folded into the accumulator (the overlap the cap bounds)
            inflight = state["arrived"] - state["completed"]
            if inflight > self.stats["coll_chunks_in_flight_peak"]:
                self.stats["coll_chunks_in_flight_peak"] = inflight
        if msg.nchunks > 1:
            # the credit decision runs the moment this chunk's device
            # copy retires (fires on the transfer lane — never blocks
            # the pump)
            fut.add_done_callback(
                lambda _f, mid=msg.msg_id, src=msg.src, st=state:
                self._return_credit(mid, src, st))
        if state["arrived"] == msg.nchunks:
            # stream complete: the tail-upload waits and the handler run
            # move to the net-recv lane so the pump stays responsive; the
            # _rdzv_in entry keeps the barrier covering the completion
            self._net_recv.submit(
                lambda mid=msg.msg_id, last=msg.seq:
                self._finish_rendezvous(mid, last_seq=last))

    def _finish_rendezvous(self, msg_id: int, last_seq: int) -> None:
        """Net-recv lane: all chunks arrived — account pipeline overlap,
        await the tail device copies, and complete the stream: invoke the
        handler with a device-resident hetero_object for a 'send', or
        overwrite the keyed target object for a rendezvous 'put'. The
        reassembly entry stays in ``_rdzv_in`` until the completion ran —
        ``Cluster.barrier`` reads it as a busy signal, and popping early
        would let the barrier pass while the tail uploads (up to a whole
        chunk) are still in flight."""
        state = self._rdzv_in.get(msg_id)
        if state is None:
            return   # stream swept (peer removed) before completion
        try:
            meta, dev = state["meta"], state["dev"]
            uploads = state["uploads"]
            for seq, (fut, nb) in uploads.items():
                if seq != last_seq and fut.done():
                    self.stats["overlap_bytes"] += nb
            parts = []
            timeout = self.runtime.cfg.rdzv_finish_timeout_s
            for k in range(meta.nchunks):
                fut, _ = uploads[k]
                try:
                    # bounded wait on the net-recv lane, which tolerates
                    # blocking by design  # lint: allow-blocking
                    parts.append(fut.get(timeout=timeout))
                except TimeoutError:
                    raise TimeoutError(
                        f"rank {self.rank}: rendezvous stream "
                        f"{msg_id} from rank {meta.src} "
                        f"({meta.total_bytes} B, op={meta.op!r}): chunk "
                        f"{k}/{meta.nchunks} upload did not complete "
                        f"within {timeout:.0f}s on device {dev}'s "
                        "transfer lane "
                        f"(backlog={self._transfer_backlog(dev)})"
                    ) from None
                self.runtime.futures.release(fut)
            if state["slab"] is not None:
                assembled = state["slab"].reshape(meta.payload_shape)
            elif len(parts) == 1:
                assembled = parts[0].reshape(meta.payload_shape)
            else:   # non-jax Device backends (tests): plain host assembly
                assembled = np.concatenate([np.asarray(p) for p in parts]) \
                    .reshape(meta.payload_shape)
            if meta.op in ("put", "reduce"):
                # rendezvous put (ROADMAP follow-up b): the stream lands
                # device-resident and becomes the target's only valid
                # copy — no receiver-side host staging. For op='reduce'
                # the slab already IS base + every chunk (the adds were
                # fused on the transfer lane), so the same rebind
                # completes the accumulation; without a slab (non-jax
                # landing, single chunk) the add happens on host here. A
                # missing target (aborted collective) drops the result.
                target = self.objects.get(meta.object_key)
                if target is not None:
                    if meta.op == "reduce" and state["slab"] is None:
                        fut = target.request_host(write=True)
                        arr = fut.get()  # lint: allow-blocking (net-recv lane)
                        np.add(arr, np.asarray(assembled).reshape(arr.shape),
                               out=arr, casting="unsafe")
                        target.release()
                        self.stats["coll_bytes_reduced"] += \
                            int(meta.total_bytes or 0)
                    else:
                        if isinstance(assembled, np.ndarray):
                            assembled = self.runtime._device(dev).upload(
                                assembled)
                        self.runtime.rebind_device_copy(target, assembled,
                                                        dev)
                self._mark_done(meta, ack=False)  # explicit ack follows
                self.cluster.deliver(Message(msg_id=msg_id, kind="ack",
                                             src=self.rank, dst=meta.src))
                if meta.handler:
                    self._invoke(meta, target)
                return
            obj = self.runtime.adopt_device_array(assembled, dev)
            # completion ack: the sender recycles its parked pool buffer
            self._mark_done(meta, ack=False)
            self.cluster.deliver(Message(msg_id=msg_id, kind="ack",
                                         src=self.rank, dst=meta.src))
            self._invoke(meta, obj)
        finally:
            self._rdzv_in.pop(msg_id, None)

    def _handle(self, msg: Message):
        if self._reliability and msg.msg_id in self._seen \
                and msg.kind in ("meta", "payload", "put", "get"):
            # retransmission of a delivery that already completed: drop,
            # but re-ack so the sender stops resending (its ack was lost)
            self.stats["dup_dropped"] += 1
            if msg.ack_req:
                self.cluster.deliver(Message(msg_id=msg.msg_id, kind="ack",
                                             src=self.rank, dst=msg.src))
            return
        if msg.kind == "meta":
            self.stats["received"] += 1
            if msg.payload_shape is None:
                self._invoke(msg, None)
                self._mark_done(msg)
            elif msg.protocol == "rdzv":
                self._prepare_rendezvous(msg)
            elif msg.inline is not None:
                if not self._verify(msg, msg.inline):
                    return      # never-arrived: no ack → sender retries
                arr = np.frombuffer(msg.inline, dtype=msg.payload_dtype
                                    ).reshape(msg.payload_shape).copy()
                obj = self.runtime.hetero_object(arr)
                self._invoke(msg, obj)
                self._mark_done(msg)
            else:
                prior = self._pending_meta.pop(msg.msg_id, None)
                if prior is not None and prior.kind == "payload":
                    # the payload beat its metadata through the network
                    # (control and data ride different virtual channels)
                    obj = self._adopt_payload(prior, msg)
                    self._invoke(msg, obj)
                    self._mark_done(msg)
                else:
                    self._pending_meta[msg.msg_id] = msg
        elif msg.kind == "cts" or msg.kind == "credit":
            # window opened / slid: stream on the net-send lane, not the
            # pump — unrelated messages are never head-of-line blocked
            # behind this stream's payload (normally intercepted by
            # dispatch_control; this path serves Cluster subclasses that
            # enqueue control messages directly)
            self.dispatch_control(msg)
        elif msg.kind == "chunk":
            self._receive_chunk(msg)
        elif msg.kind == "ack":
            parked = self._rdzv_bufs.pop(msg.msg_id, None)
            if parked is not None:
                self.runtime.staging.release(parked[1])
            self._rdzv_sent.pop(msg.msg_id, None)
            self._ack_unacked(msg.msg_id)
        elif msg.kind == "payload":
            if not self._verify(msg, msg.payload):
                # never-arrived: its meta half (parked here or still in
                # flight) stays pending; the unacked meta+payload unit
                # retransmits and the clean payload re-pairs
                return
            meta = self._pending_meta.pop(msg.msg_id, None)
            if meta is None:       # payload raced ahead of metadata
                self._pending_meta[msg.msg_id] = msg
                return
            obj = self._adopt_payload(msg, meta)
            self._invoke(meta, obj)
            self._mark_done(meta)
        elif msg.kind == "put":
            if not self._verify(msg, msg.payload):
                return      # never-arrived: no ack → sender retries
            self.stats["received"] += 1
            target = self.objects.get(msg.object_key)
            if msg.op == "reduce":
                # eager accumulate (small collective hop): add on the
                # receiver's host copy — fixed per-stream arrival order
                # is the engine's job; this just folds one contribution
                if target is not None:
                    fut = target.request_host(write=True)
                    arr = fut.get()
                    np.add(arr, np.asarray(msg.payload).reshape(arr.shape),
                           out=arr, casting="unsafe")
                    target.release()
                    self.stats["coll_bytes_reduced"] += \
                        int(msg.payload.nbytes)
            elif target is not None:
                if msg.path == "direct" \
                        and not isinstance(msg.payload, np.ndarray):
                    # consumer-routed device landing (ROADMAP follow-up
                    # d): no host staging on the receive side either —
                    # prefer the sender's hint, then a device already
                    # holding the target, then the ledger's least-loaded
                    pref = msg.consumer_device
                    if pref is None:
                        pref = next(iter(target.resident_devices()), None)
                    dev = self.runtime.pick_landing_device(preferred=pref)
                    local = self._land_direct(msg.payload, dev)
                    self.stats["bytes_d2d"] += msg.payload.nbytes
                    self.runtime.rebind_device_copy(target, local, dev)
                else:
                    fut = target.request_host(write=True)
                    arr = fut.get()
                    np.copyto(arr, np.asarray(msg.payload))
                    target.release()
            if msg.handler:
                self._invoke(msg, target)
            self._mark_done(msg)
        elif msg.kind == "get":
            self.stats["received"] += 1
            src_obj = self.objects.get(msg.object_key)
            self.send(msg.src, msg.handler, src_obj,
                      user={"object_key": msg.object_key},
                      path=msg.path or "host",
                      consumer_device=msg.consumer_device)
            self._mark_done(msg)

    def _land_direct(self, payload: Any, device_id: int) -> Any:
        """One Device API D2D landing for a foreign (cross-rank) device
        payload, observed into the local interconnect model — the single
        path every direct receive (monolithic, chunk, put) routes
        through."""
        return d2d_transfer(None, self.runtime._device(device_id), payload,
                            observer=self.runtime.topology.observe)

    def _landing_device(self, meta: Message) -> int:
        """Consumer-routed delivery: the sender's per-message
        ``consumer_device`` hint wins; for a rendezvous put, a device
        already holding the target object comes next; then this rank's
        ``route_to`` registration for the handler, then the handler's
        declared device-type affinity, and finally the residency ledger's
        least-loaded device — never a hardwired device 0."""
        ids = {d.info.device_id for d in self.runtime.devices}
        pref = meta.consumer_device
        if pref not in ids and meta.op in ("put", "reduce"):
            target = self.objects.get(meta.object_key)
            if target is not None:
                pref = next(iter(target.resident_devices()), None)
        if pref not in ids:      # absent or invalid hint: fall through
            pref = self.routes.get(meta.handler)
        return self.runtime.pick_landing_device(
            preferred=pref, device_type=H.affinity(meta.handler))

    def _adopt_payload(self, msg: Message, meta: Message) -> HeteroObject:
        """Land an incoming payload in the local runtime. DIRECT payloads
        (device arrays) are moved with one Device API transfer onto the
        consumer task's device (falling back to least-loaded) — never
        staged through host (paper §3.2.3 Fig. 7)."""
        if msg.path == "direct" and not isinstance(msg.payload, np.ndarray):
            dev = self._landing_device(meta)
            local = self._land_direct(msg.payload, dev)
            self.stats["bytes_d2d"] += msg.payload.nbytes
            return self.runtime.adopt_device_array(local, dev)
        self.stats["bytes_staged"] += msg.payload.nbytes
        return self.runtime.hetero_object(msg.payload)

    def _invoke(self, meta: Message, obj: Optional[HeteroObject]):
        fn = H.resolve(meta.handler)
        ctx = HandlerContext(self, meta)
        fn(ctx, obj)

    def _pump(self):
        while not self._stop:
            self._flush_outgoing()
            if self._hb_dst is not None or self._reliability:
                self._tick()
            try:
                _prio, _seq, msg = self.inbox.get(timeout=0.001)
            except queue.Empty:
                continue
            if msg is None:
                return
            if msg is _FLUSH:
                continue          # woken to flush outgoing; loop does it
            self._busy_enter()    # popped but effects not yet visible
            try:
                self._handle(msg)
            except BaseException as e:  # bad message must not kill the rank
                self._record_handler_error(e)
            finally:
                self._busy_exit()

    def _record_handler_error(self, exc: BaseException) -> None:
        """Route a swallowed pump/handler exception to the error sink:
        counted in ``stats["handler_errors"]``, bounded trace kept for
        ``check()`` (strict mode re-raises at the next barrier)."""
        self.stats["handler_errors"] += 1
        self._errors.append(exc)
        del self._errors[:-50]
        if not (self._stop or self.runtime.cfg.strict_errors):
            import traceback
            traceback.print_exception(type(exc), exc, exc.__traceback__)

    def check(self) -> None:
        """Strict mode: re-raise the first swallowed pump-handler error
        (``Cluster.barrier`` calls this after draining)."""
        if self._errors and self.runtime.cfg.strict_errors:
            raise RuntimeError(
                f"rank {self.rank}: {self.stats['handler_errors']} "
                "swallowed handler error(s)") from self._errors[0]

    # -- rendezvous-state hygiene (peer loss / shutdown) ---------------
    def state_gauges(self) -> Dict[str, int]:
        """Leak gauges: live rendezvous/protocol state entries — all zero
        once every stream completed or was swept — plus the cumulative
        integrity counters (zero on a clean, uncorrupted link)."""
        with self._unacked_lock:
            unacked = len(self._unacked)
        return {"rdzv_out": len(self._rdzv_out),
                "rdzv_in": len(self._rdzv_in),
                "rdzv_bufs": len(self._rdzv_bufs),
                "pending_meta": len(self._pending_meta),
                "rdzv_sent": len(self._rdzv_sent),
                "unacked": unacked,
                "checksum_fail": self.stats["checksum_fail"],
                "chunks_rejected": self.stats["chunks_rejected"],
                "coll_bytes_reduced": self.stats["coll_bytes_reduced"],
                "coll_chunks_in_flight_peak":
                    self.stats["coll_chunks_in_flight_peak"],
                "coll_aborts": self.stats["coll_aborts"]}

    def _sweep_out_streams(self, peer: Optional[int] = None
                           ) -> Dict[str, int]:
        """Sweep the SEND-side rendezvous state tied to ``peer`` (``None``
        = all peers): parked outgoing streams whose CTS/credits will
        never arrive, and pooled buffers whose completion ack is lost —
        their staging buffers return to the pool. ``_rdzv_out`` and
        ``_rdzv_bufs`` are mutated only on the net-send lane, so this
        must run THERE (or after the lane is joined, at shutdown) —
        never concurrently with ``_advance_stream``, which may still be
        handing out zero-copy views of the very buffer being released."""
        swept = {"rdzv_out": 0, "rdzv_bufs": 0, "rdzv_sent": 0}
        for mid, st in list(self._rdzv_out.items()):
            if peer is None or st["meta"].dst == peer:
                del self._rdzv_out[mid]
                if st["pooled"]:
                    self.runtime.staging.release(st["arr"])
                swept["rdzv_out"] += 1
        for mid, st in list(self._rdzv_sent.items()):
            if peer is None or st["dst"] == peer:
                del self._rdzv_sent[mid]
                swept["rdzv_sent"] += 1
        for mid, (dst, buf) in list(self._rdzv_bufs.items()):
            if peer is None or dst == peer:
                del self._rdzv_bufs[mid]
                self.runtime.staging.release(buf)
                swept["rdzv_bufs"] += 1
        return swept

    def _sweep_in_state(self, peer: Optional[int] = None) -> Dict[str, int]:
        """Sweep the RECEIVE-side state tied to ``peer`` (``None`` = all):
        in-progress reassembly entries and orphaned metadata halves —
        the leaks an elastic rescale would otherwise accumulate. Orphan
        chunks for a swept stream are dropped by ``_receive_chunk``."""
        swept = {"rdzv_in": 0, "pending_meta": 0}
        for mid, st in list(self._rdzv_in.items()):
            if peer is None or st["meta"].src == peer:
                if self._rdzv_in.pop(mid, None) is not None:
                    swept["rdzv_in"] += 1
        for mid, m in list(self._pending_meta.items()):
            if peer is None or m.src == peer:
                if self._pending_meta.pop(mid, None) is not None:
                    swept["pending_meta"] += 1
        return swept

    def remove_peer(self, peer: int) -> Dict[str, int]:
        """A peer left the cluster mid-stream (elastic rescale): sweep
        every rendezvous stream to/from it and release the pooled
        buffers its lost CTS/credit/ack messages left parked. The whole
        send-side sweep runs on the net-send lane (the only mutator of
        ``_rdzv_out``/``_rdzv_bufs``), so it cannot race a concurrent
        ``_advance_stream``; the receive-side sweep runs here. Returns
        the per-kind swept counts."""
        timeout = self.runtime.cfg.peer_sweep_timeout_s
        try:
            fut: HFuture = HFuture()
            self._net_send.submit(
                lambda p=peer: self._sweep_out_streams(p), fut)
            swept = dict(fut.get(timeout=timeout))
        except RuntimeError:       # lane already stopped: sweep inline
            swept = dict(self._sweep_out_streams(peer))
        except TimeoutError:
            raise TimeoutError(
                f"rank {self.rank}: removing peer {peer}: the net-send "
                f"lane did not run the stream sweep within {timeout:.0f}s "
                f"(lane backlog={self._net_send.backlog()}, "
                f"live streams={sorted(self._rdzv_out)})") from None
        with self._unacked_lock:
            for mid in [m for m, st in self._unacked.items()
                        if st["dst"] == peer]:
                del self._unacked[mid]
        swept.update(self._sweep_in_state(peer))
        return swept

    def reset_peer_state(self) -> Dict[str, int]:
        """Full protocol-state reset after THIS rank rejoins from a
        partition/freeze (elastic grow): every parked stream, pending
        retransmit and reassembly entry refers to a world that moved on
        — sweep them all so the rank starts clean."""
        swept = self.remove_peer(None)  # peer=None sweeps every peer
        with self._unacked_lock:
            self._unacked.clear()
        return swept

    def shutdown(self):
        self._stop = True
        self.enqueue(None)
        self._thread.join(timeout=self.runtime.cfg.pump_join_timeout_s)
        self.runtime.shutdown()
        # gauge hygiene (sanitizer): on a clean run every leak gauge must
        # have drained BEFORE the sweeps below reclaim stranded state —
        # the sweeps exist for faulted runs, not as a leak amnesty. The
        # check is captured here and raised after the sweeps so teardown
        # still completes. Skipped when a FaultInjector is attached
        # (killed peers legitimately strand streams) or this rank is dead.
        leak = None
        if (sanitizer.current() is not None and self.runtime.cfg.sanitize
                and self.cluster.faults is None):
            leak = sanitizer.gauge_leak_report(self)
        # lanes are drained and joined: release whatever rendezvous
        # state in-flight shutdown stranded (pooled buffers back to the
        # pool, reassembly/metadata entries dropped)
        self._sweep_out_streams()
        self._sweep_in_state()
        if leak is not None:
            san = sanitizer.current()
            if san is not None:
                san.note_gauge_leaks(1)
            raise sanitizer.SanitizerError(leak)


class FaultInjector:
    """Deterministic fault injection at the simulated network layer.

    Faults are modeled where real ones happen — on the wire and at the
    endpoints — so every recovery mechanism above (retries, NACKs,
    heartbeat detection, peer sweeps, chunk migration) is exercised by
    the same code paths production traffic uses:

    - ``kill_rank``: full partition — every message to OR from the rank
      is dropped (the process is "gone" to the network; its local pump
      keeps spinning, which is what a crashed-but-undetected peer looks
      like to everyone else).
    - ``freeze_rank``: straggler — messages touching the rank are
      delayed by the remaining freeze time (and observed into the
      ``InterconnectModel`` as latency samples, which is precisely the
      EWMA signal straggler detection reads). The rank keeps computing.
    - ``set_link``: per-directed-link loss/duplication/extra delay/
      bit-flip corruption, each applied per message from a seeded RNG —
      deterministic for a fixed seed and delivery order. Corruption
      flips one bit in a COPY of the payload/inline bytes (the sender's
      retained buffers stay pristine, so the reliability layer's
      retransmission carries the clean bytes).
    - ``corrupt_checkpoint_leaf``: flip one seeded bit in a committed
      checkpoint leaf's ``.npy`` data section on disk — the silent
      storage-corruption case ``Checkpointer`` digests guard against.
    - ``fail_task``: plant deterministic kernel faults in a rank's local
      Runtime — the next ``times`` launches raise ``InjectedTaskFault``
      (retried up to ``RuntimeConfig.task_retries``, then surfaced).

    All decisions come from one seeded ``random.Random`` under a lock;
    ``stats`` counts every injected event."""

    def __init__(self, cluster: "Cluster", seed: int = 0):
        self.cluster = cluster
        self.rng = random.Random(seed)
        self._lock = sanitizer.make_lock("FaultInjector._lock")
        self.dead: Set[int] = set()
        self.frozen: Dict[int, float] = {}     # rank -> thaw instant
        self.links: Dict[Tuple[int, int], Dict[str, float]] = {}
        self.stats = {"dropped": 0, "duplicated": 0, "delayed": 0,
                      "kills": 0, "freezes": 0, "corrupted": 0,
                      "ckpt_corrupted": 0, "task_faults": 0}

    # -- fault controls -------------------------------------------------
    def kill_rank(self, rank: int) -> None:
        with self._lock:
            self.dead.add(rank)
            self.stats["kills"] += 1

    def revive_rank(self, rank: int) -> None:
        with self._lock:
            self.dead.discard(rank)

    def freeze_rank(self, rank: int, seconds: float) -> None:
        """Delay all traffic touching ``rank`` for ``seconds`` from now
        (extends an active freeze rather than stacking)."""
        with self._lock:
            self.frozen[rank] = max(self.frozen.get(rank, 0.0),
                                    time.perf_counter() + seconds)
            self.stats["freezes"] += 1

    def is_frozen(self, rank: int) -> bool:
        return self._frozen_for(rank) > 0.0

    def _frozen_for(self, rank: int) -> float:
        thaw = self.frozen.get(rank)
        if thaw is None:
            return 0.0
        remaining = thaw - time.perf_counter()
        if remaining <= 0:
            self.frozen.pop(rank, None)
            return 0.0
        return remaining

    def set_link(self, src: int, dst: int, drop: float = 0.0,
                 dup: float = 0.0, delay_s: float = 0.0,
                 corrupt: float = 0.0) -> None:
        """Per-directed-link fault profile: each message (src → dst) is
        dropped with probability ``drop``, duplicated with ``dup``,
        delayed an extra ``delay_s``, and — for messages carrying
        host-visible payload bytes — bit-flipped with probability
        ``corrupt``."""
        self.links[(src, dst)] = {"drop": drop, "dup": dup,
                                  "delay_s": delay_s, "corrupt": corrupt}

    def clear_link(self, src: int, dst: int) -> None:
        self.links.pop((src, dst), None)

    # -- the interception point ----------------------------------------
    def intercept(self, msg: Message) -> Tuple[bool, float, bool]:
        """Fault decision for one message: (drop, extra_delay_s,
        duplicate)."""
        with self._lock:
            if msg.src in self.dead or msg.dst in self.dead:
                self.stats["dropped"] += 1
                return True, 0.0, False
            delay = max(self._frozen_for(msg.src),
                        self._frozen_for(msg.dst))
            link = self.links.get((msg.src, msg.dst))
            dup = False
            if link is not None:
                if link["drop"] and self.rng.random() < link["drop"]:
                    self.stats["dropped"] += 1
                    return True, 0.0, False
                if link["dup"] and self.rng.random() < link["dup"]:
                    dup = True
                    self.stats["duplicated"] += 1
                delay += link["delay_s"]
            if delay > 0:
                self.stats["delayed"] += 1
            return False, delay, dup

    # -- corruption -----------------------------------------------------
    def maybe_corrupt(self, msg: Message) -> Message:
        """Bit-flip decision for one message: returns either ``msg``
        untouched or a shallow copy whose payload/inline bytes have one
        seeded bit flipped.

        The copy is essential: the sender retains the *original*
        ``Message`` objects for ack-timeout retransmission and tail
        resends, so mutating in place would poison every retry. Only
        host-visible bytes (np.ndarray / bytes) are candidates — DIRECT
        device arrays are immutable in-process references a wire flip
        cannot reach (and hashing them would force a readback)."""
        with self._lock:
            link = self.links.get((msg.src, msg.dst))
            if (link is None or not link.get("corrupt")
                    or self.rng.random() >= link["corrupt"]):
                return msg
            if msg.inline is not None and len(msg.inline) > 0:
                buf = bytearray(msg.inline)
                bit = self.rng.randrange(len(buf) * 8)
                buf[bit >> 3] ^= 1 << (bit & 7)
                self.stats["corrupted"] += 1
                return dataclasses.replace(msg, inline=bytes(buf))
            pay = msg.payload
            if isinstance(pay, np.ndarray) and pay.nbytes > 0:
                flipped = np.array(pay, copy=True)
                flat = flipped.reshape(-1).view(np.uint8)
                bit = self.rng.randrange(flat.size * 8)
                flat[bit >> 3] ^= 1 << (bit & 7)
                self.stats["corrupted"] += 1
                return dataclasses.replace(msg, payload=flipped)
            return msg

    def corrupt_checkpoint_leaf(self, directory: str, step: int,
                                key: str) -> None:
        """Flip one seeded bit in the data section of a committed
        checkpoint leaf's ``.npy`` file — silent storage corruption, the
        case the manifest digests exist to catch. The npy header is left
        intact (np.load must still parse shape/dtype) by locating the
        data section from the end of the file: ``offset = size − nbytes``
        computed from the manifest's own shape/dtype entry."""
        step_dir = os.path.join(directory, f"step_{step}")
        with open(os.path.join(step_dir, "manifest.json")) as f:
            manifest = json.load(f)
        entry = manifest["leaves"][key]
        nbytes = int(np.prod(entry["shape"], dtype=np.int64) *
                     np.dtype(entry["dtype"]).itemsize)
        path = os.path.join(step_dir, entry["file"])
        size = os.path.getsize(path)
        with self._lock:
            bit = self.rng.randrange(max(1, nbytes) * 8)
            self.stats["ckpt_corrupted"] += 1
        with open(path, "r+b") as f:
            f.seek((size - nbytes) + (bit >> 3))
            b = f.read(1)
            f.seek(-1, os.SEEK_CUR)
            f.write(bytes([b[0] ^ (1 << (bit & 7))]))

    def fail_task(self, rank: int, times: int = 1) -> None:
        """Plant ``times`` kernel faults in ``rank``'s local Runtime: the
        next ``times`` task launches there raise ``InjectedTaskFault``
        from inside ``_launch``, exercising retry (``task_retries``) and
        strict-error surfacing through the production failure path."""
        rt = self.cluster.ranks[rank].runtime
        with rt._lock:
            rt._inject_task_faults += times
        with self._lock:
            self.stats["task_faults"] += times


@dataclasses.dataclass
class HandlerContext:
    rank: Rank
    message: Message

    @property
    def user(self):
        return self.message.user

    def send(self, dst, handler_name, obj=None, **kw):
        return self.rank.send(dst, handler_name, obj, **kw)


class Cluster:
    """In-process rank set with a simulated cut-through network.
    ``latency_s`` and ``bw_bytes_per_s`` let benchmarks model
    interconnect behaviour; the 'direct' path skips the host-staging cost
    the way GPU-aware MPI does.

    Transmission is modeled AT THE LINK, not in the sender (ROADMAP
    follow-up d): each directed (src, dst) pair with a nonzero simulated
    delay gets its own ``("link", src, dst)`` lane on a cluster-wide
    progress engine, which serializes that link's payloads — so chunk
    k+1's transmit overlaps chunk k's receive-side upload across the
    whole credit window, instead of the old store-and-forward model that
    billed transmission in the sender's pump and kept exactly one chunk
    in flight. The wire is occupied only for each message's
    SERIALIZATION time (bytes/bandwidth); propagation latency delays
    delivery on a per-link ``linkprop`` lane without holding the wire —
    true cut-through, so a long-fat link does not serialize messages
    behind each other's flight time. Control messages (CTS, credits,
    acks — anything 0-byte) ride a higher-priority virtual channel on
    the link, the way real fabrics keep flow control out from behind
    bulk data.

    The control VC is NOT free: it has a finite per-link drain rate
    (``ctrl_drain_per_s`` messages/second, a NIC-message-rate analogue)
    and its own ``_ctrl_free`` occupancy schedule mirroring the payload
    wire's ``_wire_free`` — so a credit storm queues behind itself and
    is billed real simulated time, instead of the old model where
    control chatter cost nothing and naive per-chunk crediting looked
    free. ``ctrl_stats`` counts control messages and their accumulated
    queueing. The drain rate is DERIVED by default
    (``ctrl_drain_per_s=None``): an EWMA over the measured
    ``dispatch_control`` service time, seeded at 200k msgs/s and clamped
    to [20k, 5M] — the same measure-then-derive pattern chunk sizing
    uses with link bandwidth. Passing an explicit value pins the rate,
    and ``ctrl_drain_per_s=0`` restores the unbilled channel.

    ``topology`` is the rank-pair ``InterconnectModel``: every
    payload-carrying delivery is timed into it, and the rendezvous
    protocol sizes its chunks and credit windows from the measured
    bandwidth-delay product of the (src, dst) pair."""

    _CONTROL_KINDS = frozenset({"cts", "ack", "credit", "get", "nack"})

    # adaptive control-drain seed and clamps (messages/second): the seed
    # matches the old constant; the clamps keep one outlier service
    # sample from pricing the channel absurdly in either direction
    CTRL_DRAIN_SEED = 200e3
    CTRL_DRAIN_MIN = 20e3
    CTRL_DRAIN_MAX = 5e6
    _CTRL_EWMA_ALPHA = 0.25

    def __init__(self, n_ranks: int, rt_config: Optional[RuntimeConfig] = None,
                 latency_s: float = 0.0, bw_bytes_per_s: float = 0.0,
                 ctrl_drain_per_s: Optional[float] = None):
        self.latency_s = latency_s
        self.bw = bw_bytes_per_s
        # control-VC drain rate (ROADMAP 5d): ``None`` derives it from the
        # measured control-message service time — an EWMA over what each
        # ``dispatch_control`` actually costs, the same
        # measure-then-derive pattern chunk sizing uses with bandwidth —
        # seeded at the old 200k/s constant. An explicit value pins the
        # rate (benchmarks/tests); 0 restores the unbilled channel.
        self._ctrl_adaptive = ctrl_drain_per_s is None
        self._ctrl_pinned = (0.0 if ctrl_drain_per_s is None
                             else float(ctrl_drain_per_s))
        self._ctrl_service_ewma = 1.0 / self.CTRL_DRAIN_SEED
        self.topology = InterconnectModel()
        self.net = ProgressEngine(name="net")
        self._inflight = 0             # messages on a link lane right now
        self._inflight_lock = sanitizer.make_lock("Cluster._inflight_lock")
        # per-directed-link wire model: the perf_counter instant the wire
        # is next free. Advanced by the EXACT modeled transmission time,
        # so sleep overshoot never accumulates across a chunk stream
        # (only each message's own delivery jitters, the wire schedule
        # stays faithful). Written only from that link's serial lane.
        self._wire_free: Dict[Tuple[int, int], float] = {}
        # control-VC occupancy schedule (finite drain rate): written from
        # ANY delivering thread at reservation time, hence its own lock
        self._ctrl_free: Dict[Tuple[int, int], float] = {}
        self._ctrl_lock = sanitizer.make_lock("Cluster._ctrl_lock")
        self.ctrl_stats = {"msgs": 0, "queued_s": 0.0,
                           "adaptive": self._ctrl_adaptive,
                           "drain_per_s": (self.CTRL_DRAIN_SEED
                                           if self._ctrl_adaptive
                                           else self._ctrl_pinned),
                           "service_ewma_s": self._ctrl_service_ewma}
        # fault injection (None = perfect network, zero overhead on the
        # delivery path beyond one attribute check)
        self.faults: Optional[FaultInjector] = None
        self._elastic = None       # bound by ElasticRuntime
        self.ranks = [Rank(self, r, rt_config) for r in range(n_ranks)]

    @property
    def ctrl_drain(self) -> float:
        """Current control-VC drain rate (messages/second). Pinned mode
        returns the constructor value verbatim; adaptive mode inverts the
        measured per-message service-time EWMA, clamped to
        [CTRL_DRAIN_MIN, CTRL_DRAIN_MAX]."""
        if not self._ctrl_adaptive:
            return self._ctrl_pinned
        rate = 1.0 / max(self._ctrl_service_ewma, 1e-9)
        return min(max(rate, self.CTRL_DRAIN_MIN), self.CTRL_DRAIN_MAX)

    def _observe_ctrl_service(self, dt: float) -> None:
        """Fold one measured control-dispatch service time into the EWMA
        the adaptive drain rate derives from."""
        if not self._ctrl_adaptive or dt <= 0:
            return
        with self._ctrl_lock:
            self._ctrl_service_ewma += self._CTRL_EWMA_ALPHA * (
                dt - self._ctrl_service_ewma)
            self.ctrl_stats["service_ewma_s"] = self._ctrl_service_ewma
            self.ctrl_stats["drain_per_s"] = self.ctrl_drain

    def fault_injector(self, seed: int = 0) -> "FaultInjector":
        """Attach deterministic fault injection and engage the
        reliability layer (ack/retry/NACK retransmission) on every rank —
        an injected drop then surfaces as a retransmit, never a hang.
        Idempotent; returns the injector."""
        if self.faults is None:
            self.faults = FaultInjector(self, seed)
        for r in self.ranks:
            r._reliability = True
        return self.faults

    @staticmethod
    def _sleep_until(deadline: float) -> None:
        """Wait until a modeled delivery instant without burning a core:
        coarse GIL-releasing sleep for the bulk, a yielding spin only for
        the final ~150 µs. A full-duration spin would occupy a whole CPU
        for every millisecond of simulated wire time — on small hosts
        that starvation re-creates the very head-of-line blocking the
        cut-through model removes."""
        san = sanitizer.current()
        if san is not None:
            # simulated wire time is a sleep: flag it if it ever runs on
            # a strict lane (link/linkctl lanes are blocking-allowed)
            san.note_sleep(max(deadline - time.perf_counter(), 0.0),
                           "Cluster._sleep_until")
        while True:
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                return
            if remaining > 150e-6:
                # simulated wire latency on the link/linkctl lanes, which
                # tolerate blocking by design  # lint: allow-blocking
                time.sleep(remaining - 100e-6)
            else:
                time.sleep(0)  # sched_yield  # lint: allow-blocking

    def _priority(self, msg: Message, nbytes: int) -> int:
        """Virtual channels on the simulated wire: control traffic first,
        eager payloads next, bulk rendezvous chunks last — a small
        message never queues behind a whole streamed window."""
        if nbytes == 0 or msg.kind in self._CONTROL_KINDS:
            return 0
        return 2 if msg.kind == "chunk" else 1

    def deliver(self, msg: Message):
        """Hand a message to the network, via the fault injector when one
        is attached: a dropped message vanishes here (the reliability
        layer's retries are the only recovery), a duplicated one is
        transmitted twice, and a delayed one (frozen rank / slow link)
        parks on a per-link fault lane whose delivery is *observed* into
        the interconnect model — injected slowness shows up in the same
        EWMA latency telemetry real slowness would."""
        fi = self.faults
        if fi is not None:
            drop, extra, dup = fi.intercept(msg)
            if drop:
                return
            # one corruption decision per wire crossing; a duplicate
            # carries the same (possibly flipped) bytes — dedup and
            # checksum verification both see what the wire produced
            msg = fi.maybe_corrupt(msg)
            if dup:
                self._transmit(msg)
            if extra > 0:
                self._deliver_delayed(msg, extra)
                return
        self._transmit(msg)

    def _deliver_delayed(self, msg: Message, delay: float) -> None:
        """Injected-fault delay: park the message on the per-link fault
        lane, transmit after ``delay``, and observe the elapsed time as a
        (latency-classed) topology sample — the straggler signal."""
        with self._inflight_lock:
            self._inflight += 1
        t0 = time.perf_counter()
        t_deliver = t0 + delay
        link = (msg.src, msg.dst)

        def run():
            try:
                self._sleep_until(t_deliver)
                self._transmit(msg)
                nbytes = msg.payload.nbytes if msg.payload is not None \
                    else (len(msg.inline) if msg.inline is not None else 0)
                # 0-byte control messages observe as 1 byte: a latency
                # sample, exactly what a delayed heartbeat should be
                self.topology.observe(msg.src, msg.dst, max(nbytes, 1),
                                      time.perf_counter() - t0)
            finally:
                with self._inflight_lock:
                    self._inflight -= 1

        try:
            self.net.submit("fault", link, run)
        except RuntimeError:        # engine shut down: drop, roll back
            with self._inflight_lock:
                self._inflight -= 1

    def _transmit(self, msg: Message):
        """The fault-free network: when the simulated link has a nonzero
        delay the message is queued on a link lane (cut-through — the
        LINK serializes transmission, the sender is free immediately);
        zero-delay messages land in the destination inbox directly.
        Control traffic (priority 0) rides a dedicated per-link control
        lane — the virtual channel real fabrics use — so a credit or CTS
        is never stuck behind an in-service bulk chunk; payload messages
        serialize on the wire's ``_wire_free`` schedule, non-preemptively,
        priority-ordered."""
        nbytes = msg.payload.nbytes if msg.payload is not None else \
            (len(msg.inline) if msg.inline is not None else 0)
        delay = self.latency_s
        if self.bw and nbytes:
            delay += nbytes / self.bw
        dst = self.ranks[msg.dst]
        if delay <= 0:
            t0 = time.perf_counter()
            if not dst.dispatch_control(msg):
                dst.enqueue(msg, msg_priority(msg, nbytes))
            if nbytes:
                self.topology.observe(msg.src, msg.dst, nbytes,
                                      time.perf_counter() - t0)
            return
        prio = msg_priority(msg, nbytes)
        link = (msg.src, msg.dst)
        if prio == PRIO_CONTROL:
            # control VC: billed against the finite per-link drain rate.
            # The delivery instant is reserved on the _ctrl_free schedule
            # up front (monotonic per link, so control stays ordered),
            # then short waits deliver inline in the calling thread —
            # waking an idle per-link control lane costs several hundred
            # µs on a busy host, far more than the simulated latency —
            # and queued-up waits (a credit storm billing real time) move
            # to the linkctl lane so the caller never stalls on them.
            t0 = time.perf_counter()
            t_deliver = t0 + delay
            if self.ctrl_drain > 0:
                service = 1.0 / self.ctrl_drain
                with self._ctrl_lock:
                    start = max(t0, self._ctrl_free.get(link, 0.0))
                    self._ctrl_free[link] = start + service
                    self.ctrl_stats["msgs"] += 1
                    self.ctrl_stats["queued_s"] += start - t0
                t_deliver = start + service + delay
            ctl = self.net.peek("linkctl", link)
            if t_deliver - t0 <= 100e-6 and (ctl is None or not ctl.busy()):
                self._sleep_until(t_deliver)
                ts = time.perf_counter()
                if not dst.dispatch_control(msg):
                    dst.enqueue(msg, prio)
                self._observe_ctrl_service(time.perf_counter() - ts)
                return
            with self._inflight_lock:
                self._inflight += 1

            def transmit_ctrl():
                try:
                    self._sleep_until(t_deliver)
                    ts = time.perf_counter()
                    if not dst.dispatch_control(msg):
                        dst.enqueue(msg, prio)
                    self._observe_ctrl_service(time.perf_counter() - ts)
                finally:
                    with self._inflight_lock:
                        self._inflight -= 1

            try:
                self.net.submit("linkctl", link, transmit_ctrl)
            except RuntimeError:    # engine shut down: drop, roll back
                with self._inflight_lock:
                    self._inflight -= 1
            return
        with self._inflight_lock:
            self._inflight += 1

        def finish(t0: float):
            try:
                if not dst.dispatch_control(msg):
                    dst.enqueue(msg, prio)
                if nbytes:
                    self.topology.observe(msg.src, msg.dst, nbytes,
                                          time.perf_counter() - t0)
            finally:
                with self._inflight_lock:
                    self._inflight -= 1

        def transmit():
            # cut-through: the wire is OCCUPIED only for the
            # serialization time (bytes/bandwidth); propagation latency
            # delays delivery but does not hold the wire — billing
            # latency as occupancy would make every message on a
            # long-fat link serialize behind the previous one's whole
            # flight time, which no real fabric does. The link lane
            # paces occupancy; the per-link propagation lane sleeps out
            # the latency (delivery instants are monotonic per link, so
            # its FIFO preserves order).
            t0 = time.perf_counter()
            serialize = nbytes / self.bw if self.bw and nbytes else 0.0
            start = max(t0, self._wire_free.get(link, 0.0))
            self._wire_free[link] = start + serialize
            t_deliver = start + serialize + self.latency_s
            if self.latency_s > 0:
                self._sleep_until(start + serialize)

                def propagate():
                    self._sleep_until(t_deliver)
                    finish(t0)
                try:
                    self.net.submit("linkprop", link, propagate)
                    return
                except RuntimeError:    # engine shutting down: inline
                    pass
            self._sleep_until(t_deliver)
            finish(t0)

        try:
            self.net.submit("link", link, transmit, priority=prio)
        except RuntimeError:        # engine shut down: drop, roll back
            with self._inflight_lock:
                self._inflight -= 1

    def _rank_busy(self, r: Rank) -> bool:
        with r._out_lock:
            if r.outgoing:
                return True
        return (not r.inbox.empty() or r._active
                or bool(r._rdzv_out) or bool(r._rdzv_in)
                or r._net_send.busy() or r._net_recv.busy())

    def _net_busy(self) -> bool:
        with self._inflight_lock:
            return self._inflight > 0

    def _barrier_diagnostics(self) -> str:
        """What the cluster is stuck on: per-busy-rank queue depths, lane
        backlogs, live rendezvous stream ids and unacked reliable sends,
        plus the network's in-flight count and control-VC pressure —
        attached to the barrier-timeout error so a hang names its
        culprit instead of just timing out."""
        with self._inflight_lock:
            inflight = self._inflight
        parts = [f"net: {inflight} msg(s) in flight on link lanes, "
                 f"ctrl VC {self.ctrl_stats['msgs']} msgs "
                 f"({self.ctrl_stats['queued_s'] * 1e3:.1f} ms queued)"]
        dead = self.faults.dead if self.faults is not None else frozenset()
        for r in self.ranks:
            if r.rank in dead or not self._rank_busy(r):
                continue
            lanes = r.runtime.engine.backlogs()
            with r._out_lock:
                nout = len(r.outgoing)
            with r._unacked_lock:
                unacked = sorted(r._unacked)
            parts.append(
                f"rank {r.rank}: inbox={r.inbox.qsize()} "
                f"active={r._active} outgoing={nout} "
                f"lane_backlogs={lanes or '{}'} "
                f"rdzv_out={sorted(r._rdzv_out)} "
                f"rdzv_in={sorted(r._rdzv_in)} "
                f"pending_meta={sorted(r._pending_meta)} "
                f"unacked={unacked}")
        return "; ".join(parts)

    def barrier(self, timeout: float = 60.0):
        """Wait until every rank's message work has drained — inboxes,
        pump activity, rendezvous state, net-send/net-recv lanes, and
        messages in flight on the simulated links — then barrier the
        runtimes. Requires TWO consecutive all-idle sweeps: every handoff
        (pump → lane → link → inbox) marks its next stage busy before the
        previous one goes idle, so anything in flight during sweep one is
        visible somewhere by sweep two. Ranks the fault injector has
        killed are skipped — they are partitioned, not draining."""
        deadline = clock.now() + timeout
        idle_sweeps = 0
        while idle_sweeps < 2:
            dead = self.faults.dead if self.faults is not None \
                else frozenset()
            if self._net_busy() \
                    or any(self._rank_busy(r) for r in self.ranks
                           if r.rank not in dead):
                idle_sweeps = 0
                if clock.now() > deadline:
                    diag = self._barrier_diagnostics()
                    if sanitizer.current() is not None:
                        # wait-graph verdict: turn the raw backlog dump
                        # into a named root cause (deadlock cycle across
                        # ranks/streams, or the slowest lane)
                        diag += ("; waitgraph: "
                                 + sanitizer.waitgraph_verdict(self))
                    raise TimeoutError(
                        f"cluster barrier timeout after {timeout:.1f}s — "
                        + diag)
                time.sleep(0.001)
            else:
                idle_sweeps += 1
        dead = self.faults.dead if self.faults is not None else frozenset()
        for r in self.ranks:
            if r.rank in dead:
                continue
            r.runtime.barrier(timeout=max(deadline - clock.now(), 1.0))
            r.check()      # strict mode: surface swallowed handler errors

    def shutdown(self):
        # a sanitizer gauge-leak assertion on one rank must not leave the
        # remaining ranks (and the network engine) running: finish the
        # teardown, then re-raise the first failure
        errs: List[BaseException] = []
        for r in self.ranks:
            try:
                r.shutdown()
            except sanitizer.SanitizerError as e:
                errs.append(e)
        self.net.shutdown()
        if errs:
            raise errs[0]

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
