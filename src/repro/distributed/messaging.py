"""Message-driven distributed runtime (PREMA layer, paper §3.2).

Faithful reproduction of the messaging semantics on an in-process "cluster":
each rank runs a message-pump thread with its own heterogeneous tasking
Runtime, and inter-rank messages follow the paper's two-phase protocol —

  sender:   (1) async read-access request on the hetero_object
            (2) push {future, metadata} to the outgoing pending queue
            (3) pump polls the queue
            (4) when the future completes, send metadata msg + payload msg
            (5) release access
  receiver: (1) receive metadata  (2) prepare buffer  (3) receive payload
            (4) request device allocation  (5) run the user handler

Two payload paths are modeled, matching §3.2.3: HOST_STAGED (device→host →
network → host→device) and DIRECT (device→device; "GPU-aware interconnect").
The DIRECT path is real, not simulated: the sender snapshots the freshest
*device* copy via ``Runtime._request_device_view`` (jax arrays are immutable,
so no staging copy is needed), the payload travels as that device array, and
the receiver lands it with one Device API ``transfer`` onto its own device —
no host copy is materialized on either side. Per-path traffic is accounted
in ``Rank.stats`` (``bytes_d2d`` vs ``bytes_staged``).
Small messages (≤512B) inline the payload in the metadata message
(§4.2.3). On a real TPU pod the network step lowers to ICI collectives
(see distributed/collectives.py); this layer is the host-side control plane
and the single-node multi-device execution engine.
"""
from __future__ import annotations

import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import HeteroObject, Runtime, RuntimeConfig
from repro.core.device_api import transfer as d2d_transfer
from repro.core.futures import HFuture
from repro.core.hetero_object import HOST
from repro.distributed import handlers as H

INLINE_PAYLOAD_BYTES = 512
_msg_ids = itertools.count()
_FLUSH = object()            # pump wake-up sentinel (not a Message)


@dataclasses.dataclass
class Message:
    msg_id: int
    kind: str                  # 'meta' | 'payload' | 'put' | 'get' | 'ack'
    src: int
    dst: int
    handler: Optional[str] = None
    payload_shape: Optional[Tuple[int, ...]] = None
    payload_dtype: Optional[str] = None
    inline: Optional[bytes] = None
    payload: Optional[np.ndarray] = None     # "network" buffer
    object_key: Optional[Any] = None
    reply_to: Optional[int] = None
    user: Optional[Dict[str, Any]] = None
    path: str = "host"         # 'host' (staged) | 'direct'
    # receiver device the payload's consumer task will run on, when the
    # sender knows it (consumer-routed delivery, ROADMAP follow-up d)
    consumer_device: Optional[int] = None


class Rank:
    """One simulated process: message pump + local tasking runtime."""

    def __init__(self, cluster: "Cluster", rank: int,
                 rt_config: Optional[RuntimeConfig] = None):
        self.cluster = cluster
        self.rank = rank
        self.runtime = Runtime(rt_config or RuntimeConfig())
        self.inbox: "queue.Queue[Optional[Message]]" = queue.Queue()
        self.outgoing: List[Tuple[HFuture, Message, HeteroObject]] = []
        self._out_lock = threading.Lock()
        self._pending_meta: Dict[int, Message] = {}
        self.objects: Dict[Any, HeteroObject] = {}   # global ptr -> object
        # handler name -> local device id: where this rank wants payloads
        # for that handler landed (consumer routing, set via route_to)
        self.routes: Dict[str, int] = {}
        self.stats = {"sent": 0, "received": 0, "bytes_out": 0,
                      "bytes_d2d": 0, "bytes_staged": 0}
        self._stop = False
        self._thread = threading.Thread(target=self._pump, daemon=True,
                                        name=f"prema-rank{rank}")
        self._thread.start()

    # ------------------------------------------------------------------
    # public API (paper: mp_send with hetero_object argument)
    # ------------------------------------------------------------------
    def send(self, dst: int, handler_name: str, obj: Optional[HeteroObject]
             = None, user: Optional[Dict[str, Any]] = None,
             path: str = "host",
             consumer_device: Optional[int] = None) -> HFuture:
        """One-sided async handler invocation with optional hetero_object
        payload. ``consumer_device`` names the receiver device the payload's
        consumer task will run on, when known — DIRECT payloads then land
        there with a single transfer. Returns a future completed when the
        message has been handed to the network (not when the handler ran)."""
        fut = HFuture()
        meta = Message(msg_id=next(_msg_ids), kind="meta", src=self.rank,
                       dst=dst, handler=handler_name, user=user, path=path,
                       consumer_device=consumer_device)
        if obj is None:
            self.cluster.deliver(meta)
            self.stats["sent"] += 1
            fut.set_result(None)
            return fut
        meta.payload_shape = tuple(obj.shape)
        meta.payload_dtype = np.dtype(obj.dtype).str
        # (1) async access request; payload follows when ready. DIRECT sends
        # take a device view (no host staging, §3.2.3 Fig. 7); host-staged
        # sends pin a host copy as before (Fig. 6).
        if path == "direct":
            access = self.runtime._request_device_view(obj)
        else:
            access = obj.request_host(write=False)

        def on_ready(_):
            with self._out_lock:
                self.outgoing.append((access, meta, obj))
            # poke the pump so the flush happens now, not at the next poll
            self.inbox.put(_FLUSH)
            fut.set_result(None)

        access.add_done_callback(on_ready)
        return fut

    def put(self, dst: int, object_key: Any, data: HeteroObject,
            on_done: Optional[str] = None) -> HFuture:
        """Remote put: overwrite the target's hetero_object (paper §4.2.4:
        reuses existing, pinned target memory — no receiver allocation)."""
        fut = HFuture()
        access = data.request_host(write=False)

        def on_ready(_):
            arr = np.array(access.get())
            data.release()
            msg = Message(msg_id=next(_msg_ids), kind="put", src=self.rank,
                          dst=dst, object_key=object_key, payload=arr,
                          handler=on_done)
            self.cluster.deliver(msg)
            self.stats["sent"] += 1
            self.stats["bytes_out"] += arr.nbytes
            fut.set_result(None)

        access.add_done_callback(on_ready)
        return fut

    def get(self, dst: int, object_key: Any, handler_name: str) -> HFuture:
        """Remote get: ask ``dst`` for object data; handler runs locally with
        the received hetero_object."""
        fut = HFuture()
        msg = Message(msg_id=next(_msg_ids), kind="get", src=self.rank,
                      dst=dst, object_key=object_key, handler=handler_name)
        self.cluster.deliver(msg)
        self.stats["sent"] += 1
        fut.set_result(None)
        return fut

    def register_object(self, key: Any, obj: HeteroObject) -> None:
        self.objects[key] = obj

    def route_to(self, handler_name: str, device_id: int) -> None:
        """Declare that payloads for ``handler_name`` will be consumed by
        tasks on local ``device_id`` — incoming DIRECT payloads land there
        directly instead of on the least-loaded fallback."""
        self.routes[handler_name] = device_id

    # ------------------------------------------------------------------
    # pump
    # ------------------------------------------------------------------
    def _flush_outgoing(self):
        ready = []
        with self._out_lock:
            still = []
            for access, meta, obj in self.outgoing:
                if access.done():
                    ready.append((access, meta, obj))
                else:
                    still.append((access, meta, obj))
            self.outgoing = still
        for access, meta, obj in ready:
            if meta.path == "direct":
                # device-aware interconnect (§3.2.3 Fig. 7): the NIC reads
                # device memory directly — the payload stays a device array
                space, arr = access.get()   # arr: private on-device clone
                if space == HOST:
                    # no device copy existed; fall back to the staged path
                    meta.path = "host"
            else:
                # host-staged (§3.2.3 Fig. 6): explicit staging copy
                arr = np.array(access.get())
                obj.release()
            nbytes = arr.nbytes
            if meta.path == "direct":
                self.stats["bytes_d2d"] += nbytes
            else:
                self.stats["bytes_staged"] += nbytes
            if meta.path != "direct" and nbytes <= INLINE_PAYLOAD_BYTES:
                meta.inline = np.asarray(arr).tobytes()  # §4.2.3 small msgs
                self.cluster.deliver(meta)
            else:
                self.cluster.deliver(meta)
                payload = Message(msg_id=meta.msg_id, kind="payload",
                                  src=self.rank, dst=meta.dst, payload=arr,
                                  path=meta.path)
                self.cluster.deliver(payload)
            self.stats["sent"] += 1
            self.stats["bytes_out"] += nbytes

    def _handle(self, msg: Message):
        if msg.kind == "meta":
            self.stats["received"] += 1
            if msg.payload_shape is None:
                self._invoke(msg, None)
            elif msg.inline is not None:
                arr = np.frombuffer(msg.inline, dtype=msg.payload_dtype
                                    ).reshape(msg.payload_shape).copy()
                obj = self.runtime.hetero_object(arr)
                self._invoke(msg, obj)
            else:
                self._pending_meta[msg.msg_id] = msg
        elif msg.kind == "payload":
            meta = self._pending_meta.pop(msg.msg_id, None)
            if meta is None:       # payload raced ahead of metadata
                self._pending_meta[msg.msg_id] = msg
                return
            obj = self._adopt_payload(msg, meta)
            self._invoke(meta, obj)
        elif msg.kind == "put":
            self.stats["received"] += 1
            target = self.objects.get(msg.object_key)
            if target is not None:
                fut = target.request_host(write=True)
                arr = fut.get()
                np.copyto(arr, msg.payload)
                target.release()
            if msg.handler:
                self._invoke(msg, target)
        elif msg.kind == "get":
            self.stats["received"] += 1
            src_obj = self.objects.get(msg.object_key)
            self.send(msg.src, msg.handler, src_obj,
                      user={"object_key": msg.object_key})

    def _landing_device(self, meta: Message) -> int:
        """Consumer-routed delivery: the sender's per-message
        ``consumer_device`` hint wins, then this rank's ``route_to``
        registration for the handler, then the handler's declared
        device-type affinity, and finally the residency ledger's
        least-loaded device — never a hardwired device 0."""
        ids = {d.info.device_id for d in self.runtime.devices}
        pref = meta.consumer_device
        if pref not in ids:      # absent or invalid hint: fall through
            pref = self.routes.get(meta.handler)
        return self.runtime.pick_landing_device(
            preferred=pref, device_type=H.affinity(meta.handler))

    def _adopt_payload(self, msg: Message, meta: Message) -> HeteroObject:
        """Land an incoming payload in the local runtime. DIRECT payloads
        (device arrays) are moved with one Device API transfer onto the
        consumer task's device (falling back to least-loaded) — never
        staged through host (paper §3.2.3 Fig. 7)."""
        if msg.path == "direct" and not isinstance(msg.payload, np.ndarray):
            dst = self.runtime._device(self._landing_device(meta))
            local = d2d_transfer(None, dst, msg.payload)
            self.stats["bytes_d2d"] += msg.payload.nbytes
            return self.runtime.adopt_device_array(local,
                                                   dst.info.device_id)
        self.stats["bytes_staged"] += msg.payload.nbytes
        return self.runtime.hetero_object(msg.payload)

    def _invoke(self, meta: Message, obj: Optional[HeteroObject]):
        fn = H.resolve(meta.handler)
        ctx = HandlerContext(self, meta)
        fn(ctx, obj)

    def _pump(self):
        while not self._stop:
            self._flush_outgoing()
            try:
                msg = self.inbox.get(timeout=0.001)
            except queue.Empty:
                continue
            if msg is None:
                return
            if msg is _FLUSH:
                continue          # woken to flush outgoing; loop does it
            try:
                self._handle(msg)
            except BaseException:   # a bad message must not kill the rank
                import traceback
                traceback.print_exc()

    def shutdown(self):
        self._stop = True
        self.inbox.put(None)
        self._thread.join(timeout=5)
        self.runtime.shutdown()


@dataclasses.dataclass
class HandlerContext:
    rank: Rank
    message: Message

    @property
    def user(self):
        return self.message.user

    def send(self, dst, handler_name, obj=None, **kw):
        return self.rank.send(dst, handler_name, obj, **kw)


class Cluster:
    """In-process rank set with a simulated network. ``latency_s`` and
    ``bw_bytes_per_s`` let benchmarks model interconnect behaviour; the
    'direct' path skips the host-staging cost the way GPU-aware MPI does."""

    def __init__(self, n_ranks: int, rt_config: Optional[RuntimeConfig] = None,
                 latency_s: float = 0.0, bw_bytes_per_s: float = 0.0):
        self.latency_s = latency_s
        self.bw = bw_bytes_per_s
        self.ranks = [Rank(self, r, rt_config) for r in range(n_ranks)]

    def deliver(self, msg: Message):
        if self.latency_s or (self.bw and msg.payload is not None):
            delay = self.latency_s
            if self.bw and msg.payload is not None:
                delay += msg.payload.nbytes / self.bw
            if delay > 0:
                time.sleep(delay)
        self.ranks[msg.dst].inbox.put(msg)

    def barrier(self, timeout: float = 60.0):
        deadline = time.time() + timeout
        for r in self.ranks:
            # outgoing queues drained + runtimes idle
            while True:
                with r._out_lock:
                    busy = bool(r.outgoing)
                busy = busy or not r.inbox.empty()
                if not busy:
                    break
                if time.time() > deadline:
                    raise TimeoutError("cluster barrier timeout")
                time.sleep(0.001)
        for r in self.ranks:
            r.runtime.barrier(timeout=max(deadline - time.time(), 1.0))

    def shutdown(self):
        for r in self.ranks:
            r.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()
