"""SPMD lowering of PREMA's communication patterns (hardware adaptation).

On a TPU pod there is no message-driven NIC: communication is compiled into
the program as ICI collectives. This module lowers the paper's patterns:

  handler payload / put / get  →  lax.ppermute (point-to-point)
  halo exchange (Jacobi)       →  paired ppermutes per face
  scatter of mobile chunks     →  all_to_all
  reduction handlers           →  psum

The host-staged path of §3.2.3 survives as ``host_round_trip`` for
host-mediated transfers (checkpoint, elastic rescale, data ingestion).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS


def ring_permute(x: jax.Array, axis_name: str, shift: int = 1) -> jax.Array:
    """Send x to rank+shift (ring) along a mesh axis — inside shard_map."""
    n = jax.lax.axis_size(axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return jax.lax.ppermute(x, axis_name, perm)


def halo_exchange_1d(block: jax.Array, axis_name: str, halo: int = 1,
                     wrap: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Exchange face slabs with ±1 neighbours along ``axis_name``.
    block: [..., L, ...] local slab, exchange along dim 0.
    Returns (lo_halo, hi_halo) received from the -1 / +1 neighbours
    (zeros at boundaries unless wrap)."""
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    hi_face = block[-halo:]          # send up
    lo_face = block[:halo]           # send down
    if wrap:
        perm_up = [(i, (i + 1) % n) for i in range(n)]
        perm_dn = [(i, (i - 1) % n) for i in range(n)]
    else:
        perm_up = [(i, i + 1) for i in range(n - 1)]
        perm_dn = [(i, i - 1) for i in range(1, n)]
    from_lo = jax.lax.ppermute(hi_face, axis_name, perm_up)   # my lo halo
    from_hi = jax.lax.ppermute(lo_face, axis_name, perm_dn)   # my hi halo
    if not wrap:
        zero = jnp.zeros_like(from_lo)
        from_lo = jnp.where(idx == 0, zero, from_lo)
        from_hi = jnp.where(idx == n - 1, jnp.zeros_like(from_hi), from_hi)
    return from_lo, from_hi


def spmd_put(x: jax.Array, axis_name: str, src: int, dst: int) -> jax.Array:
    """One-sided put: ``src``'s x replaces ``dst``'s x; other ranks keep
    theirs. Lowers to a single collective-permute pair."""
    moved = jax.lax.ppermute(x, axis_name, [(src, dst)])
    idx = jax.lax.axis_index(axis_name)
    return jnp.where(idx == dst, moved, x)


def spmd_get(x: jax.Array, axis_name: str, src: int) -> jax.Array:
    """Every rank receives src's x (get analogue): ppermute fan-out.

    A masked ``psum`` also works but pays an O(n)-bandwidth reduction for
    what is semantically a broadcast; a one-to-all ``ppermute`` fan-out
    moves each payload once per destination and keeps the source's value
    bit-identical (no add in the path)."""
    n = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    perm = [(src, d) for d in range(n) if d != src]
    moved = jax.lax.ppermute(x, axis_name, perm)
    return jnp.where(idx == src, x, moved)


def host_round_trip(x: jax.Array, device: Optional[jax.Device] = None
                    ) -> jax.Array:
    """Host-staged path (§3.2.3 without GPU-aware interconnect): device →
    host → (network) → host → device. Used by checkpoint/elastic paths."""
    host = np.asarray(x)
    return jax.device_put(host, device)
