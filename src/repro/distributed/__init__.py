from repro.distributed.elastic import ElasticController, WorkerHealth  # noqa: F401
from repro.distributed.handlers import handler, registered, resolve  # noqa: F401
from repro.distributed.messaging import Cluster, HandlerContext, Message, Rank  # noqa: F401
from repro.distributed.mobile_object import (MobileObject, MobilePtr,  # noqa: F401
                                             OwnerMap, block_distribution,
                                             rebalance_greedy)
from repro.distributed.overdecomp import (Chunk, DecompPlan,  # noqa: F401
                                          microbatch_plan, plan_decomposition)
