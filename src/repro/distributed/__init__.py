from repro.distributed.collectives_rt import (CollectiveAborted,  # noqa: F401
                                              CollectiveGroup)
from repro.distributed.elastic import (ElasticController,  # noqa: F401
                                       ElasticRuntime, WorkerHealth)
from repro.distributed.handlers import handler, registered, resolve  # noqa: F401
from repro.distributed.messaging import (Cluster, FaultInjector,  # noqa: F401
                                         HandlerContext, Message, Rank)
from repro.distributed.mobile_object import (MobileObject, MobilePtr,  # noqa: F401
                                             OwnerMap, block_distribution,
                                             rebalance_greedy)
from repro.distributed.overdecomp import (Chunk, DecompPlan,  # noqa: F401
                                          microbatch_plan, plan_decomposition)
