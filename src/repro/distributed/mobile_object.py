"""Mobile objects + owner map (paper §1.1): globally addressable,
location-independent containers. The owner map is the load-balancing lever —
migrating a mobile object is an owner-map update plus a data transfer, which
is how PREMA does implicit distributed load balancing and how we do
straggler mitigation (move chunks off a slow rank) and elastic rescale
(re-map chunks of a lost/added rank).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class MobilePtr:
    """Global name of a mobile object."""
    oid: int

    def __int__(self):
        return self.oid


class OwnerMap:
    """oid -> rank, replicated control state. Deterministic given the event
    log (assign/migrate), so every rank can replay it.

    Each entry may also carry a per-chunk **device hint** — the device id
    (on the owner rank) whose tasks consume the chunk. Migration executors
    pass it as ``Rank.send(..., consumer_device=...)``/``put(...)`` so the
    payload lands where the chunk's tasks run (ROADMAP follow-up d). A
    migration without a new hint clears the old one: device ids are local
    to the previous owner and would mis-route on the new rank."""

    def __init__(self):
        self._owner: Dict[int, int] = {}
        self._hints: Dict[int, int] = {}
        self.version = 0

    def assign(self, oid: int, rank: int,
               device_hint: Optional[int] = None) -> None:
        self._owner[oid] = rank
        if device_hint is not None:
            self._hints[oid] = device_hint
        self.version += 1

    def owner(self, oid: int) -> int:
        return self._owner[oid]

    def device_hint(self, oid: int) -> Optional[int]:
        """Consumer device id on the owner rank, if a hint is recorded."""
        return self._hints.get(oid)

    def set_device_hint(self, oid: int, device_id: Optional[int]) -> None:
        if device_id is None:
            self._hints.pop(oid, None)
        else:
            self._hints[oid] = device_id
        self.version += 1

    def migrate(self, oid: int, new_rank: int,
                device_hint: Optional[int] = None) -> None:
        self._owner[oid] = new_rank
        if device_hint is None:
            self._hints.pop(oid, None)
        else:
            self._hints[oid] = device_hint
        self.version += 1

    def owned_by(self, rank: int) -> List[int]:
        return [o for o, r in self._owner.items() if r == rank]

    def items(self):
        return self._owner.items()

    def __len__(self):
        return len(self._owner)


def block_distribution(n_objects: int, n_ranks: int) -> Dict[int, int]:
    """Contiguous block assignment (the paper's initial decomposition)."""
    return {i: min(i * n_ranks // n_objects, n_ranks - 1)
            for i in range(n_objects)}


def rebalance_greedy(loads: Dict[int, float], owner: OwnerMap,
                     chunk_load: Dict[int, float],
                     max_moves: int = 8) -> List[Tuple[int, int, int]]:
    """Greedy diffusion: move chunks from the most- to the least-loaded rank.
    Returns [(oid, src, dst)] migration plan; the caller executes transfers
    and applies owner.migrate. Used for straggler mitigation: a straggler's
    effective load is inflated by its slowdown factor."""
    plan: List[Tuple[int, int, int]] = []
    loads = dict(loads)
    for _ in range(max_moves):
        src = max(loads, key=loads.get)
        dst = min(loads, key=loads.get)
        if loads[src] - loads[dst] < 1e-9:
            break
        movable = [o for o in owner.owned_by(src)]
        if not movable:
            break
        # smallest chunk that helps
        movable.sort(key=lambda o: chunk_load.get(o, 1.0))
        best = None
        gap = loads[src] - loads[dst]
        for o in movable:
            w = chunk_load.get(o, 1.0)
            if w < gap:
                best = o
        if best is None:
            break
        w = chunk_load.get(best, 1.0)
        owner.migrate(best, dst)
        plan.append((best, src, dst))
        loads[src] -= w
        loads[dst] += w
    return plan


class MobileObject:
    """A chunk of application data bound to an owner rank. Holds a
    hetero_object on the owner; elsewhere it is just the pointer.

    ``meta["device"]`` (see ``device_hint``) records which of the owner's
    devices consumes this chunk. Migration executors that ship a chunk's
    data should pass it as ``Rank.send(..., consumer_device=...)`` so the
    payload lands where the chunk's tasks will run instead of on the
    landing fallback (wiring a built-in executor is a ROADMAP item)."""

    def __init__(self, ptr: Optional[MobilePtr] = None,
                 data: Any = None, meta: Optional[Dict[str, Any]] = None):
        self.ptr = ptr or MobilePtr(next(_ids))
        self.data = data            # HeteroObject on the owner rank
        self.meta = meta or {}

    @property
    def device_hint(self) -> Optional[int]:
        """Consumer device id on the owner rank, if known."""
        return self.meta.get("device")

    def __repr__(self):
        return f"MobileObject(oid={self.ptr.oid}, meta={self.meta})"
