"""Deterministic synthetic token pipeline with host-side sharding.

Production shape: each host process owns a slice of the global batch
(``host_index`` / ``host_count``); batches are generated deterministically
from (seed, step) so restarts resume bit-identically without data-state
checkpoints — the data pipeline is stateless by construction, which is the
cheapest form of fault tolerance.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Markov-ish synthetic LM stream: learnable structure (not pure noise) so
    a few hundred training steps show a decreasing loss curve."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.host_count
        rng = np.random.default_rng(cfg.seed)
        # fixed sparse bigram table: each token has 8 likely successors
        self._succ = rng.integers(
            0, cfg.vocab, size=(min(cfg.vocab, 4096), 8), dtype=np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 1000 + cfg.host_index)
        b, s = self.local_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=b)
        nsucc = self._succ.shape[0]
        for t in range(s):
            cur = toks[:, t] % nsucc
            choice = rng.integers(0, 8, size=b)
            noise = rng.random(b) < 0.1
            nxt = self._succ[cur, choice]
            nxt = np.where(noise, rng.integers(0, cfg.vocab, size=b), nxt)
            toks[:, t + 1] = nxt
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
