"""Production serving driver: batched prefill + decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import canon, get_config, get_smoke_config
from repro.launch.mesh import make_production_mesh, make_smoke_mesh
from repro.models import build_model, build_smoke
from repro.models.layers import unbox
from repro.models.sharding import use_sharding
from repro.serve import make_decode_step, make_prefill_step


class Engine:
    """Minimal batched engine: one prefill, then token-by-token decode with a
    capacity-allocated cache (prefill writes into the decode cache slots)."""

    def __init__(self, model, params, batch: int, max_len: int):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.batch = batch
        self._prefill = jax.jit(make_prefill_step(model))
        self._decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    def generate(self, tokens: jax.Array, gen: int, extra=None):
        b, s = tokens.shape
        cache0 = self.model.init_cache(b, self.max_len)
        batch = {"tokens": jnp.pad(tokens,
                                   ((0, 0), (0, self.max_len - s)))}
        if extra:
            batch.update(extra)
        # prefill over padded batch: simple engines prefill at fixed length;
        # we prefill exactly s tokens then decode
        batch["tokens"] = tokens
        nxt, cache = self._prefill(self.params, batch, cache0)
        # grow prefill cache (length s) into decode capacity
        def grow(a):
            if hasattr(a, "ndim"):
                for ax in range(1, min(a.ndim, 3)):
                    if a.shape[ax] == s and a.shape[-1] != s:
                        pad = [(0, 0)] * a.ndim
                        pad[ax] = (0, self.max_len - s)
                        return jnp.pad(a, pad)
            return a
        cache = jax.tree.map(grow, cache)
        out = [nxt]
        lengths = jnp.full((b,), s, jnp.int32)
        cur = nxt
        for _ in range(gen - 1):
            cur, cache = self._decode(self.params, cache, cur, lengths)
            lengths = lengths + 1
            out.append(cur)
        return jnp.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args(argv)

    arch = canon(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    model = build_smoke(cfg) if args.smoke else build_model(cfg)
    mesh = make_production_mesh() if args.production_mesh \
        else make_smoke_mesh(1, 1)

    with use_sharding(mesh):
        params, _ = unbox(model.init(jax.random.PRNGKey(0)))
        eng = Engine(model, params, args.batch,
                     args.prompt_len + args.gen)
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab)
        extra = {}
        if cfg.enc_dec:
            extra["frames"] = jnp.zeros((args.batch, cfg.encoder_seq,
                                         cfg.d_model))
        if cfg.frontend == "vision":
            extra["vision_embeds"] = jnp.zeros(
                (args.batch, cfg.frontend_tokens, cfg.d_model))
        t0 = time.time()
        out = eng.generate(tokens, args.gen, extra)
        dt = time.time() - t0
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("sample:", np.asarray(out[0][:12]))
    return out


if __name__ == "__main__":
    main()
