"""Roofline analysis from the compiled dry-run artifacts.

Three terms per (arch × shape) on the single-pod mesh:

    t_compute    = FLOPs / (chips × 197 TF/s)
    t_memory     = HBM bytes / (chips × 819 GB/s)
    t_collective = collective bytes per device / 50 GB/s per link

Measurement method (documented because XLA's cost model needs correcting):
``compiled.cost_analysis()`` counts every while-loop body ONCE, independent
of trip count — so anything under ``lax.scan`` (the layer stack, flash
attention's q/kv blocks, the chunked CE loss) is undercounted. We correct:

  1. layer-stack scan: probe lowerings with 0 layers (M0) and 1 period (M1)
     isolate the per-period body; corrected = M_full + (n_periods−1)·(M1−M0).
     This fixes flops, HBM bytes and collective bytes together (collectives
     live at layer level).
  2. flash-attention q/kv scans + CE-loss seq scan: corrected analytically —
     the block shapes and trip counts are static, so the uncounted work is
     (trips−1) × body cost. Compute-side attention/loss flops use the exact
     einsum formulas below.
  3. compute term primary source: the analytic FLOP model (exact for the
     math executed, matmul-dominated); the probe-corrected HLO flops are
     reported alongside as a cross-check.

MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference forward);
ratio MODEL/analytic exposes remat + attention overhead honestly.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.configs import (GLOBAL_ATTN, LOCAL_ATTN, RGLRU, SSD,
                           SHAPES_BY_NAME, ModelConfig, get_config,
                           shapes_for, ARCH_IDS)

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

FLASH_BLOCK = 512
LOSS_CHUNK = 1024


# ---------------------------------------------------------------------------
# analytic FLOP model (forward; totals across the whole job)
# ---------------------------------------------------------------------------

def _layer_kinds(cfg: ModelConfig) -> List[str]:
    return [cfg.layer_pattern[i % len(cfg.layer_pattern)]
            for i in range(cfg.n_layers)]


def analytic_forward_flops(cfg: ModelConfig, shape) -> Dict[str, float]:
    """Returns {'proj':…, 'attn':…, 'mlp':…, 'loss':…, 'total':…} global
    forward FLOPs for one step of the given shape."""
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    D, H, K, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                   cfg.resolved_head_dim)
    tokens = B * (S if kind != "decode" else 1)
    f_proj = f_attn = f_mlp = 0.0
    for lk in _layer_kinds(cfg):
        if lk in (GLOBAL_ATTN, LOCAL_ATTN):
            f_proj += tokens * 2 * D * hd * (2 * H + 2 * K)
            if kind == "decode":
                ctx = min(cfg.window, S) if lk == LOCAL_ATTN else S
                f_attn += tokens * 4 * H * hd * ctx
            else:
                ctx = 2 * min(cfg.window, S) if lk == LOCAL_ATTN else S
                f_attn += B * S * 4 * H * hd * ctx  # our lowering: all blocks
            if cfg.moe is not None:
                m = cfg.moe
                f_mlp += tokens * 2 * D * m.num_experts          # router
                mult = 6 if cfg.gated_mlp else 4
                f_mlp += tokens * m.top_k * 1.25 * mult * D * m.d_ff_expert
                if m.d_ff_shared:
                    f_mlp += tokens * mult * D * m.d_ff_shared
            else:
                f_mlp += tokens * (6 if cfg.gated_mlp else 4) * D * cfg.d_ff
        elif lk == SSD:
            sc = cfg.ssm
            di = sc.expand * D
            gn = sc.ngroups * sc.d_state
            nh = di // sc.headdim
            f_proj += tokens * 2 * D * (2 * di + 2 * gn + nh) + \
                tokens * 2 * di * D
            if kind == "decode":
                f_attn += tokens * 4 * nh * sc.headdim * sc.d_state
            else:
                q = min(sc.chunk_size, S)
                f_attn += B * S * 2 * (q * gn + q * di + 2 * di * sc.d_state)
        elif lk == RGLRU:
            w = cfg.rglru.lru_width or D
            bd = w // cfg.n_heads
            f_proj += tokens * (2 * D * w * 2 + 2 * w * D)
            f_attn += tokens * (2 * 2 * w * bd + 10 * w)
            f_mlp += tokens * (6 if cfg.gated_mlp else 4) * D * cfg.d_ff
    if cfg.enc_dec:
        enc_tokens = B * cfg.encoder_seq
        enc_t_pad = cfg.encoder_seq + ((-cfg.encoder_seq) % 128)
        for _ in range(cfg.n_encoder_layers):
            f_proj += enc_tokens * 2 * D * hd * (2 * H + 2 * K) * \
                (1 if kind != "decode" else 0)
            if kind != "decode":
                f_attn += B * cfg.encoder_seq * 4 * H * hd * cfg.encoder_seq
                f_mlp += enc_tokens * 4 * D * cfg.d_ff
        # decoder cross attention
        for _ in range(cfg.n_layers):
            f_proj += tokens * 2 * D * hd * 2 * H    # q,o (kv cached/enc)
            if kind != "decode":
                f_proj += enc_tokens * 2 * D * hd * 2 * K
            f_attn += tokens * 4 * H * hd * enc_t_pad
    # loss / unembed
    if kind == "train":
        f_loss = tokens * 2 * D * cfg.vocab
    else:
        f_loss = B * 2 * D * cfg.vocab       # last position / decode step
    total = f_proj + f_attn + f_mlp + f_loss
    return {"proj": f_proj, "attn": f_attn, "mlp": f_mlp, "loss": f_loss,
            "total": total}


def analytic_total_flops(cfg: ModelConfig, shape, remat: str) -> float:
    fwd = analytic_forward_flops(cfg, shape)["total"]
    if shape.kind != "train":
        return fwd
    mult = 4.0 if remat == "full" else 3.3   # fwd + bwd(2) + recompute
    return fwd * mult


# ---------------------------------------------------------------------------
# probe-based HLO correction
# ---------------------------------------------------------------------------

def _load(results_dir: str, arch: str, shape: str, opt: str,
          probe: Optional[int] = None, pod: str = "pod1") -> Optional[Dict]:
    tag = f"{arch}__{shape}__{pod}__{opt}"
    if probe is not None:
        tag += f"__probe{probe}"
    path = os.path.join(results_dir, tag + ".json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        d = json.load(f)
    return None if ("error" in d or d.get("skipped")) else d


def corrected_hlo(full: Dict, p0: Optional[Dict], p1: Optional[Dict],
                  cfg: ModelConfig) -> Dict[str, float]:
    """Apply the layer-scan correction to flops / bytes / collectives."""
    n_periods = cfg.n_layers // len(cfg.layer_pattern)
    out = {}
    for key in ("flops_per_device", "bytes_per_device",
                "collective_total_bytes"):
        v = full.get(key, 0.0) or 0.0
        if p0 is not None and p1 is not None and n_periods > 1:
            body = max((p1.get(key, 0.0) or 0.0) - (p0.get(key, 0.0) or 0.0),
                       0.0)
            v = v + (n_periods - 1) * body
        out[key] = v
    # per-collective-type breakdown with the same scaling
    colls = dict(full.get("collective_bytes_per_device", {}))
    if p0 is not None and p1 is not None and n_periods > 1:
        c0 = p0.get("collective_bytes_per_device", {})
        c1 = p1.get("collective_bytes_per_device", {})
        for k in colls:
            body = max(c1.get(k, 0) - c0.get(k, 0), 0)
            colls[k] = colls[k] + (n_periods - 1) * body
    out["collectives"] = colls
    out["collective_total_bytes"] = float(sum(colls.values())) if colls else \
        out["collective_total_bytes"]
    return out


def flash_scan_bytes_correction(cfg: ModelConfig, shape, chips: int) -> float:
    """Uncounted HBM traffic of flash-scan iterations: each q block re-reads
    the full K/V stream (trips−1 of which the HLO missed)."""
    if shape.kind == "decode":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    hd, K = cfg.resolved_head_dim, cfg.n_kv_heads
    n_global = sum(1 for k in _layer_kinds(cfg) if k == GLOBAL_ATTN)
    if cfg.enc_dec:
        n_global += 0  # encoder handled approximately by probe scaling
    if n_global == 0 or S <= FLASH_BLOCK:
        return 0.0
    nq = S // min(FLASH_BLOCK, S)
    kv_bytes = 2 * B * S * K * hd * 2          # K+V, bf16
    return n_global * (nq - 1) * kv_bytes / chips


def loss_scan_flops(cfg: ModelConfig, shape, chips: int) -> float:
    if shape.kind != "train":
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    n_chunks = max(S // LOSS_CHUNK, 1)
    per_chunk = 2 * B * LOSS_CHUNK * cfg.d_model * cfg.vocab
    return (n_chunks - 1) * per_chunk / chips


# ---------------------------------------------------------------------------
# table builder
# ---------------------------------------------------------------------------

def analyze_cell(results_dir: str, arch: str, shape_name: str,
                 opt: str = "baseline") -> Optional[Dict[str, Any]]:
    full = _load(results_dir, arch, shape_name, opt)
    if full is None:
        return None
    p0 = _load(results_dir, arch, shape_name, opt, probe=0)
    p1 = _load(results_dir, arch, shape_name, opt, probe=1)
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    chips = full["chips"]
    hlo = corrected_hlo(full, p0, p1, cfg)

    remat = "full"   # both levels keep full remat (see §Perf iteration 2)
    ana_flops = analytic_total_flops(cfg, shape, remat) / chips
    hlo_flops = hlo["flops_per_device"] + loss_scan_flops(cfg, shape, chips)
    hbm = hlo["bytes_per_device"] + flash_scan_bytes_correction(
        cfg, shape, chips)
    coll = hlo["collective_total_bytes"]

    t_compute = ana_flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)

    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    model_flops = (6 if shape.kind == "train" else 2) * n_active * tokens \
        / chips
    bound = max(terms.values())
    hints = {
        "compute": "reduce recompute (remat policy) / skip masked attention "
                   "blocks / higher arithmetic-intensity kernel fusion",
        "memory": "sequence-parallel activations, smaller remat window, "
                  "bf16 master-free optimizer or fused loss to cut HBM "
                  "round-trips",
        "collective": "reshard to cut per-layer all-gathers "
                      "(ZeRO placement / SP), fuse small all-reduces, "
                      "overlap collectives behind the scan",
    }
    return {
        "arch": arch, "shape": shape_name, "opt": opt, "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "analytic_flops_per_device": ana_flops,
        "hlo_flops_corrected": hlo_flops,
        "hlo_flops_raw": full.get("flops_per_device"),
        "hbm_bytes_corrected": hbm,
        "collective_bytes_corrected": coll,
        "collectives": hlo["collectives"],
        "model_flops_per_device": model_flops,
        "model_vs_analytic": model_flops / ana_flops if ana_flops else None,
        "step_time_bound_s": bound,
        "roofline_fraction": t_compute / bound if bound else None,
        "memory_temp_bytes": full.get("temp_size_in_bytes"),
        "memory_args_bytes": full.get("argument_size_in_bytes"),
        "hint": hints[bottleneck],
    }


def build_table(results_dir: str, opt: str = "baseline") -> List[Dict]:
    rows = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            row = analyze_cell(results_dir, arch, shape.name, opt)
            if row:
                rows.append(row)
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="benchmarks/results/dryrun")
    ap.add_argument("--opt", default="baseline")
    ap.add_argument("--out", default="benchmarks/results/roofline.json")
    args = ap.parse_args()
    rows = build_table(args.results, args.opt)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    hdr = (f"{'arch':24s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
           f"{'coll':>9s} {'bottleneck':>10s} {'roofline%':>9s} "
           f"{'model/hlo':>9s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:24s} {r['shape']:12s} "
              f"{r['t_compute_s']*1e3:8.1f}ms {r['t_memory_s']*1e3:8.1f}ms "
              f"{r['t_collective_s']*1e3:8.1f}ms {r['bottleneck']:>10s} "
              f"{(r['roofline_fraction'] or 0)*100:8.1f}% "
              f"{(r['model_vs_analytic'] or 0):9.2f}")


if __name__ == "__main__":
    main()
