"""Elastic training driver: failure detection → mesh shrink → restore →
continue; growth is the same flow in reverse.

This is the end-to-end wiring of the fault-tolerance substrate:
ElasticController (health/plan) + Checkpointer (mesh-agnostic restore) +
the stateless data pipeline (replay from step counters). The demo entry
point simulates losing half the data-parallel axis mid-run and continues on
the survivors, bit-identically to a run that never used the lost chips
(per-step determinism comes from (seed, step), not from world size).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.elastic_train --steps 12 --fail-at 6
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import canon, get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.distributed import ElasticController
from repro.launch.mesh import opt_specs
from repro.models import build_smoke
from repro.models.layers import unbox
from repro.models.sharding import use_sharding
from repro.train import (AdamWConfig, TrainConfig, abstract_train_state,
                         init_train_state, make_train_step)


def _mesh_for(devices):
    return jax.sharding.Mesh(np.array(devices).reshape(len(devices), 1),
                             ("data", "model"))


def run_elastic(arch: str = "yi_9b", steps: int = 12, fail_at: int = 6,
                ckpt_dir: str = "/tmp/repro_elastic", seed: int = 0):
    """Returns (losses, world_sizes) across the failure boundary."""
    cfg = get_smoke_config(arch)
    model = build_smoke(cfg)
    tcfg = TrainConfig(opt=AdamWConfig(lr_peak=1e-3, warmup_steps=2,
                                       total_steps=steps))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8, seed=seed))
    ck = Checkpointer(ckpt_dir, keep=2, async_save=False)
    all_devices = jax.devices()
    ec = ElasticController(range(len(all_devices)), heartbeat_timeout=1e9)

    losses, worlds = [], []

    def train_span(devices, start, end, restore):
        mesh = _mesh_for(devices)
        with use_sharding(mesh):
            step_fn = jax.jit(make_train_step(model, tcfg),
                              donate_argnums=(0,))
            if restore:
                abs_state = abstract_train_state(model)
                state = ck.restore_latest(abs_state)
            else:
                state = init_train_state(model, jax.random.PRNGKey(seed))
            for i in range(start, end):
                batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
                state, metrics = step_fn(state, batch)
                losses.append(float(metrics["loss"]))
                worlds.append(len(devices))
            ck.save(end, state)
        return state

    # healthy span on the full world
    train_span(all_devices, 0, fail_at, restore=False)

    # failure: half the data axis goes silent → shrink plan → resume from
    # the last committed checkpoint on the survivors
    n_dead = len(all_devices) // 2
    for w in range(len(all_devices) - n_dead, len(all_devices)):
        ec.health[w].last_heartbeat = -1.0
        ec.health[w].alive = False
    survivors = all_devices[:len(all_devices) - n_dead]
    train_span(survivors, fail_at, steps, restore=True)
    return losses, worlds


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--fail-at", type=int, default=6)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_elastic")
    args = ap.parse_args(argv)
    losses, worlds = run_elastic(canon(args.arch), args.steps, args.fail_at,
                                 args.ckpt_dir)
    for i, (l, w) in enumerate(zip(losses, worlds)):
        marker = "  <- shrunk world" if i and worlds[i - 1] != w else ""
        print(f"step {i:3d} world={w} loss={l:.4f}{marker}")
    print("elastic run complete")
    return losses, worlds


if __name__ == "__main__":
    main()
