"""Per-cell configuration auto-tuning.

The §Perf sweeps show no single lowering wins everywhere: sequence
parallelism is a 2.4× win for gemma3 training but a 0.75× regression for
recurrentgemma (the RG-LRU associative scan needs the full sequence per
shard), and seq-sharded KV decode only pays when KV heads don't divide the
model axis. A deployment therefore picks per-(arch × shape) configs from the
dry-run roofline — this module materializes that choice.

    PYTHONPATH=src python -m repro.launch.autotune
      → benchmarks/results/tuned_configs.json   (consulted by launchers)
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.configs import ARCH_IDS, get_config, shapes_for
from repro.launch import roofline as R

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
DRY = os.path.join(REPO, "benchmarks", "results", "dryrun")


def tune(results_dir: str = DRY) -> Dict[str, Dict]:
    base = {(r["arch"], r["shape"]): r
            for r in R.build_table(results_dir, "baseline")}
    opt = {(r["arch"], r["shape"]): r
           for r in R.build_table(results_dir, "opt")}
    tuned: Dict[str, Dict] = {}
    for key, b in base.items():
        cands = {"baseline": b}
        if key in opt:
            cands["opt"] = opt[key]
        pick = min(cands, key=lambda k: cands[k]["step_time_bound_s"])
        r = cands[pick]
        tuned[f"{key[0]}__{key[1]}"] = {
            "config": pick,
            "step_bound_s": r["step_time_bound_s"],
            "bottleneck": r["bottleneck"],
            "roofline_fraction": r["roofline_fraction"],
            "speedup_vs_baseline": (
                b["step_time_bound_s"] / r["step_time_bound_s"]),
        }
    return tuned


def main():
    tuned = tune()
    out = os.path.join(REPO, "benchmarks", "results", "tuned_configs.json")
    with open(out, "w") as f:
        json.dump(tuned, f, indent=2)
    n_opt = sum(1 for v in tuned.values() if v["config"] == "opt")
    import numpy as np
    sp = [v["speedup_vs_baseline"] for v in tuned.values()]
    print(f"tuned {len(tuned)} cells: {n_opt} pick 'opt', "
          f"{len(tuned) - n_opt} keep 'baseline'")
    print(f"geomean speedup vs always-baseline: "
          f"{float(np.exp(np.mean(np.log(sp)))):.2f}x")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
