"""Production mesh construction + sharding resolution for program states.

``make_production_mesh`` is a function (never a module-level constant) so that
importing this module does not touch JAX device state.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.models.sharding import resolve_spec


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(data: int = 1, model: int = 1) -> Mesh:
    return jax.make_mesh((data, model), ("data", "model"))


# ---------------------------------------------------------------------------
# Spec builders
# ---------------------------------------------------------------------------

def param_specs(abs_params, axes_tree, mesh: Mesh):
    """NamedShardings for a param tree given its logical axes tree."""
    return jax.tree.map(
        lambda sds, ax: NamedSharding(
            mesh, resolve_spec(ax, shape=sds.shape, mesh=mesh)),
        abs_params, axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def zero_shard(spec: PS, shape: Tuple[int, ...], mesh: Mesh,
               zero_axes: Tuple[str, ...] = ("data",)) -> PS:
    """Add ZeRO-1 sharding: place ``zero_axes`` on the first unsharded dim
    whose size divides. Leaves the spec unchanged if nothing fits."""
    za = tuple(a for a in zero_axes if a in mesh.shape)
    if not za:
        return spec
    zsize = int(np.prod([mesh.shape[a] for a in za]))
    parts = list(spec) + [None] * (len(shape) - len(spec))
    used = set()
    for p in parts:
        if p is None:
            continue
        used.update(p if isinstance(p, tuple) else (p,))
    if any(a in used for a in za):
        return spec
    for i, (p, s) in enumerate(zip(parts, shape)):
        if p is None and s % zsize == 0 and s > 0:
            parts[i] = za if len(za) > 1 else za[0]
            return PS(*parts)
    return spec


def opt_specs(abs_state, axes_tree, mesh: Mesh, zero: bool = True):
    """Shardings for a TrainState: params get their natural specs; m/v/master
    additionally get ZeRO-1 sharding over the data axis."""
    p_specs = param_specs(
        jax.tree.map(lambda x: x, abs_state.params), axes_tree, mesh)

    def zspec(sds, ax):
        spec = resolve_spec(ax, shape=sds.shape, mesh=mesh)
        if zero:
            spec = zero_shard(spec, sds.shape, mesh)
        return NamedSharding(mesh, spec)

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    m_specs = jax.tree.map(zspec, abs_state.opt.m, axes_tree, is_leaf=is_ax)
    v_specs = jax.tree.map(zspec, abs_state.opt.v, axes_tree, is_leaf=is_ax)
    w_specs = jax.tree.map(zspec, abs_state.opt.master, axes_tree,
                           is_leaf=is_ax)
    from repro.train.optimizer import AdamWState, TrainState
    return TrainState(
        params=p_specs,
        opt=AdamWState(step=NamedSharding(mesh, PS()), m=m_specs, v=v_specs,
                       master=w_specs))


def batch_specs(shape_kind: str, mesh: Mesh, global_batch: int,
                seq_shard_kv: bool = False) -> Dict[str, NamedSharding]:
    """Input shardings for train/prefill/decode batches."""
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    baxes = data_axes if global_batch % dsize == 0 else None
    if baxes is not None and len(baxes) == 1:
        baxes = baxes[0]
    b = PS(baxes)
    return {"batch": b, "scalar": PS()}


def cache_specs(abs_cache, mesh: Mesh, cfg, *, seq_shard: bool = False,
                seq_axis: Optional[str] = None):
    """Shardings for a KV/recurrent cache pytree.

    Leaf layouts (by layer kind and role):
      attn k/v   : [..., B, T, K, D]  (stacked leading layer dims optional)
      ssd conv   : [..., B, W-1, C]    (replicated over model — DP-only SSD)
      ssd state  : [..., B, H, P, N]
      rglru conv : [..., B, W-1, lru]  (lru dim shards over model)
      rglru state: [..., B, lru]
    Batch shards over the data axes when divisible; otherwise (``seq_shard``)
    the attention T dim shards over 'data' (long-context decode).
    """
    from repro.configs.base import RGLRU, SSD

    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dsize = int(np.prod([mesh.shape[a] for a in data_axes]))
    msize = mesh.shape.get("model", 1)
    baxes = data_axes if len(data_axes) > 1 else data_axes[0]
    _, rem = (cfg.n_layers // len(cfg.layer_pattern),
              tuple(cfg.layer_pattern[:cfg.n_layers % len(cfg.layer_pattern)]))

    def kind_of(path) -> str:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(p.key)
            elif hasattr(p, "idx"):
                keys.append(p.idx)
        if keys and keys[0] == "periods":
            return cfg.layer_pattern[keys[1]]
        if keys and isinstance(keys[0], str) and keys[0].startswith("rem_"):
            return rem[int(keys[0][4:])]
        return "global_attn"  # encdec decoder self/cross caches

    def leaf_spec(path, sds):
        role = None
        for p in reversed(path):
            if hasattr(p, "key"):
                role = p.key
                break
        kind = kind_of(path)
        shape, nd = sds.shape, len(sds.shape)
        parts: list = [None] * nd
        if role in ("k", "v"):
            b_dim, t_dim, k_dim = nd - 4, nd - 3, nd - 2
            if shape[b_dim] % dsize == 0:
                parts[b_dim] = baxes
            elif seq_shard and "data" in mesh.shape and \
                    shape[t_dim] % mesh.shape["data"] == 0:
                parts[t_dim] = "data"
            if seq_axis is not None and parts[t_dim] is None \
                    and seq_axis in mesh.shape \
                    and shape[t_dim] % mesh.shape[seq_axis] == 0:
                parts[t_dim] = seq_axis
            if shape[k_dim] % msize == 0 and msize > 1 \
                    and seq_axis != "model":
                parts[k_dim] = "model"
        else:
            b_dim = nd - (3 if role == "conv" else
                          4 if role == "state" and kind == SSD else 2)
            b_dim = max(b_dim, 0)
            if shape[b_dim] % dsize == 0:
                parts[b_dim] = baxes
            if kind == RGLRU and shape[-1] % msize == 0 and msize > 1 \
                    and nd - 1 != b_dim:
                parts[-1] = "model"
        return NamedSharding(mesh, PS(*parts))

    return jax.tree_util.tree_map_with_path(leaf_spec, abs_cache)
