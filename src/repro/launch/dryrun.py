import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything else follows.
import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import (SHAPES_BY_NAME, get_config, shapes_for, canon,
                           ARCH_IDS)
from repro.launch.mesh import (batch_specs, cache_specs, make_production_mesh,
                               opt_specs, param_specs)
from repro.models import build_model
from repro.models.layers import unbox
from repro.models.model_zoo import Model
from repro.models.transformer import Flags
from repro.models.sharding import use_sharding
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import TrainState, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step

from jax.sharding import NamedSharding, PartitionSpec as PS

# TPU v5e hardware constants (per chip) — see brief.
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text.
    Shapes in the partitioned module are per-device."""
    out = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        for coll in COLLECTIVES:
            # match " = <shape> all-reduce(" etc.; exclude -start/-done pairs
            # counting twice (count only the -start or the plain form)
            token = f" {coll}("
            token_start = f" {coll}-start("
            use = None
            if token in stripped:
                use = stripped.split(token, 1)
            elif token_start in stripped:
                use = stripped.split(token_start, 1)
            if use is None:
                continue
            operands = use[1]
            total = sum(_shape_bytes(m.group(1), m.group(2))
                        for m in _SHAPE_RE.finditer(operands))
            if total == 0:
                # fall back to the result shape on the lhs
                m = _SHAPE_RE.search(use[0])
                if m:
                    total = _shape_bytes(m.group(1), m.group(2))
            out[coll] += total
            break
    return out


def _flags_for(shape_kind: str, seq_shard: bool, opt_level: str) -> Flags:
    """opt_level 'baseline' = paper-faithful lowering; 'opt' = the winning
    configuration from the §Perf hillclimb: full remat (lowest live memory)
    + sequence parallelism + seq-sharded KV decode."""
    return Flags(
        remat="full",
        moe_mode="ep",
        seq_shard_kv="data" if seq_shard else None,
        param_dtype=jnp.bfloat16,
        loss_chunk=1024,
        flash_block=512,
    )


def _rules_for(opt_level: str) -> Dict[str, Any]:
    if opt_level == "opt":
        # beyond-paper: sequence-parallel activations at layer boundaries
        return {"act_seq": "model"}
    return {}


# Named optimization stacks for §Perf hillclimbing. Each entry:
# (extra_flags, extra_rules, over_decompose, cache_seq_axis)
VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    # paper-faithful technique: over-decomposition (microbatch pipeline)
    "od2": dict(over_decompose=2),
    "od4": dict(over_decompose=4),
    "od8": dict(over_decompose=8),
    # beyond-paper ladder
    "dots": dict(extra_flags={"remat": "dots"}),
    "dots_sp": dict(extra_flags={"remat": "dots"},
                    extra_rules={"act_seq": "model"}),
    "dots_sp_od4": dict(extra_flags={"remat": "dots"},
                        extra_rules={"act_seq": "model"}, over_decompose=4),
    "dots_sp_od8": dict(extra_flags={"remat": "dots"},
                        extra_rules={"act_seq": "model"}, over_decompose=8),
    # SP with full remat: bytes of SP + the low live-memory of full remat
    "sp": dict(extra_rules={"act_seq": "model"}),
    "sp_od4": dict(extra_rules={"act_seq": "model"}, over_decompose=4),
    "sp_od8": dict(extra_rules={"act_seq": "model"}, over_decompose=8),
    # decode: seq-sharded KV over the model axis (kv-head-replicated archs)
    "kvseq_model": dict(extra_flags={"seq_shard_kv": "model"},
                        cache_seq_axis="model"),
    # mamba2: smaller SSD chunk (halves the decay-matrix traffic)
    "ssd_chunk128": dict(ssd_chunk=128),
    "ssd_chunk128_dots_sp": dict(ssd_chunk=128,
                                 extra_flags={"remat": "dots"},
                                 extra_rules={"act_seq": "model"}),
    "loss_chunk512": dict(extra_flags={"loss_chunk": 512}),
    # int8+EF compression of the cross-pod gradient reduction (use with
    # --multi-pod; see train/compression.py). vocab replicated: the XLA SPMD
    # partitioner CHECK-fails on a vocab-sharded embedding-grad scatter
    # inside a partially-manual region (XLA limitation, see EXPERIMENTS.md)
    "compress_pod": dict(train_compress=True, extra_rules={"vocab": None}),
}


def abstract_boxed(model: Model):
    boxed = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    return unbox(boxed)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               opt_level: str = "baseline", over_decompose: int = 1,
               extra_rules: Optional[Dict[str, Any]] = None,
               extra_flags: Optional[Dict[str, Any]] = None,
               probe: Optional[int] = None,
               cache_seq_axis: Optional[str] = None,
               ssd_chunk: Optional[int] = None,
               train_compress: bool = False) -> Dict[str, Any]:
    """probe=0: 0-layer model (scan/overhead-free baseline); probe=k: model
    with exactly k periods. Used by launch.roofline to correct XLA's
    count-scan-body-once cost accounting (see EXPERIMENTS.md §Method)."""
    import dataclasses as _dc
    cfg = get_config(arch)
    if probe is not None:
        period = len(cfg.layer_pattern)
        cfg = _dc.replace(cfg, n_layers=probe * period,
                          n_encoder_layers=(probe if cfg.enc_dec else 0))
    if ssd_chunk is not None and cfg.ssm is not None:
        cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, chunk_size=ssd_chunk))
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in shapes_for(cfg):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "full-attention arch skips long_500k (see DESIGN)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    seq_shard = (shape.kind == "decode"
                 and shape.global_batch % mesh.shape["data"] != 0)
    flags = _flags_for(shape.kind, seq_shard, opt_level)
    if opt_level == "opt" and shape.kind == "decode" and not seq_shard \
            and cfg.n_kv_heads % mesh.shape.get("model", 1) != 0 \
            and not cfg.attention_free:
        # hillclimb winner for kv-head-replicated GQA: seq-sharded KV cache
        import dataclasses as _dc2
        flags = _dc2.replace(flags, seq_shard_kv="model")
        cache_seq_axis = cache_seq_axis or "model"
    if extra_flags:
        import dataclasses as _dc
        flags = _dc.replace(flags, **extra_flags)
    rules = _rules_for(opt_level)
    if extra_rules:
        rules.update(extra_rules)
    model = build_model(cfg, flags)

    def input_shardings(in_specs):
        """Shard dim 0 (batch) over the widest dividing data-axis group."""
        def for_one(v):
            parts: list = [None] * v.ndim
            cands = [tuple(a for a in ("pod", "data") if a in mesh.shape),
                     ("data",)]
            for axes_ in cands:
                size = int(np.prod([mesh.shape[a] for a in axes_]))
                if v.shape[0] % size == 0:
                    parts[0] = axes_ if len(axes_) > 1 else axes_[0]
                    break
            return NamedSharding(mesh, PS(*parts))
        return {k: for_one(v) for k, v in in_specs.items()}

    t0 = time.time()
    with use_sharding(mesh, rules):
        params_abs, axes = abstract_boxed(model)
        in_specs = model.input_specs(shape)
        batch_shardings = input_shardings(in_specs)
        if shape.kind == "train":
            compress = train_compress and "pod" in mesh.shape
            n_pods = mesh.shape.get("pod", 1)

            def mk_state(p):
                ef = None
                if compress:
                    ef = jax.tree.map(
                        lambda q: jnp.zeros((n_pods,) + q.shape, jnp.float32),
                        p)
                return TrainState(params=p, opt=init_opt_state(p), ef=ef)

            state_abs = jax.eval_shape(mk_state, params_abs)
            state_spec = opt_specs(state_abs, axes, mesh)
            if compress:
                ef_spec = jax.tree.map(
                    lambda _: NamedSharding(mesh, PS("pod")),
                    state_abs.ef)
                import dataclasses as _dc3
                state_spec = _dc3.replace(state_spec, ef=ef_spec)
            step = make_train_step(model, TrainConfig(
                over_decompose=over_decompose,
                compress_pod_grads=compress),
                param_axes=axes if compress else None)
            jitted = jax.jit(step,
                             in_shardings=(state_spec, batch_shardings),
                             out_shardings=(state_spec, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_abs, in_specs)
        elif shape.kind == "prefill":
            pspec = param_specs(params_abs, axes, mesh)
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspec = cache_specs(cache_abs, mesh, cfg, seq_shard=False)
            step = make_prefill_step(model)
            jitted = jax.jit(step,
                             in_shardings=(pspec, batch_shardings, cspec),
                             out_shardings=(None, cspec),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, in_specs, cache_abs)
        else:  # decode
            pspec = param_specs(params_abs, axes, mesh)
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspec = cache_specs(cache_abs, mesh, cfg, seq_shard=seq_shard,
                                seq_axis=cache_seq_axis)
            step = make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(pspec, cspec,
                                           batch_shardings["tokens"],
                                           batch_shardings["lengths"]),
                             out_shardings=(None, cspec),
                             donate_argnums=(1,))
            lowered = jitted.lower(
                params_abs, cache_abs, in_specs["tokens"],
                in_specs["lengths"])
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "chips": n_chips,
        "opt_level": opt_level, "over_decompose": over_decompose,
        "seq_shard_kv": seq_shard, "probe": probe,
        "n_layers": cfg.n_layers, "period": len(cfg.layer_pattern),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        result["flops_per_device"] = float(ca.get("flops", -1))
        result["bytes_per_device"] = float(ca.get("bytes accessed", -1))
    except Exception as e:  # pragma: no cover
        result["cost_analysis_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                result[attr] = int(v)
    except Exception as e:  # pragma: no cover
        result["memory_analysis_error"] = str(e)
    try:
        hlo = compiled.as_text()
        coll = parse_collective_bytes(hlo)
        result["collective_bytes_per_device"] = coll
        result["collective_total_bytes"] = int(sum(coll.values()))
        result["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # pragma: no cover
        result["hlo_error"] = str(e)

    # roofline terms (seconds per step, per chip)
    flops = result.get("flops_per_device", 0.0)
    hbm = result.get("bytes_per_device", 0.0)
    coll_b = result.get("collective_total_bytes", 0)
    result["t_compute"] = flops / PEAK_FLOPS if flops > 0 else None
    result["t_memory"] = hbm / HBM_BW if hbm > 0 else None
    result["t_collective"] = coll_b / ICI_BW
    terms = {"compute": result["t_compute"] or 0.0,
             "memory": result["t_memory"] or 0.0,
             "collective": result["t_collective"] or 0.0}
    result["bottleneck"] = max(terms, key=terms.get)
    # model flops: 6·N_active·D(train) / 2·N·D(inference fwd)
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    result["model_flops_per_device"] = mult * n_active * tokens / n_chips
    if flops > 0:
        result["model_vs_hlo_flops"] = result["model_flops_per_device"] / flops
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--opt-level", default="baseline",
                    choices=["baseline", "opt"])
    ap.add_argument("--over-decompose", type=int, default=1)
    ap.add_argument("--probe", type=int, default=None)
    ap.add_argument("--variant", default=None, choices=sorted(VARIANTS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    kw: Dict[str, Any] = dict(multi_pod=args.multi_pod,
                              opt_level=args.opt_level,
                              over_decompose=args.over_decompose,
                              probe=args.probe)
    if args.variant:
        v = VARIANTS[args.variant]
        kw["extra_flags"] = v.get("extra_flags")
        kw["extra_rules"] = v.get("extra_rules")
        kw["cache_seq_axis"] = v.get("cache_seq_axis")
        kw["ssd_chunk"] = v.get("ssd_chunk")
        kw["train_compress"] = v.get("train_compress", False)
        if "over_decompose" in v:
            kw["over_decompose"] = v["over_decompose"]
    res = lower_cell(canon(args.arch), args.shape, **kw)
    if args.variant:
        res["variant"] = args.variant
    js = json.dumps(res, indent=2, default=str)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)


if __name__ == "__main__":
    main()
