"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 100 --over-decompose 4 --checkpoint-dir /tmp/ck

On a real slice the production mesh is built from the flags; in this CPU
container ``--smoke`` uses the reduced config on a 1×1 mesh. Fault tolerance:
checkpoints every ``--ckpt-every`` steps (async, rotated), automatic resume
from the latest committed step, stateless data pipeline keyed by (seed, step).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import canon, get_config, get_smoke_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_production_mesh, make_smoke_mesh, opt_specs
from repro.models import build_model, build_smoke
from repro.models.layers import unbox
from repro.models.sharding import use_sharding
from repro.models.transformer import Flags
from repro.train import (AdamWConfig, TrainConfig, abstract_train_state,
                         init_train_state, make_train_step)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU container)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--over-decompose", type=int, default=1,
                    help="microbatches per step (paper over-decomposition)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    arch = canon(args.arch)
    cfg = get_smoke_config(arch) if args.smoke else get_config(arch)
    model = build_smoke(cfg) if args.smoke else build_model(cfg)
    mesh = make_production_mesh(multi_pod=args.multi_pod) \
        if args.production_mesh else make_smoke_mesh(1, 1)

    tcfg = TrainConfig(
        opt=AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps, weight_decay=0.01),
        over_decompose=args.over_decompose)
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                  global_batch=args.global_batch))

    with use_sharding(mesh):
        step_fn = jax.jit(make_train_step(model, tcfg), donate_argnums=(0,))
        state = init_train_state(model, jax.random.PRNGKey(0))
        start = 0
        ck = None
        if args.checkpoint_dir:
            ck = Checkpointer(args.checkpoint_dir, keep=3)
            latest = ck.latest_step()
            if latest is not None:
                abs_state = abstract_train_state(model)
                state = ck.restore(latest, abs_state)
                start = latest
                print(f"resumed from step {latest}")

        t0 = time.time()
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
            if cfg.frontend == "vision":
                batch["vision_embeds"] = jnp.zeros(
                    (args.global_batch, cfg.frontend_tokens, cfg.d_model))
            if cfg.enc_dec:
                batch["frames"] = jnp.zeros(
                    (args.global_batch, cfg.encoder_seq, cfg.d_model))
            state, metrics = step_fn(state, batch)
            if (i + 1) % args.log_every == 0:
                dt = (time.time() - t0) / args.log_every
                tok_s = args.global_batch * args.seq_len / dt
                print(f"step {i+1:5d} loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"{dt*1e3:.0f} ms/step {tok_s:.0f} tok/s", flush=True)
                t0 = time.time()
            if ck and (i + 1) % args.ckpt_every == 0:
                ck.save(i + 1, state)
        if ck:
            ck.save(args.steps, state, block=True)
        print("done")
    return state


if __name__ == "__main__":
    main()
