"""Unified asynchronous progress engine (paper §3.2.1 + §4.1.3).

The paper keeps communication progress decoupled from compute: control
messages stay cheap even while large payloads stream, because nothing
that makes progress ever blocks inside somebody else's loop. HPX and
DiOMP attribute the same overlap wins to a dedicated progress/completion
engine. This module is that engine, shared by every layer that used to
run its own ad-hoc loop:

  * the Runtime's per-device transfer queues  → ``("transfer", dev)`` lanes
  * the Runtime's in-flight launch polling    → ``("complete", dev)`` lanes
  * the distributed Rank's rendezvous stream  → ``("net-send", rank)`` lane
  * the distributed Rank's stream completion  → ``("net-recv", rank)`` lane
  * the simulated Cluster's per-link wires    → ``("link", src, dst)`` lanes

A ``Lane`` is a serial execution context: one daemon thread draining a
priority queue of jobs (FIFO within a priority level). Jobs post their
result into an ``HFuture`` — the completion event — instead of making
the producer wait. Because every lane is serial, state owned by a lane
needs no locks: post a job to mutate it. Lanes are created lazily and
typed by a ``(kind, key...)`` tuple, so an idle configuration spawns no
threads.

Completion events for device work use ``Lane.submit`` with a job that
performs the (cheap, already-dispatched) blocking wait and then runs the
continuation — a dedicated completion thread per device, never a poll
loop in the compute worker. Device launches complete in FIFO order per
device, which matches the per-device execution streams underneath.

Errors from fire-and-forget jobs (no future to carry them) are routed to
the engine's error sink instead of vanishing on stderr: the owning
``ProgressEngine`` records them, surfaces the count through
``Runtime.stats()["progress_errors"]``, and in strict mode re-raises the
first one from ``check()`` (called by ``Runtime.barrier``) so tests fail
loudly instead of hanging on a silently-dead continuation.
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.futures import HFuture

LaneKey = Tuple[Any, ...]

# error sink keeps a bounded trace of swallowed asynchronous errors
_MAX_SINK_ERRORS = 100


class Lane:
    """One serial execution context: a named daemon thread draining a
    priority queue. ``submit`` returns immediately; the job's completion
    is posted to the returned future. Lower priority runs first, FIFO
    within a priority level."""

    __slots__ = ("name", "_q", "_seq", "_pending", "_pending_lock",
                 "_executing", "_thread", "_stopped", "jobs_done",
                 "on_error")

    def __init__(self, name: str,
                 on_error: Optional[Callable[[str, BaseException], None]]
                 = None):
        self.name = name
        self._q: "queue.PriorityQueue" = queue.PriorityQueue()
        self._seq = itertools.count()
        # jobs accepted but not yet finished (queued + executing). The
        # counter moves at submit time and in the job's finally clause,
        # so there is no popped-but-unmarked window in which a mid-job
        # lane looks idle (the old `_executing`-only accounting was set
        # AFTER PriorityQueue.get() returned, and Cluster.barrier's
        # all-idle sweep could slip through that gap).
        self._pending = 0
        self._pending_lock = threading.Lock()
        self._executing = False
        self._stopped = False
        self.jobs_done = 0
        self.on_error = on_error
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    def submit(self, fn: Callable[[], Any], fut: Optional[HFuture] = None,
               priority: int = 0) -> Optional[HFuture]:
        """Enqueue ``fn``; its result (or error) lands in ``fut`` when the
        lane reaches it. ``fut=None`` posts fire-and-forget work.
        Submitting to a stopped lane raises ``RuntimeError`` (and resolves
        ``fut`` with that error first) — the old behaviour enqueued the
        job behind the infinite-priority stop sentinel, so it never ran
        and its future never resolved (a silent hang). The check and the
        enqueue share ``stop()``'s lock: a submit that wins the race
        lands its job BEFORE the sentinel (which sorts behind every
        queued job), so an accepted job always runs."""
        with self._pending_lock:
            if self._stopped:
                err = RuntimeError(f"lane {self.name} is stopped")
                if fut is not None:
                    fut.set_error(err)
                raise err
            self._pending += 1
            self._q.put((priority, next(self._seq), fn, fut))
        return fut

    def busy(self) -> bool:
        """True while the lane holds accepted-but-unfinished work. Backed
        by the pending counter (moved at submit / job-finally), so a job
        that has been popped off the queue but not yet started still
        counts — no idle-looking window mid-handoff."""
        return self._pending > 0

    def pending(self) -> int:
        """Accepted-but-unfinished jobs (queued + executing)."""
        return self._pending

    def backlog(self) -> int:
        """Jobs waiting behind the currently-executing one — the queue
        depth the adaptive flow controller feeds on (a lane with one
        in-service job and nothing queued is draining at line rate; a
        positive backlog means arrivals outpace the drain)."""
        return max(self._pending - (1 if self._executing else 0), 0)

    def _run(self):
        while True:
            _prio, _seq, fn, fut = self._q.get()
            if fn is None:
                return
            self._executing = True
            try:
                result = fn()
            except BaseException as e:
                if fut is not None:
                    fut.set_error(e)
                elif self.on_error is not None:
                    self.on_error(self.name, e)
                else:                      # pragma: no cover - diagnostics
                    import traceback
                    traceback.print_exc()
            else:
                if fut is not None:
                    fut.set_result(result)
            finally:
                self.jobs_done += 1
                self._executing = False
                with self._pending_lock:
                    self._pending -= 1

    def stop(self, join_timeout: float = 5.0) -> None:
        with self._pending_lock:     # atomic with submit's check+enqueue
            if self._stopped:
                return
            self._stopped = True
            # inf priority: the sentinel sorts behind every queued job
            self._q.put((float("inf"), next(self._seq), None, None))
        self._thread.join(timeout=join_timeout)


class ProgressEngine:
    """Reactor over typed lanes. Layers ask for a lane by ``(kind, key)``
    — ``("transfer", device_id)``, ``("net-send", rank)``, ``("link",
    src, dst)`` — and get the same serial context every time; lanes are
    created on first use. ``submit`` is the one-call sugar; ``complete``
    posts a completion event: run ``waiter`` (a blocking ready-wait for
    work that was already dispatched asynchronously) on the kind's
    completion lane, then hand the result to ``callback``.

    ``strict=True`` turns the error sink into a tripwire: ``check()``
    re-raises the first swallowed fire-and-forget error (tests call it
    through ``Runtime.barrier``)."""

    def __init__(self, name: str = "progress", strict: bool = False):
        self.name = name
        self.strict = strict
        self._lanes: Dict[LaneKey, Lane] = {}
        self._lock = threading.Lock()
        self._shutdown = False
        self._errors: List[Tuple[str, BaseException]] = []

    # -- error sink ----------------------------------------------------
    def _record_error(self, lane_name: str, exc: BaseException) -> None:
        with self._lock:
            self._errors.append((lane_name, exc))
            del self._errors[:-_MAX_SINK_ERRORS]
        if not self.strict:                # keep the stderr trace too
            import traceback
            traceback.print_exception(type(exc), exc, exc.__traceback__)

    def error_count(self) -> int:
        with self._lock:
            return len(self._errors)

    def errors_snapshot(self) -> List[str]:
        with self._lock:
            return [f"{lane}: {type(exc).__name__}: {exc}"
                    for lane, exc in self._errors]

    def check(self) -> None:
        """Strict mode: re-raise the first swallowed asynchronous error.
        A no-op when not strict (the sink still counts them)."""
        if not self.strict:
            return
        with self._lock:
            first = self._errors[0] if self._errors else None
        if first is not None:
            lane, exc = first
            raise RuntimeError(
                f"progress engine {self.name}: swallowed error on lane "
                f"{lane}") from exc

    # -- lanes ---------------------------------------------------------
    def lane(self, kind: str, *key: Any) -> Lane:
        k = (kind,) + key
        with self._lock:
            ln = self._lanes.get(k)
            if ln is None:
                if self._shutdown:
                    raise RuntimeError("progress engine is shut down")
                tag = "-".join(str(p) for p in k)
                ln = Lane(f"{self.name}-{tag}", on_error=self._record_error)
                self._lanes[k] = ln
            return ln

    def peek(self, kind: str, *key: Any) -> Optional[Lane]:
        """The ``(kind, key)`` lane if it already exists — without
        spawning one (introspection / fast-path checks)."""
        with self._lock:
            return self._lanes.get((kind,) + key)

    def backlogs(self) -> Dict[str, int]:
        """Queue depth of every lane that currently has work backed up —
        the diagnostic attached to barrier timeouts and the lane-pressure
        signal straggler detection reads. Busy-but-draining lanes with an
        empty queue report 0 and are omitted."""
        with self._lock:
            lanes = list(self._lanes.items())
        out: Dict[str, int] = {}
        for key, ln in lanes:
            b = ln.backlog()
            if b:
                out["-".join(str(p) for p in key)] = b
        return out

    def submit(self, kind: str, key: Any, fn: Callable[[], Any],
               fut: Optional[HFuture] = None,
               priority: int = 0) -> Optional[HFuture]:
        return self.lane(kind, key).submit(fn, fut, priority)

    # -- completion events ---------------------------------------------
    def complete(self, kind: str, key: Any, waiter: Callable[[], Any],
                 callback: Callable[[Any, Optional[BaseException]], None]
                 ) -> None:
        """Post a completion event: the ``(kind, key)`` completion lane
        runs ``waiter()`` (blocking until the already-dispatched work is
        done) and then ``callback(result, error)``. The producer never
        blocks — this is the dedicated completion thread the paper's
        progress engine trades the per-call poll loop for. Events on one
        lane fire in submission order (FIFO per device stream)."""

        def job():
            result, error = None, None
            try:
                result = waiter()
            except BaseException as e:
                error = e
            callback(result, error)

        self.lane(kind, key).submit(job)

    # -- introspection / teardown --------------------------------------
    def busy(self) -> bool:
        with self._lock:
            lanes = list(self._lanes.values())
        return any(ln.busy() for ln in lanes)

    def lanes_snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            lanes = dict(self._lanes)
        return {
            "-".join(str(p) for p in k): {
                "jobs_done": ln.jobs_done, "busy": ln.busy(),
            }
            for k, ln in sorted(lanes.items(), key=lambda kv: str(kv[0]))
        }

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            lanes = list(self._lanes.values())
        for ln in lanes:
            ln.stop()
