"""Unified asynchronous progress engine (paper §3.2.1 + §4.1.3).

The paper keeps communication progress decoupled from compute: control
messages stay cheap even while large payloads stream, because nothing
that makes progress ever blocks inside somebody else's loop. HPX and
DiOMP attribute the same overlap wins to a dedicated progress/completion
engine. This module is that engine, shared by every layer that used to
run its own ad-hoc loop:

  * the Runtime's per-device transfer queues  → ``("transfer", dev)`` lanes
  * the Runtime's in-flight launch polling    → ``("complete", dev)`` lanes
  * the distributed Rank's rendezvous stream  → ``("net-send", rank)`` lane
  * the distributed Rank's stream completion  → ``("net-recv", rank)`` lane
  * the simulated Cluster's per-link wires    → ``("link", src, dst)`` lanes

A ``Lane`` is a serial execution context: one daemon thread draining a
priority queue of jobs (FIFO within a priority level). Jobs post their
result into an ``HFuture`` — the completion event — instead of making
the producer wait. Because every lane is serial, state owned by a lane
needs no locks: post a job to mutate it. Lanes are created lazily and
typed by a ``(kind, key...)`` tuple, so an idle configuration spawns no
threads.

Completion events for device work use ``Lane.submit`` with a job that
performs the (cheap, already-dispatched) blocking wait and then runs the
continuation — a dedicated completion thread per device, never a poll
loop in the compute worker. Device launches complete in FIFO order per
device, which matches the per-device execution streams underneath.
"""
from __future__ import annotations

import itertools
import queue
import threading
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.futures import HFuture

LaneKey = Tuple[Any, ...]


class Lane:
    """One serial execution context: a named daemon thread draining a
    priority queue. ``submit`` returns immediately; the job's completion
    is posted to the returned future. Lower priority runs first, FIFO
    within a priority level."""

    __slots__ = ("name", "_q", "_seq", "_executing", "_thread", "_stopped",
                 "jobs_done")

    def __init__(self, name: str):
        self.name = name
        self._q: "queue.PriorityQueue" = queue.PriorityQueue()
        self._seq = itertools.count()
        self._executing = False
        self._stopped = False
        self.jobs_done = 0
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    def submit(self, fn: Callable[[], Any], fut: Optional[HFuture] = None,
               priority: int = 0) -> Optional[HFuture]:
        """Enqueue ``fn``; its result (or error) lands in ``fut`` when the
        lane reaches it. ``fut=None`` posts fire-and-forget work."""
        self._q.put((priority, next(self._seq), fn, fut))
        return fut

    def busy(self) -> bool:
        """True while the lane holds queued or executing work. A job is
        marked executing before it is popped off the queue's accounting,
        so there is no idle-looking window mid-job."""
        return self._executing or not self._q.empty()

    def _run(self):
        while True:
            _prio, _seq, fn, fut = self._q.get()
            if fn is None:
                return
            self._executing = True
            try:
                result = fn()
            except BaseException as e:
                if fut is not None:
                    fut.set_error(e)
                else:                      # pragma: no cover - diagnostics
                    import traceback
                    traceback.print_exc()
            else:
                if fut is not None:
                    fut.set_result(result)
            finally:
                self.jobs_done += 1
                self._executing = False

    def stop(self, join_timeout: float = 5.0) -> None:
        if self._stopped:
            return
        self._stopped = True
        # inf priority: the sentinel sorts behind every queued job
        self._q.put((float("inf"), next(self._seq), None, None))
        self._thread.join(timeout=join_timeout)


class ProgressEngine:
    """Reactor over typed lanes. Layers ask for a lane by ``(kind, key)``
    — ``("transfer", device_id)``, ``("net-send", rank)``, ``("link",
    src, dst)`` — and get the same serial context every time; lanes are
    created on first use. ``submit`` is the one-call sugar; ``complete``
    posts a completion event: run ``waiter`` (a blocking ready-wait for
    work that was already dispatched asynchronously) on the kind's
    completion lane, then hand the result to ``callback``."""

    def __init__(self, name: str = "progress"):
        self.name = name
        self._lanes: Dict[LaneKey, Lane] = {}
        self._lock = threading.Lock()
        self._shutdown = False

    # -- lanes ---------------------------------------------------------
    def lane(self, kind: str, *key: Any) -> Lane:
        k = (kind,) + key
        with self._lock:
            ln = self._lanes.get(k)
            if ln is None:
                if self._shutdown:
                    raise RuntimeError("progress engine is shut down")
                tag = "-".join(str(p) for p in k)
                ln = Lane(f"{self.name}-{tag}")
                self._lanes[k] = ln
            return ln

    def submit(self, kind: str, key: Any, fn: Callable[[], Any],
               fut: Optional[HFuture] = None,
               priority: int = 0) -> Optional[HFuture]:
        return self.lane(kind, key).submit(fn, fut, priority)

    # -- completion events ---------------------------------------------
    def complete(self, kind: str, key: Any, waiter: Callable[[], Any],
                 callback: Callable[[Any, Optional[BaseException]], None]
                 ) -> None:
        """Post a completion event: the ``(kind, key)`` completion lane
        runs ``waiter()`` (blocking until the already-dispatched work is
        done) and then ``callback(result, error)``. The producer never
        blocks — this is the dedicated completion thread the paper's
        progress engine trades the per-call poll loop for. Events on one
        lane fire in submission order (FIFO per device stream)."""

        def job():
            result, error = None, None
            try:
                result = waiter()
            except BaseException as e:
                error = e
            callback(result, error)

        self.lane(kind, key).submit(job)

    # -- introspection / teardown --------------------------------------
    def busy(self) -> bool:
        with self._lock:
            lanes = list(self._lanes.values())
        return any(ln.busy() for ln in lanes)

    def lanes_snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            lanes = dict(self._lanes)
        return {
            "-".join(str(p) for p in k): {
                "jobs_done": ln.jobs_done, "busy": ln.busy(),
            }
            for k, ln in sorted(lanes.items(), key=lambda kv: str(kv[0]))
        }

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            lanes = list(self._lanes.values())
        for ln in lanes:
            ln.stop()
