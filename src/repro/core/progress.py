"""Unified asynchronous progress engine (paper §3.2.1 + §4.1.3).

The paper keeps communication progress decoupled from compute: control
messages stay cheap even while large payloads stream, because nothing
that makes progress ever blocks inside somebody else's loop. HPX and
DiOMP attribute the same overlap wins to a dedicated progress/completion
engine. This module is that engine, shared by every layer that used to
run its own ad-hoc loop:

  * the Runtime's per-device transfer queues  → ``("transfer", dev)`` lanes
  * the Runtime's in-flight launch polling    → ``("complete", dev)`` lanes
  * the distributed Rank's rendezvous stream  → ``("net-send", rank)`` lane
  * the distributed Rank's stream completion  → ``("net-recv", rank)`` lane
  * the simulated Cluster's per-link wires    → ``("link", src, dst)`` lanes

A ``Lane`` is a serial execution context draining a priority queue of
jobs (FIFO within a priority level). Jobs post their result into an
``HFuture`` — the completion event — instead of making the producer
wait. Because every lane is serial, state owned by a lane needs no
locks: post a job to mutate it.

Lanes no longer own a thread each. All of an engine's lanes are serviced
by one shared worker pool (``pool_workers`` threads) with lane affinity:

  * a lane with queued work holds a *run token* — exactly one worker may
    drain it at a time, so per-lane serial ordering is preserved;
  * a worker that drains a lane dry keeps it *sticky* for a short grace
    window (one timed queue read) so a hot lane's next job lands on the
    same warm worker without a handoff through the pool;
  * when every pool worker is parked inside a blocking job (completion
    waits, simulated wire time) and more lanes become runnable, the pool
    spawns short-lived *overflow* workers that retire after a brief idle
    TTL — forward progress never waits on a blocked sibling lane;
  * idle lanes cost nothing: creating a lane spawns no thread, so the
    hundreds of lanes a large topology implies no longer mean hundreds
    of idle threads. ``pool_workers=0`` restores the legacy
    thread-per-lane mode.

Completion events for device work use ``Lane.submit`` with a job that
performs the (cheap, already-dispatched) blocking wait and then runs the
continuation — a serial completion lane per device, never a poll loop in
the compute worker. Device launches complete in FIFO order per device,
which matches the per-device execution streams underneath.

Errors from fire-and-forget jobs (no future to carry them) are routed to
the engine's error sink instead of vanishing on stderr: the owning
``ProgressEngine`` records them, surfaces the count through
``Runtime.stats()["progress_errors"]``, and in strict mode re-raises the
first one from ``check()`` (called by ``Runtime.barrier``) so tests fail
loudly instead of hanging on a silently-dead continuation.
"""
from __future__ import annotations

import collections
import itertools
import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import sanitizer
from repro.core.futures import HFuture

LaneKey = Tuple[Any, ...]

# error sink keeps a bounded trace of swallowed asynchronous errors
_MAX_SINK_ERRORS = 100

# default shared-pool width per engine (0 = legacy thread-per-lane)
DEFAULT_POOL_WORKERS = 4

# how long a worker lingers on a drained lane before releasing its run
# token (hot-lane wake locality: a back-to-back submit skips the pool)
_STICKY_S = 100e-6

# idle TTL for overflow workers spawned past the base pool width
_OVERFLOW_TTL_S = 0.05


class _LanePool:
    """Shared worker pool servicing every lane of one engine.

    Runnable lanes sit in a ready deque; a lane enters it at most once
    (its ``_scheduled`` run token). ``_unclaimed`` counts notifies handed
    to idle workers that have not yet claimed a lane — a wake only rides
    an existing notify when one more idle worker remains to consume it,
    otherwise it spawns (base worker up to ``base``, overflow past it).
    That accounting closes the coalescing hole where two wakes share one
    notify, the single woken worker blocks inside the first lane's job,
    and the second lane starves."""

    def __init__(self, name: str, workers: int):
        self.name = name
        self.base = max(1, int(workers))
        self._lock = sanitizer.make_lock("LanePool._lock")
        self._cond = sanitizer.make_condition(self._lock)
        self._ready: "collections.deque" = collections.deque()
        self._idle = 0
        self._unclaimed = 0
        self._n_workers = 0
        self._n_base = 0
        self._shutdown = False
        self._wid = itertools.count()

    def worker_count(self) -> int:
        with self._lock:
            return self._n_workers

    def wake(self, lane: "Lane") -> None:
        """Make ``lane`` runnable. No-op if it already holds its run
        token (a worker is draining it, or it is queued)."""
        with self._lock:
            if lane._scheduled:
                return
            lane._scheduled = True
            self._ready.append(lane)
            if self._unclaimed < self._idle:
                self._unclaimed += 1
                self._cond.notify()
            elif self._n_base < self.base:
                self._n_base += 1
                self._spawn(base=True)
            else:
                self._spawn(base=False)

    def _spawn(self, base: bool) -> None:
        self._n_workers += 1
        threading.Thread(target=self._worker, args=(base,), daemon=True,
                         name=f"{self.name}-w{next(self._wid)}").start()

    def _worker(self, base: bool) -> None:
        while True:
            with self._lock:
                while not self._ready:
                    if self._shutdown:
                        self._retire(base)
                        return
                    self._idle += 1
                    got = self._cond.wait(None if base else _OVERFLOW_TTL_S)
                    self._idle -= 1
                    if not base and not got and not self._ready:
                        self._retire(base)  # overflow worker idled out
                        return
                lane = self._ready.popleft()
                if self._unclaimed:
                    self._unclaimed -= 1
            self._drain(lane)

    def _retire(self, base: bool) -> None:
        # caller holds self._lock
        self._n_workers -= 1
        if base:
            self._n_base -= 1

    def _drain(self, lane: "Lane") -> None:
        """Drain one lane while holding its run token. The final
        empty-check happens under the pool lock, serialized against
        ``wake``: a submit that lands after the check finds the token
        cleared and re-schedules the lane — no lost wakeup."""
        while True:
            try:
                item = lane._q.get(block=False)
            except queue.Empty:
                item = None
            if item is None:
                try:  # sticky grace: hot lanes keep their warm worker
                    item = lane._q.get(timeout=_STICKY_S)
                except queue.Empty:
                    item = None
            if item is None:
                with self._lock:
                    if lane._q.empty():
                        lane._scheduled = False
                        return
                continue
            _prio, _seq, fn, fut = item
            if fn is None:  # stop sentinel — sorts behind every real job
                with self._lock:
                    lane._scheduled = False
                lane._dead.set()
                return
            lane._run_job(fn, fut)

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            self._cond.notify_all()


class Lane:
    """One serial execution context: a named priority queue drained by
    the owning engine's worker pool (or, in legacy mode, a dedicated
    daemon thread). ``submit`` returns immediately; the job's completion
    is posted to the returned future. Lower priority runs first, FIFO
    within a priority level."""

    __slots__ = ("name", "kind", "_q", "_seq", "_pending", "_pending_lock",
                 "_executing", "_thread", "_stopped", "jobs_done",
                 "on_error", "_pool", "_scheduled", "_dead")

    def __init__(self, name: str,
                 on_error: Optional[Callable[[str, BaseException], None]]
                 = None, pool: Optional[_LanePool] = None,
                 kind: str = ""):
        self.name = name
        # lane type ("net-send", "transfer", ...) — the sanitizer's
        # lane-discipline policy is keyed on it (LANE_BLOCKING_OK)
        self.kind = kind
        self._q: "queue.PriorityQueue" = queue.PriorityQueue()
        self._seq = itertools.count()
        # jobs accepted but not yet finished (queued + executing). The
        # counter moves at submit time and in the job's finally clause,
        # so there is no popped-but-unmarked window in which a mid-job
        # lane looks idle (the old `_executing`-only accounting was set
        # AFTER PriorityQueue.get() returned, and Cluster.barrier's
        # all-idle sweep could slip through that gap).
        self._pending = 0
        self._pending_lock = sanitizer.make_lock("Lane._pending_lock")
        self._executing = False
        self._stopped = False
        self.jobs_done = 0
        self.on_error = on_error
        self._pool = pool
        self._scheduled = False      # run token, guarded by pool lock
        self._dead = threading.Event()
        if pool is None:
            self._thread = threading.Thread(target=self._run, daemon=True,
                                            name=name)
            self._thread.start()
        else:
            self._thread = None

    def submit(self, fn: Callable[[], Any], fut: Optional[HFuture] = None,
               priority: int = 0) -> Optional[HFuture]:
        """Enqueue ``fn``; its result (or error) lands in ``fut`` when the
        lane reaches it. ``fut=None`` posts fire-and-forget work.
        Submitting to a stopped lane raises ``RuntimeError`` (and resolves
        ``fut`` with that error first) — the old behaviour enqueued the
        job behind the infinite-priority stop sentinel, so it never ran
        and its future never resolved (a silent hang). The check and the
        enqueue share ``stop()``'s lock: a submit that wins the race
        lands its job BEFORE the sentinel (which sorts behind every
        queued job), so an accepted job always runs — identically in
        pooled and thread-per-lane modes."""
        with self._pending_lock:
            if self._stopped:
                err = RuntimeError(f"lane {self.name} is stopped")
                if fut is not None:
                    fut.set_error(err)
                raise err
            self._pending += 1
            self._q.put((priority, next(self._seq), fn, fut))
        if self._pool is not None:
            self._pool.wake(self)
        return fut

    def busy(self) -> bool:
        """True while the lane holds accepted-but-unfinished work. Backed
        by the pending counter (moved at submit / job-finally), so a job
        that has been popped off the queue but not yet started still
        counts — no idle-looking window mid-handoff."""
        return self._pending > 0

    def pending(self) -> int:
        """Accepted-but-unfinished jobs (queued + executing)."""
        return self._pending

    def backlog(self) -> int:
        """Jobs waiting behind the currently-executing one — the queue
        depth the adaptive flow controller feeds on (a lane with one
        in-service job and nothing queued is draining at line rate; a
        positive backlog means arrivals outpace the drain)."""
        return max(self._pending - (1 if self._executing else 0), 0)

    def _run_job(self, fn: Callable[[], Any], fut: Optional[HFuture]) -> None:
        # publish the lane context so the sanitizer can flag blocking
        # operations executed on strict serial lanes (no-op when off)
        san = sanitizer.current()
        tok = san.enter_lane(self.name, self.kind) if san is not None \
            else None
        self._executing = True
        try:
            result = fn()
        except BaseException as e:
            if fut is not None:
                fut.set_error(e)
            elif self.on_error is not None:
                self.on_error(self.name, e)
            else:                      # pragma: no cover - diagnostics
                import traceback
                traceback.print_exc()
        else:
            if fut is not None:
                fut.set_result(result)
        finally:
            self.jobs_done += 1
            self._executing = False
            if san is not None:
                san.exit_lane(tok)
            with self._pending_lock:
                self._pending -= 1

    def _run(self):
        # legacy thread-per-lane drain loop (pool_workers=0)
        while True:
            _prio, _seq, fn, fut = self._q.get()
            if fn is None:
                self._dead.set()
                return
            self._run_job(fn, fut)

    def stop(self, join_timeout: float = 5.0) -> None:
        with self._pending_lock:     # atomic with submit's check+enqueue
            if self._stopped:
                return
            self._stopped = True
            # inf priority: the sentinel sorts behind every queued job
            self._q.put((float("inf"), next(self._seq), None, None))
        if self._pool is not None:
            self._pool.wake(self)    # a worker must consume the sentinel
            self._dead.wait(timeout=join_timeout)
        else:
            self._thread.join(timeout=join_timeout)


class ProgressEngine:
    """Reactor over typed lanes. Layers ask for a lane by ``(kind, key)``
    — ``("transfer", device_id)``, ``("net-send", rank)``, ``("link",
    src, dst)`` — and get the same serial context every time; lanes are
    created on first use and serviced by the engine's shared worker pool
    (``pool_workers`` base threads + transient overflow; ``0`` restores
    one dedicated thread per lane). ``submit`` is the one-call sugar;
    ``complete`` posts a completion event: run ``waiter`` (a blocking
    ready-wait for work that was already dispatched asynchronously) on
    the kind's completion lane, then hand the result to ``callback``.

    ``strict=True`` turns the error sink into a tripwire: ``check()``
    re-raises the first swallowed fire-and-forget error (tests call it
    through ``Runtime.barrier``)."""

    def __init__(self, name: str = "progress", strict: bool = False,
                 pool_workers: int = DEFAULT_POOL_WORKERS):
        self.name = name
        self.strict = strict
        self._lanes: Dict[LaneKey, Lane] = {}
        self._lock = sanitizer.make_lock("ProgressEngine._lock")
        self._shutdown = False
        self._errors: List[Tuple[str, BaseException]] = []
        self._pool = (_LanePool(name, pool_workers)
                      if pool_workers > 0 else None)

    # -- error sink ----------------------------------------------------
    def _record_error(self, lane_name: str, exc: BaseException) -> None:
        with self._lock:
            self._errors.append((lane_name, exc))
            del self._errors[:-_MAX_SINK_ERRORS]
        if not self.strict:                # keep the stderr trace too
            import traceback
            traceback.print_exception(type(exc), exc, exc.__traceback__)

    def error_count(self) -> int:
        with self._lock:
            return len(self._errors)

    def errors_snapshot(self) -> List[str]:
        with self._lock:
            return [f"{lane}: {type(exc).__name__}: {exc}"
                    for lane, exc in self._errors]

    def check(self) -> None:
        """Strict mode: re-raise the first swallowed asynchronous error.
        A no-op when not strict (the sink still counts them)."""
        if not self.strict:
            return
        with self._lock:
            first = self._errors[0] if self._errors else None
        if first is not None:
            lane, exc = first
            raise RuntimeError(
                f"progress engine {self.name}: swallowed error on lane "
                f"{lane}") from exc

    # -- lanes ---------------------------------------------------------
    def lane(self, kind: str, *key: Any) -> Lane:
        k = (kind,) + key
        with self._lock:
            ln = self._lanes.get(k)
            if ln is None:
                if self._shutdown:
                    raise RuntimeError("progress engine is shut down")
                tag = "-".join(str(p) for p in k)
                ln = Lane(f"{self.name}-{tag}", on_error=self._record_error,
                          pool=self._pool, kind=kind)
                self._lanes[k] = ln
            return ln

    def peek(self, kind: str, *key: Any) -> Optional[Lane]:
        """The ``(kind, key)`` lane if it already exists — without
        spawning one (introspection / fast-path checks)."""
        with self._lock:
            return self._lanes.get((kind,) + key)

    def worker_threads(self) -> int:
        """Live worker threads servicing this engine's lanes. Pool mode:
        the pool's current width (base + overflow). Legacy mode: one per
        lane."""
        if self._pool is not None:
            return self._pool.worker_count()
        with self._lock:
            return len(self._lanes)

    def backlogs(self) -> Dict[str, int]:
        """Queue depth of every lane that currently has work backed up —
        the diagnostic attached to barrier timeouts and the lane-pressure
        signal straggler detection reads. Busy-but-draining lanes with an
        empty queue report 0 and are omitted."""
        with self._lock:
            lanes = list(self._lanes.items())
        out: Dict[str, int] = {}
        for key, ln in lanes:
            b = ln.backlog()
            if b:
                out["-".join(str(p) for p in key)] = b
        return out

    def submit(self, kind: str, key: Any, fn: Callable[[], Any],
               fut: Optional[HFuture] = None,
               priority: int = 0) -> Optional[HFuture]:
        return self.lane(kind, key).submit(fn, fut, priority)

    # -- completion events ---------------------------------------------
    def complete(self, kind: str, key: Any, waiter: Callable[[], Any],
                 callback: Callable[[Any, Optional[BaseException]], None]
                 ) -> None:
        """Post a completion event: the ``(kind, key)`` completion lane
        runs ``waiter()`` (blocking until the already-dispatched work is
        done) and then ``callback(result, error)``. The producer never
        blocks — this is the dedicated completion thread the paper's
        progress engine trades the per-call poll loop for. Events on one
        lane fire in submission order (FIFO per device stream)."""

        def job():
            result, error = None, None
            try:
                result = waiter()
            except BaseException as e:
                error = e
            callback(result, error)

        self.lane(kind, key).submit(job)

    # -- introspection / teardown --------------------------------------
    def busy(self) -> bool:
        with self._lock:
            lanes = list(self._lanes.values())
        return any(ln.busy() for ln in lanes)

    def lanes_snapshot(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            lanes = dict(self._lanes)
        return {
            "-".join(str(p) for p in k): {
                "jobs_done": ln.jobs_done, "busy": ln.busy(),
            }
            for k, ln in sorted(lanes.items(), key=lambda kv: str(kv[0]))
        }

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            lanes = list(self._lanes.values())
        for ln in lanes:
            ln.stop()
        if self._pool is not None:
            self._pool.shutdown()
