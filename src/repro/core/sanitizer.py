"""Concurrency sanitizer: dynamic lock-order, lane-discipline, wait-graph
and gauge-hygiene analysis for the runtime (enabled via
``RuntimeConfig.sanitize`` / ``REPRO_SANITIZE=1``).

The runtime's failure mode is the silent deadlock or leak, not the
crash: continuation-driven protocols (credit-windowed rendezvous
streams, collective phase hops, the shared lane worker pool) hang or
strand state instead of raising. Every PR so far fixed one of those by
hand; this module turns the bug classes into machine-checked properties:

* **Lock-order analysis** (TSan lockset style): runtime locks are built
  through ``make_lock``/``make_rlock``/``make_condition`` — with the
  sanitizer off these return plain ``threading`` primitives (zero
  overhead); with it on they return order-tracking proxies feeding a
  global *may-precede* graph at lock-NAME granularity. A cycle in that
  graph is a potential deadlock even on runs that happen not to hang.
  Same-name edges are excluded (two ``HeteroObject.lock`` instances
  never nest in this codebase; a name-granularity self-edge would be
  pure noise) and non-blocking (try-)acquires add no edges — a trylock
  cannot deadlock.

* **Lane discipline**: ``Lane._run_job`` publishes the executing lane
  into a thread-local; blocking operations observed there — an
  ``HFuture.get`` that actually waited, a contended tracked-lock acquire
  above ``block_threshold_s``, a simulated-wire sleep — are flagged when
  the lane's kind is not in ``LANE_BLOCKING_OK``. This is the bug class
  PR 5 fixed by hand (a blocking wait on the net-send lane stalls every
  stream multiplexed onto it).

* **Distributed wait-for graph**: built on demand from live protocol
  state (stalled ``_rdzv_out`` windows awaiting credits, incomplete
  ``_rdzv_in`` streams awaiting chunks, unacked reliable sends, metas
  without payload halves, pending collective ops). A cycle names a root
  cause; ``Cluster.barrier`` timeout diagnostics attach the verdict. A
  cycle only counts when its edges span >= 2 distinct streams — the two
  complementary halves of ONE healthy in-flight stream always form a
  trivial 2-cycle (sender waits on credits from the receiver that is
  still uploading its chunks) and must not be reported.

* **Gauge hygiene**: at ``Rank.shutdown`` every ``state_gauges()`` leak
  gauge must have drained to zero, or the sanitizer raises naming the
  owning stream/peer. The assertion applies to clean runs only (no
  ``FaultInjector`` attached): faulted runs legitimately strand state
  that the shutdown sweep reclaims.

The sanitizer is process-global (``install()``/``current()``): lock
identity crosses Runtime/Rank/Cluster boundaries, so a per-instance
graph would miss exactly the cross-component inversions it exists to
find. Counters surface as ``Runtime.stats()["sanitizer"]``.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Set, Tuple

__all__ = [
    "SanitizerError", "RuntimeSanitizer", "WaitGraph",
    "install", "uninstall", "current", "env_enabled",
    "make_lock", "make_rlock", "make_condition",
    "LANE_BLOCKING_OK", "lane_blocking_ok",
    "build_wait_graph", "waitgraph_verdict", "gauge_leak_report",
]


class SanitizerError(RuntimeError):
    """A sanitizer assertion failed (lock-order cycle, gauge leak)."""


# Lane kinds whose jobs are ALLOWED to block. These lanes exist to
# absorb a wait (completion events, simulated wire time) or perform
# documented tail waits that cannot feed back into their own drain
# (net-recv finish waits on transfer-lane uploads; transfer-lane reduce
# steps wait on a prior upload of the same stream — see the
# `# lint: allow-blocking` sites in messaging.py). Every other kind —
# most importantly "net-send", which multiplexes ALL of a rank's
# outbound streams — is serial control flow and must never block.
LANE_BLOCKING_OK = frozenset({
    "complete", "transfer", "net-recv",
    "link", "linkprop", "linkctl", "fault",
})

# leak gauges: the Rank.state_gauges() keys that must drain to zero by
# shutdown on a clean (fault-free) run
_LEAK_GAUGES = ("rdzv_out", "rdzv_in", "rdzv_bufs",
                "pending_meta", "rdzv_sent", "unacked")

_MAX_EVENTS = 100        # bounded lane-blocking event trace


def lane_blocking_ok(kind: str) -> bool:
    return kind in LANE_BLOCKING_OK


def env_enabled() -> bool:
    """CI switch: ``REPRO_SANITIZE=1`` turns ``RuntimeConfig.sanitize``
    on by default for every runtime in the process."""
    return os.environ.get("REPRO_SANITIZE", "") == "1"


# ---------------------------------------------------------------------------
# tracked lock proxies
# ---------------------------------------------------------------------------

class _TrackedLock:
    """Order-tracking proxy around ``threading.Lock``. Delegates the
    full lock protocol so ``threading.Condition`` can wrap it."""

    __slots__ = ("_inner", "name", "_san")
    _reentrant = False

    def __init__(self, name: str, san: "RuntimeSanitizer"):
        # constructed per future/object on the task hot path: one inner
        # primitive, no factory-method hop
        self._inner = threading.Lock()
        self.name = name
        self._san = san

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        # Hot path: an uncontended trylock first skips BOTH
        # perf_counter reads; bookkeeping is inlined (no helper-call
        # chain) — the replay fast path takes these locks per task and
        # the sanitize-on overhead budget is 10%.
        san = self._san
        inner = self._inner
        if not blocking:
            if inner.acquire(False):
                # trylocks cannot deadlock: track held-ness (for release
                # symmetry) but add no may-precede edges
                san._local.held.append(self)
                return True
            return False
        if not inner.acquire(False):
            t0 = time.perf_counter()
            if not inner.acquire(True, timeout):
                return False
            waited = time.perf_counter() - t0
            if waited >= san.block_threshold_s:
                san._note_blocking("lock-acquire", waited, self.name)
        # may-precede edges record ORDER, not contention: a blocking
        # acquire contributes them even when it happened not to wait
        st = san._local
        held = st.held
        if held:
            nm = self.name
            cache = st.edge_cache
            for h in held:
                hn = h.name
                if hn != nm:                 # same-name nesting: excluded
                    pair = (hn, nm)
                    if pair not in cache:
                        cache.add(pair)
                        with san._glock:
                            if pair not in san._edges:
                                san._edges[pair] = \
                                    threading.current_thread().name
        held.append(self)
        return True

    def release(self) -> None:
        held = self._san._local.held
        if held and held[-1] is self:        # LIFO release: common case
            held.pop()
        else:
            for i in range(len(held) - 1, -1, -1):
                if held[i] is self:
                    del held[i]
                    break
            # not found: acquired before install — ignore
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    # match threading.Lock semantics: __enter__ IS acquire (returns True)
    __enter__ = acquire

    def __exit__(self, *exc):
        # release() inlined: one Python frame per with-block, not two —
        # the tracked cycle is on the per-task hot path
        held = self._san._local.held
        if held and held[-1] is self:        # LIFO release: common case
            held.pop()
        else:
            for i in range(len(held) - 1, -1, -1):
                if held[i] is self:
                    del held[i]
                    break
        self._inner.release()
        return False

    def __repr__(self):  # pragma: no cover - diagnostics
        return f"<tracked {type(self).__name__} {self.name!r}>"


class _TrackedRLock(_TrackedLock):
    """Order-tracking proxy around ``threading.RLock``. Exposes the
    private ``Condition`` protocol (``_release_save`` etc.) by
    delegation: ``Condition.wait`` releases/reacquires the INNER lock
    directly, which is bookkeeping-safe — the waiting thread is blocked
    for exactly the window in which our held-stack is stale, so it can
    acquire nothing and no false edges form."""

    __slots__ = ()
    _reentrant = True

    def __init__(self, name: str, san: "RuntimeSanitizer"):
        self._inner = threading.RLock()
        self.name = name
        self._san = san

    # Condition protocol ------------------------------------------------
    def _release_save(self):
        return self._inner._release_save()

    def _acquire_restore(self, state):
        self._inner._acquire_restore(state)

    def _is_owned(self):
        return self._inner._is_owned()


class _ThreadState(threading.local):
    """Per-thread sanitizer state: held-lock stack, an edge cache so the
    steady state never touches the global graph lock, and the lane
    context published by ``Lane._run_job``."""

    def __init__(self):
        self.held: List[_TrackedLock] = []
        self.edge_cache: Set[Tuple[str, str]] = set()
        self.lane: Optional[Tuple[str, str, bool]] = None  # (name, kind, ok)


# ---------------------------------------------------------------------------
# RuntimeSanitizer
# ---------------------------------------------------------------------------

class RuntimeSanitizer:
    """One analysis domain: a may-precede lock graph, a lane-discipline
    event trace, and counters. Usable standalone in tests; the
    process-global instance is managed by ``install()``."""

    def __init__(self, block_threshold_s: float = 0.010):
        self.block_threshold_s = block_threshold_s
        self._glock = threading.Lock()          # guards graph + events
        # (held_name, acquired_name) -> thread name of first observation
        self._edges: Dict[Tuple[str, str], str] = {}
        self._lane_events: List[Dict[str, Any]] = []
        self._lane_event_count = 0
        self._waitgraph_probes = 0
        self._gauge_leaks = 0
        self._local = _ThreadState()

    # -- lock factories -------------------------------------------------
    def tracked_lock(self, name: str) -> _TrackedLock:
        return _TrackedLock(name, self)

    def tracked_rlock(self, name: str) -> _TrackedRLock:
        return _TrackedRLock(name, self)

    # lock bookkeeping lives inlined in _TrackedLock.acquire/release —
    # it is the sanitize-on hot path and must stay call-free

    # -- lane discipline ------------------------------------------------
    def enter_lane(self, name: str, kind: str):
        st = self._local
        prev = st.lane
        st.lane = (name, kind, kind in LANE_BLOCKING_OK)
        return prev

    def exit_lane(self, prev) -> None:
        self._local.lane = prev

    def current_lane(self) -> Optional[Tuple[str, str, bool]]:
        return self._local.lane

    def _note_blocking(self, op: str, waited_s: float, detail: str) -> None:
        lane = self._local.lane
        if lane is None or lane[2]:
            return                       # not on a lane / blocking allowed
        with self._glock:
            self._lane_event_count += 1
            self._lane_events.append({
                "lane": lane[0], "kind": lane[1], "op": op,
                "waited_s": waited_s, "detail": detail})
            del self._lane_events[:-_MAX_EVENTS]

    def note_future_wait(self, waited_s: float) -> None:
        """An ``HFuture.get`` that found the event unset and actually
        entered the wait path (any duration: a near-resolved future
        could just as well have waited forever)."""
        self._note_blocking("future-wait", waited_s, "HFuture.get")

    def note_sleep(self, duration_s: float, where: str = "sleep") -> None:
        self._note_blocking("sleep", duration_s, where)

    # -- analyses -------------------------------------------------------
    def lock_order_edges(self) -> Dict[Tuple[str, str], str]:
        with self._glock:
            return dict(self._edges)

    def lock_order_cycles(self) -> List[List[str]]:
        """Cycles in the may-precede graph: each is a name path
        ``[A, B, ..., A]`` meaning some thread acquires B under A while
        another acquires A under (eventually) B — a potential deadlock
        even if this run never interleaved into the hang."""
        with self._glock:
            adj: Dict[str, List[str]] = {}
            for a, b in self._edges:
                adj.setdefault(a, []).append(b)
        return _find_cycles(adj)

    def check_lock_order(self) -> None:
        cycles = self.lock_order_cycles()
        if cycles:
            edges = self.lock_order_edges()
            cyc = cycles[0]
            samples = [
                f"{a}->{b} (first seen on thread "
                f"{edges.get((a, b), '?')})"
                for a, b in zip(cyc, cyc[1:], strict=False)]
            raise SanitizerError(
                "potential deadlock: lock-order cycle "
                + " -> ".join(cyc) + "; " + "; ".join(samples))

    def lane_blocking_report(self) -> List[Dict[str, Any]]:
        with self._glock:
            return [dict(e) for e in self._lane_events]

    # -- counters -------------------------------------------------------
    def note_waitgraph_probe(self) -> None:
        with self._glock:
            self._waitgraph_probes += 1

    def note_gauge_leaks(self, n: int) -> None:
        with self._glock:
            self._gauge_leaks += n

    def stats_snapshot(self) -> Dict[str, int]:
        cycles = len(self.lock_order_cycles())
        with self._glock:
            return {
                "lock_order_edges": len(self._edges),
                "potential_deadlocks": cycles,
                "lane_blocking_events": self._lane_event_count,
                "waitgraph_probes": self._waitgraph_probes,
                "gauge_leaks": self._gauge_leaks,
            }


def _find_cycles(adj: Dict[str, List[str]]) -> List[List[str]]:
    """Simple cycles via DFS with an on-stack set; one representative
    per distinct cycle head. Graphs here are tiny (tens of names)."""
    cycles: List[List[str]] = []
    seen_heads: Set[str] = set()
    for start in sorted(adj):
        stack: List[Tuple[str, int]] = [(start, 0)]
        path = [start]
        on_path = {start}
        while stack:
            node, idx = stack[-1]
            succs = adj.get(node, ())
            if idx < len(succs):
                stack[-1] = (node, idx + 1)
                nxt = succs[idx]
                if nxt == start and len(path) > 1:
                    head = min(path)
                    if head not in seen_heads:
                        seen_heads.add(head)
                        k = path.index(head)
                        cycles.append(path[k:] + path[:k] + [head])
                elif nxt not in on_path and nxt > start:
                    # only explore nodes > start: each cycle is found
                    # from its smallest member exactly once
                    stack.append((nxt, 0))
                    path.append(nxt)
                    on_path.add(nxt)
            else:
                stack.pop()
                on_path.discard(path.pop())
    return cycles


# ---------------------------------------------------------------------------
# process-global install + factories
# ---------------------------------------------------------------------------

_SAN: Optional[RuntimeSanitizer] = None
_install_lock = threading.Lock()


def install(block_threshold_s: Optional[float] = None) -> RuntimeSanitizer:
    """Install (or return) the process-global sanitizer. Idempotent:
    lock identity must be stable across every Runtime/Rank in the
    process, so the first install wins."""
    global _SAN
    with _install_lock:
        if _SAN is None:
            _SAN = RuntimeSanitizer(
                block_threshold_s if block_threshold_s is not None
                else 0.010)
        elif block_threshold_s is not None:
            _SAN.block_threshold_s = min(_SAN.block_threshold_s,
                                         block_threshold_s)
        return _SAN


def uninstall() -> None:
    """Tests only: drop the global sanitizer. Locks already created stay
    tracked against the old instance (harmless); new ones are plain."""
    global _SAN
    with _install_lock:
        _SAN = None


def current() -> Optional[RuntimeSanitizer]:
    return _SAN


def make_lock(name: str):
    """Runtime lock factory: a plain ``threading.Lock`` when the
    sanitizer is off (zero overhead), an order-tracking proxy when on.
    ``name`` is the lock CLASS for the may-precede graph (one name per
    role, e.g. ``"HeteroObject.lock"`` for every object's lock)."""
    san = _SAN
    if san is None:
        return threading.Lock()
    return _TrackedLock(name, san)


def make_rlock(name: str):
    san = _SAN
    if san is None:
        return threading.RLock()
    return _TrackedRLock(name, san)


def make_condition(lock):
    """Condition over a factory-made lock. For a tracked proxy the
    Condition wraps the INNER primitive: every runtime call site
    acquires the lock itself (``with self._lock:``) before wait/notify,
    so mutual exclusion still flows through the tracked proxy and keeps
    its may-precede edges — while ``Condition``'s internals
    (``_is_owned`` on every wait/notify, ``_release_save`` /
    ``_acquire_restore`` around every wait) run on the raw lock at zero
    sanitizer cost. The held-stack is stale for exactly the window the
    waiting thread is blocked, so no false edges can form."""
    inner = getattr(lock, "_inner", None)
    return threading.Condition(inner if inner is not None else lock)


# ---------------------------------------------------------------------------
# distributed wait-for graph
# ---------------------------------------------------------------------------

class WaitGraph:
    """Rank-level wait-for graph. Nodes are rank ids; each edge carries
    the stream (msg) id it stems from and a human-readable reason."""

    def __init__(self):
        self.edges: List[Tuple[int, int, Any, str]] = []

    def add(self, src: int, dst: int, stream: Any, reason: str) -> None:
        if src != dst:
            self.edges.append((src, dst, stream, reason))

    def find_cycle(self) -> Optional[List[Tuple[int, int, Any, str]]]:
        """A cycle whose edges span >= 2 distinct streams (the two
        halves of one healthy in-flight stream form a trivial 2-cycle
        that must not be reported). Returns the edge list of the cycle,
        or None."""
        adj: Dict[int, List[Tuple[int, int, Any, str]]] = {}
        for e in self.edges:
            adj.setdefault(e[0], []).append(e)
        for start in sorted(adj):
            found = self._cycle_from(start, adj)
            if found is not None:
                return found
        return None

    def _cycle_from(self, start, adj):
        # DFS over edges, tracking the path; accept the first cycle back
        # to `start` with >= 2 distinct stream ids
        stack = [(start, iter(adj.get(start, ())))]
        path_edges: List[Tuple[int, int, Any, str]] = []
        on_path = {start}
        while stack:
            node, it = stack[-1]
            edge = next(it, None)
            if edge is None:
                stack.pop()
                if path_edges:
                    on_path.discard(path_edges.pop()[1])
                continue
            _, dst, _, _ = edge
            if dst == start:
                cyc = path_edges + [edge]
                if len({e[2] for e in cyc}) >= 2:
                    return cyc
            elif dst not in on_path:
                on_path.add(dst)
                path_edges.append(edge)
                stack.append((dst, iter(adj.get(dst, ()))))
        return None


def build_wait_graph(cluster) -> WaitGraph:
    """Snapshot the live protocol state of every (alive) rank into a
    wait-for graph. Reads are unlocked dict snapshots — entries may
    race away mid-walk; this is a diagnostic, not a barrier."""
    g = WaitGraph()
    faults = getattr(cluster, "faults", None)
    dead = set(getattr(faults, "dead", ()) or ()) if faults else set()
    for r in cluster.ranks:
        if r.rank in dead:
            continue
        for mid, st in list(r._rdzv_out.items()):
            meta = st.get("meta")
            if meta is None:
                continue
            sent, total = st.get("next_seq", 0), meta.nchunks
            if sent < total and st.get("credits", 0) <= 0:
                g.add(r.rank, meta.dst, mid,
                      f"stream {mid}: sent {sent}/{total} chunks, window "
                      f"stalled awaiting credits from rank {meta.dst}")
        for mid, st in list(r._rdzv_in.items()):
            meta = st.get("meta")
            if meta is None:
                continue
            arrived, total = st.get("arrived", 0), meta.nchunks
            if arrived < total:
                g.add(r.rank, meta.src, mid,
                      f"stream {mid}: {arrived}/{total} chunks arrived "
                      f"from rank {meta.src}")
        with r._unacked_lock:
            unacked = [(mid, st.get("dst"), st.get("attempts", 0))
                       for mid, st in r._unacked.items()]
        for mid, dst, attempts in unacked:
            if dst is not None:
                g.add(r.rank, dst, mid,
                      f"msg {mid}: unacked after {attempts} retries")
        for mid, st in list(r._rdzv_sent.items()):
            dst = st.get("dst")
            if dst is not None:
                g.add(r.rank, dst, mid,
                      f"stream {mid}: tail awaiting completion ack "
                      f"from rank {dst}")
        for mid, msg in list(r._pending_meta.items()):
            g.add(r.rank, msg.src, mid,
                  f"msg {mid}: meta without payload half from "
                  f"rank {msg.src}")
    # pending collective ops: every member of an unfinished op is waiting
    # on its ring neighbour. All hops of one op share a stream id, so a
    # healthy in-flight collective never forms a reportable cycle alone.
    for grp in list(getattr(cluster, "_coll_groups", {}).values()):
        with grp._lock:
            pending = [(tag, op["kind"]) for tag, op in grp._ops.items()
                       if not op["done"].is_set()]
        ring = grp.ring_m
        for tag, kind in pending:
            for i, m in enumerate(ring):
                nxt = ring[(i + 1) % len(ring)]
                if m not in dead and nxt not in dead:
                    g.add(m, nxt, f"coll-{grp.gid}-{tag}",
                          f"collective {kind} tag {tag} pending")
    return g


def waitgraph_verdict(cluster) -> str:
    """One-line root cause for a stuck (or slow) cluster: the named
    deadlock cycle if the wait-for graph has one, else the slowest lane
    by backlog, else "all quiet"."""
    san = _SAN
    if san is not None:
        san.note_waitgraph_probe()
    g = build_wait_graph(cluster)
    cyc = g.find_cycle()
    if cyc is not None:
        hops = " -> ".join(
            f"rank {src} -[{reason}]-> rank {dst}"
            for src, dst, _stream, reason in cyc)
        return f"potential deadlock cycle: {hops}"
    # no cycle: name the slowest lane so a timeout still has a suspect
    worst_name, worst_depth = None, 0
    engines = [("net", getattr(cluster, "net", None))]
    engines += [(f"rank{r.rank}", r.runtime.engine) for r in cluster.ranks]
    for tag, eng in engines:
        if eng is None:
            continue
        for lane, depth in eng.backlogs().items():
            if depth > worst_depth:
                worst_name, worst_depth = f"{tag}:{lane}", depth
    if worst_name is not None:
        return f"no cycle: slowest lane {worst_name} (backlog {worst_depth})"
    return "no cycle: all lanes idle"


# ---------------------------------------------------------------------------
# gauge hygiene
# ---------------------------------------------------------------------------

def gauge_leak_report(rank) -> Optional[str]:
    """Nonzero leak gauges on a rank at shutdown, with the owning
    streams/peers named. Returns None when everything drained."""
    gauges = rank.state_gauges()
    bad = {k: gauges.get(k, 0) for k in _LEAK_GAUGES if gauges.get(k, 0)}
    if not bad:
        return None
    owners: List[str] = []
    for mid, st in list(rank._rdzv_out.items())[:4]:
        meta = st.get("meta")
        if meta is not None:
            owners.append(f"rdzv_out stream {mid} -> rank {meta.dst}")
    for mid, st in list(rank._rdzv_in.items())[:4]:
        meta = st.get("meta")
        if meta is not None:
            owners.append(f"rdzv_in stream {mid} <- rank {meta.src}")
    for mid, (peer, _buf) in list(rank._rdzv_bufs.items())[:4]:
        owners.append(f"rdzv_buf stream {mid} (peer rank {peer})")
    with rank._unacked_lock:
        unacked = list(rank._unacked.items())[:4]
    for mid, st in unacked:
        owners.append(f"unacked msg {mid} -> rank {st.get('dst')}")
    for mid, msg in list(rank._pending_meta.items())[:4]:
        owners.append(f"pending meta {mid} <- rank {msg.src}")
    for mid, st in list(rank._rdzv_sent.items())[:4]:
        owners.append(f"rdzv tail {mid} -> rank {st.get('dst')}")
    return (f"rank {rank.rank} leaked protocol state at shutdown: "
            f"{bad}; owners: {'; '.join(owners) or 'unknown'}")
