"""Implicit dependency inference (paper §3.1.2–3.1.3).

Assuming tasks are submitted in program order, conflicts are inferred from
argument access modes:

  RAW — a reader depends on the object's last (incomplete) writer
  WAR — a writer depends on every incomplete reader since the last write
  WAW — a writer depends on the last (incomplete) writer

Each object carries ``last_writer`` and ``readers``; edges are recorded as a
counter on the dependent plus a reverse list on the dependency, so completion
is O(out-degree). All calls happen under the runtime's global lock.
"""
from __future__ import annotations

from typing import List, Set

from repro.core.hetero_task import Access, HeteroTask, TaskState


def link(task: HeteroTask, dep: HeteroTask) -> bool:
    """Add edge dep -> task unless dep already finished. Returns True if a
    live edge was created."""
    if dep is task or dep.done():
        return False
    dep.dependents.append(task)
    task.unresolved += 1
    return True


def infer_dependencies(task: HeteroTask) -> int:
    """Wire task into the graph; returns number of unresolved deps."""
    seen: Set[int] = set()
    for ref in task.args:
        obj = ref.obj
        if ref.access.reads:
            lw = obj.last_writer
            if lw is not None and id(lw) not in seen and link(task, lw):
                seen.add(id(lw))
        if ref.access.writes:
            lw = obj.last_writer
            if lw is not None and id(lw) not in seen and link(task, lw):
                seen.add(id(lw))
            for r in list(obj.readers):
                if id(r) not in seen and link(task, r):
                    seen.add(id(r))
    for dep in task.explicit_deps:
        if id(dep) not in seen and link(task, dep):
            seen.add(id(dep))
    # register this task on its objects (program order!)
    for ref in task.args:
        obj = ref.obj
        if ref.access.writes:
            obj.last_writer = task
            obj.readers = set()
        elif ref.access.reads:
            obj.readers.add(task)
    return task.unresolved


def retire(task: HeteroTask) -> List[HeteroTask]:
    """Called on completion (under the runtime lock): clears object refs and
    returns newly-unblocked dependents."""
    for ref in task.args:
        obj = ref.obj
        if obj.last_writer is task:
            obj.last_writer = None
        obj.readers.discard(task)
    ready = []
    for dep in task.dependents:
        dep.unresolved -= 1
        if dep.unresolved == 0 and dep.state == TaskState.BLOCKED:
            ready.append(dep)
    task.dependents = []
    return ready
