"""Lineage ledger: remember how each HeteroObject was produced.

The over-decomposition literature's cheap-recovery argument (and the
paper's own ownership of every data movement) makes lineage replay the
natural last line of defence: when coherence finds an object with *no*
valid replica anywhere — evicted and lost, dropped by a failed rank,
freed too early — the runtime can re-run the task that produced it
instead of handing back zeros or restarting the job.

Correctness hinges on **generation numbers**: every write-rebind of a
HeteroObject bumps ``obj.generation``, and a lineage record is only
valid for the exact generation it produced, with inputs pinned to the
generations it *read*.  In-place write chains (``rw`` args) therefore
self-invalidate — the pre-write version of an input no longer exists
once its generation moved on — which makes replay bounded and
cycle-safe by construction.  Compiled-graph replays and distributed
puts bump generations through the same choke points, so stale records
can never resurrect old bytes.

The ledger holds strong references to the objects in its records (so
``id()`` keys stay unique) and is bounded LRU: recording a new producer
for an object supersedes the old record, and the oldest records fall
off past ``cap``.
"""
from __future__ import annotations

import collections
import threading

from repro.core import sanitizer
from typing import Any, List, Optional, Tuple


class LineageRecord:
    """One producing task: kernel + argument versions at launch time.

    ``args`` is a tuple of ``(obj, pre_gen, reads, writes)`` in the
    task's argument order; ``out_gens`` maps ``id(obj)`` of written
    objects to the generation the launch produced.
    """
    __slots__ = ("kernel", "args", "out_gens", "device_id", "epoch")

    def __init__(self, kernel: Any,
                 args: Tuple[Tuple[Any, int, bool, bool], ...],
                 out_gens: dict, device_id: int, epoch: int):
        self.kernel = kernel
        self.args = args
        self.out_gens = out_gens
        self.device_id = device_id
        self.epoch = epoch

    def __repr__(self) -> str:   # pragma: no cover - debugging aid
        k = getattr(self.kernel, "__name__", repr(self.kernel))
        return (f"LineageRecord(kernel={k}, nargs={len(self.args)}, "
                f"dev={self.device_id}, epoch={self.epoch})")


class LineageLedger:
    def __init__(self, cap: int = 4096):
        self.cap = int(cap)
        self.epoch = 0
        self._lock = sanitizer.make_lock("LineageLedger._lock")
        # id(written obj) -> its most recent LineageRecord (LRU order)
        self._by_obj: "collections.OrderedDict[int, LineageRecord]" = \
            collections.OrderedDict()

    def record(self, kernel: Any,
               arg_info: List[Tuple[Any, int, bool, bool]],
               out_gens: dict, device_id: int) -> None:
        """Remember that ``kernel(args)`` produced the written objects."""
        rec = LineageRecord(kernel, tuple(arg_info), dict(out_gens),
                            device_id, self.epoch)
        with self._lock:
            for obj, _pre, _r, writes in rec.args:
                if writes:
                    self._by_obj[id(obj)] = rec
                    self._by_obj.move_to_end(id(obj))
            while len(self._by_obj) > self.cap:
                self._by_obj.popitem(last=False)

    def producer(self, obj: Any) -> Optional[LineageRecord]:
        """The record that produced ``obj``'s *current* generation, or
        None — a record for any other generation is stale by definition
        (the object was rewritten since) and must not be replayed."""
        with self._lock:
            rec = self._by_obj.get(id(obj))
        if rec is None:
            return None
        return rec if rec.out_gens.get(id(obj)) == obj.generation else None

    def forget(self, obj: Any) -> None:
        with self._lock:
            self._by_obj.pop(id(obj), None)

    def forget_many(self, objs: Any) -> None:
        """Batched ``forget`` for the replay rebind loop: fused-chain
        outputs drop their stale records under one lock acquisition."""
        with self._lock:
            for obj in objs:
                self._by_obj.pop(id(obj), None)

    def bump_epoch(self) -> None:
        """Elastic epoch bump: records survive (generation checks keep
        them safe) but new records carry the new epoch for forensics."""
        self.epoch += 1

    def __len__(self) -> int:
        with self._lock:
            return len(self._by_obj)
