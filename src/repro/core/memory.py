"""Memory pools: reusable host staging buffers + request/future freelists.

Paper analogues:
  §4.1.1 page-locked host pool  → ``StagingPool``: preallocated, reused host
                                  staging buffers keyed by (shape, dtype)
  §4.1.4 request pools           → ``RequestPool``: freelist of futures

Per-device residency accounting and LRU offload (paper §3.1.1) moved to the
residency ledger — see ``repro.core.residency.ResidencyLedger``.
"""
from __future__ import annotations

import collections
import threading

from repro.core import sanitizer
from typing import Any, Callable, Dict, List, Tuple

import numpy as np


class StagingPool:
    """Reusable host staging buffers (the page-locked pool analogue)."""

    def __init__(self, enabled: bool = True, max_buffers_per_key: int = 8):
        self.enabled = enabled
        self._free: Dict[Tuple[Tuple[int, ...], str], List[np.ndarray]] = \
            collections.defaultdict(list)
        self._lock = sanitizer.make_lock("StagingPool._lock")
        self._max = max_buffers_per_key
        self.hits = 0
        self.misses = 0

    def acquire(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        if not self.enabled:
            self.misses += 1
            return np.empty(shape, dtype)
        key = (tuple(shape), np.dtype(dtype).str)
        with self._lock:
            lst = self._free.get(key)
            if lst:
                self.hits += 1
                return lst.pop()
        self.misses += 1
        return np.empty(shape, dtype)

    def release(self, arr: np.ndarray) -> None:
        if not self.enabled:
            return
        key = (tuple(arr.shape), arr.dtype.str)
        with self._lock:
            lst = self._free[key]
            if len(lst) < self._max:
                lst.append(arr)


class RequestPool:
    """Freelist of request/future objects (paper §4.1.4). ``hits`` counts
    recycled acquires, ``misses`` fresh constructions — surfaced through
    ``Runtime.stats()``."""

    def __init__(self, factory: Callable[[], Any], enabled: bool = True):
        self._factory = factory
        self.enabled = enabled
        self._free: List[Any] = []
        self._lock = sanitizer.make_lock("RequestPool._lock")
        self.hits = 0
        self.misses = 0

    def acquire(self) -> Any:
        if self.enabled:
            with self._lock:
                if self._free:
                    obj = self._free.pop()
                    obj.reset()
                    self.hits += 1
                    return obj
        self.misses += 1
        return self._factory()

    def release(self, obj: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            if len(self._free) < 1024:
                self._free.append(obj)
