"""Memory layer: per-device usage accounting, LRU offload, staging pools.

Paper analogues:
  §4.1.1 page-locked host pool  → ``StagingPool``: preallocated, reused host
                                  staging buffers keyed by (shape, dtype)
  §4.1.2 custom device allocator → usage ledger + buffer donation (the XLA
                                  analogue of reusing a preallocated arena)
  §3.1.1 LRU offload             → ``MemoryMonitor.ensure_capacity`` spills
                                  least-recently-used idle objects to host
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


class StagingPool:
    """Reusable host staging buffers (the page-locked pool analogue)."""

    def __init__(self, enabled: bool = True, max_buffers_per_key: int = 8):
        self.enabled = enabled
        self._free: Dict[Tuple[Tuple[int, ...], str], List[np.ndarray]] = \
            collections.defaultdict(list)
        self._lock = threading.Lock()
        self._max = max_buffers_per_key
        self.hits = 0
        self.misses = 0

    def acquire(self, shape: Tuple[int, ...], dtype) -> np.ndarray:
        if not self.enabled:
            self.misses += 1
            return np.empty(shape, dtype)
        key = (tuple(shape), np.dtype(dtype).str)
        with self._lock:
            lst = self._free.get(key)
            if lst:
                self.hits += 1
                return lst.pop()
        self.misses += 1
        return np.empty(shape, dtype)

    def release(self, arr: np.ndarray) -> None:
        if not self.enabled:
            return
        key = (tuple(arr.shape), arr.dtype.str)
        with self._lock:
            lst = self._free[key]
            if len(lst) < self._max:
                lst.append(arr)


class RequestPool:
    """Freelist of request/future objects (paper §4.1.4)."""

    def __init__(self, factory: Callable[[], Any], enabled: bool = True):
        self._factory = factory
        self.enabled = enabled
        self._free: List[Any] = []
        self._lock = threading.Lock()

    def acquire(self) -> Any:
        if self.enabled:
            with self._lock:
                if self._free:
                    obj = self._free.pop()
                    obj.reset()
                    return obj
        return self._factory()

    def release(self, obj: Any) -> None:
        if not self.enabled:
            return
        with self._lock:
            if len(self._free) < 1024:
                self._free.append(obj)


class MemoryMonitor:
    """Tracks bytes resident per device; evicts LRU idle objects under
    pressure. Objects register/unregister copies; ``touch`` updates recency."""

    def __init__(self, capacities: Dict[int, int]):
        self._cap = dict(capacities)
        self._usage: Dict[int, int] = {d: 0 for d in capacities}
        self._lru: Dict[int, "collections.OrderedDict[int, Any]"] = {
            d: collections.OrderedDict() for d in capacities}
        self._lock = threading.RLock()
        self.evictions = 0

    def usage(self, device_id: int) -> int:
        return self._usage[device_id]

    def capacity(self, device_id: int) -> int:
        return self._cap[device_id]

    def register(self, device_id: int, obj, nbytes: int) -> None:
        with self._lock:
            self._usage[device_id] += nbytes
            self._lru[device_id][id(obj)] = obj
            self._lru[device_id].move_to_end(id(obj))

    def unregister(self, device_id: int, obj, nbytes: int) -> None:
        with self._lock:
            self._usage[device_id] -= nbytes
            self._lru[device_id].pop(id(obj), None)

    def touch(self, device_id: int, obj) -> None:
        with self._lock:
            if id(obj) in self._lru[device_id]:
                self._lru[device_id].move_to_end(id(obj))

    def ensure_capacity(self, device_id: int, nbytes: int,
                        evict: Callable[[Any, int], bool]) -> bool:
        """Evict LRU objects (via ``evict(obj, device_id)``, which returns
        False when an object is busy and must be skipped) until ``nbytes``
        fits. Returns True on success."""
        with self._lock:
            if self._usage[device_id] + nbytes <= self._cap[device_id]:
                return True
            candidates = list(self._lru[device_id].values())
        for obj in candidates:
            if self._usage[device_id] + nbytes <= self._cap[device_id]:
                return True
            if evict(obj, device_id):
                self.evictions += 1
        with self._lock:
            return self._usage[device_id] + nbytes <= self._cap[device_id]
