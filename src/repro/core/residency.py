"""Residency & placement engine (paper §3.1.1 + §3.1.3/§4.4).

One source of truth for *where data lives*: a per-device **residency
ledger** tracks every HeteroObject's valid device replicas — bytes, pin
state, last touch — and every layer that previously walked ``obj.copies``
ad hoc (scheduler placement, coherence walk, LRU eviction, distributed
payload landing) now consults the ledger instead.

On top of the ledger sit pluggable **placement policies**: cost models
scoring candidate devices for a task. The default ``DataGravityPolicy``
implements the paper's data-locality scheduling ("place tasks where their
arguments already live") as bytes-to-move minus bytes-resident with a
load-pressure penalty, so tasks gravitate to their data but one hot device
cannot serialize the queue. ``Runtime`` binds the ledger to the scheduler's
policy at startup; schedulers re-key their indexed ready queues by the
policy's best placement.

The ledger also answers the distributed layer's landing question — "which
device should an incoming DIRECT payload land on when no consumer is known
yet?" — with the least-loaded device by (queue pressure, bytes resident).
"""
from __future__ import annotations

import abc
import collections
import itertools
import threading

from repro.core import sanitizer
from typing import Any, Callable, Dict, Optional, Sequence, Set, Tuple

_touch_clock = itertools.count()


class _Entry:
    """One replica record: (object, bytes, last-touch tick)."""

    __slots__ = ("obj", "nbytes", "last_touch")

    def __init__(self, obj, nbytes: int):
        self.obj = obj
        self.nbytes = nbytes
        self.last_touch = next(_touch_clock)


class ResidencyLedger:
    """Per-device replica ledger + capacity accounting + LRU eviction.

    ``record``/``drop``/``touch`` are called by the runtime wherever a
    device copy is created, invalidated, or reused; everything else reads.

    Pin ownership lives HERE (ROADMAP follow-up c): the runtime pins an
    object while any task, host access, or device view holds it
    (``pin``/``unpin``), and eviction skips pinned replicas by consulting
    the ledger alone — no ``obj.busy()`` walk, no object locks on the
    eviction path. ``version`` ticks on every replica change so placement
    decisions can detect staleness (the scheduler re-scores aged
    ready-queue entries on pop when the version moved).
    """

    def __init__(self, capacities: Dict[int, int]):
        self._cap = dict(capacities)
        self._usage: Dict[int, int] = {d: 0 for d in capacities}
        # device -> OrderedDict[id(obj) -> _Entry]  (insertion order = LRU)
        self._lru: Dict[int, "collections.OrderedDict[int, _Entry]"] = {
            d: collections.OrderedDict() for d in capacities}
        # id(obj) -> set of devices holding a valid replica
        self._where: Dict[int, Set[int]] = {}
        # id(obj) -> pin count; pinned objects are never evicted. The
        # pinner always holds a strong reference for the pin's lifetime,
        # so a recycled id() cannot alias a live pin.
        self._pins: Dict[int, int] = {}
        self._lock = sanitizer.make_rlock("ResidencyLedger._lock")
        self.evictions = 0
        self.version = 0          # bumped on every record/drop

    # -- replica bookkeeping -------------------------------------------
    def record(self, device_id: int, obj, nbytes: Optional[int] = None
               ) -> None:
        nb = obj.nbytes if nbytes is None else nbytes
        with self._lock:
            lru = self._lru[device_id]
            if id(obj) not in lru:
                self._usage[device_id] += nb
                lru[id(obj)] = _Entry(obj, nb)
                self.version += 1
            else:
                lru[id(obj)].last_touch = next(_touch_clock)
            lru.move_to_end(id(obj))
            self._where.setdefault(id(obj), set()).add(device_id)

    def drop(self, device_id: int, obj, nbytes: Optional[int] = None) -> None:
        nb = obj.nbytes if nbytes is None else nbytes
        with self._lock:
            if self._lru[device_id].pop(id(obj), None) is not None:
                self._usage[device_id] -= nb
                self.version += 1
            devs = self._where.get(id(obj))
            if devs is not None:
                devs.discard(device_id)
                if not devs:
                    del self._where[id(obj)]

    def drop_many(self, pairs: Sequence[Tuple[int, Any]]) -> None:
        """Batched ``drop``: one lock acquisition for a replay window's
        rebind invalidations instead of one per stale replica."""
        with self._lock:
            for device_id, obj in pairs:
                nb = obj.nbytes
                if self._lru[device_id].pop(id(obj), None) is not None:
                    self._usage[device_id] -= nb
                    self.version += 1
                devs = self._where.get(id(obj))
                if devs is not None:
                    devs.discard(device_id)
                    if not devs:
                        del self._where[id(obj)]

    def record_many(self, pairs: Sequence[Tuple[int, Any]]) -> None:
        """Batched ``record``: one lock acquisition for a whole replay
        window's rebinds instead of one per written object."""
        with self._lock:
            for device_id, obj in pairs:
                nb = obj.nbytes
                lru = self._lru[device_id]
                if id(obj) not in lru:
                    self._usage[device_id] += nb
                    lru[id(obj)] = _Entry(obj, nb)
                    self.version += 1
                else:
                    lru[id(obj)].last_touch = next(_touch_clock)
                lru.move_to_end(id(obj))
                self._where.setdefault(id(obj), set()).add(device_id)

    # -- pin ownership (eviction guard) --------------------------------
    def pin(self, obj) -> None:
        """Mark ``obj`` in active use (task argument, host access, device
        view): its replicas are skipped by eviction until ``unpin``."""
        with self._lock:
            self._pins[id(obj)] = self._pins.get(id(obj), 0) + 1

    def unpin(self, obj) -> None:
        with self._lock:
            n = self._pins.get(id(obj), 0) - 1
            if n <= 0:
                self._pins.pop(id(obj), None)
            else:
                self._pins[id(obj)] = n

    def pin_many(self, objs: Sequence[Any]) -> None:
        """Batched ``pin`` — the replay fast path pins a whole traced
        window's objects under a single lock acquisition."""
        with self._lock:
            pins = self._pins
            for obj in objs:
                pins[id(obj)] = pins.get(id(obj), 0) + 1

    def unpin_many(self, objs: Sequence[Any]) -> None:
        with self._lock:
            pins = self._pins
            for obj in objs:
                n = pins.get(id(obj), 0) - 1
                if n <= 0:
                    pins.pop(id(obj), None)
                else:
                    pins[id(obj)] = n

    def pinned(self, obj) -> bool:
        with self._lock:
            return self._pins.get(id(obj), 0) > 0

    def forget(self, obj) -> None:
        """Drop every replica of ``obj`` and clear its pins — the object
        left this runtime entirely (elastic chunk migration: the source
        rank must stop counting the bytes against its devices)."""
        with self._lock:
            devs = list(self._where.get(id(obj), ()))
        for d in devs:
            self.drop(d, obj)
        with self._lock:
            self._pins.pop(id(obj), None)

    def touch(self, device_id: int, obj) -> None:
        with self._lock:
            e = self._lru[device_id].get(id(obj))
            if e is not None:
                e.last_touch = next(_touch_clock)
                self._lru[device_id].move_to_end(id(obj))

    def touch_many(self, pairs: Sequence[Tuple[int, Any]]) -> None:
        """Batched ``touch``: LRU-bump a replay window's staged replicas
        under one lock acquisition."""
        with self._lock:
            for device_id, obj in pairs:
                e = self._lru[device_id].get(id(obj))
                if e is not None:
                    e.last_touch = next(_touch_clock)
                    self._lru[device_id].move_to_end(id(obj))

    # -- queries --------------------------------------------------------
    def devices_of(self, obj) -> Set[int]:
        """Devices holding a valid replica (never includes HOST)."""
        with self._lock:
            return set(self._where.get(id(obj), ()))

    def holds(self, device_id: int, obj) -> bool:
        with self._lock:
            return id(obj) in self._lru[device_id]

    def usage(self, device_id: int) -> int:
        return self._usage[device_id]

    def capacity(self, device_id: int) -> int:
        return self._cap[device_id]

    def task_bytes_resident(self, task, device_id: int) -> int:
        """Bytes of the task's (unique) arguments already on device_id."""
        with self._lock:
            lru = self._lru[device_id]
            seen, total = set(), 0
            for ref in task.args:
                oid = id(ref.obj)
                if oid not in seen:
                    seen.add(oid)
                    if oid in lru:
                        total += ref.obj.nbytes
            return total

    def task_bytes_to_move(self, task, device_id: int) -> int:
        """Bytes the coherence walk would have to copy in before launch."""
        with self._lock:
            lru = self._lru[device_id]
            seen, total = set(), 0
            for ref in task.args:
                oid = id(ref.obj)
                if oid not in seen:
                    seen.add(oid)
                    if oid not in lru:
                        total += ref.obj.nbytes
            return total

    def least_loaded_device(self, pressure: Optional[Callable[[int], int]]
                            = None,
                            among: Optional[Sequence[int]] = None) -> int:
        """Landing device for data with no known consumer: least queue
        pressure first (when the scheduler provides it), then fewest bytes
        resident, then lowest id — deterministic. ``among`` restricts the
        candidates (e.g. to one device type)."""
        devs = sorted(self._cap if among is None
                      else (d for d in among if d in self._cap))
        if not devs:
            devs = sorted(self._cap)
        if pressure is None:
            return min(devs, key=lambda d: (self._usage[d], d))
        return min(devs, key=lambda d: (pressure(d), self._usage[d], d))

    # -- capacity / eviction -------------------------------------------
    def ensure_capacity(self, device_id: int, nbytes: int,
                        evict: Callable[[Any, int], bool]) -> bool:
        """Evict LRU replicas (via ``evict(obj, device_id)``, which returns
        False when an object is busy and must be skipped) until ``nbytes``
        fits. Returns True on success."""
        with self._lock:
            if self._usage[device_id] + nbytes <= self._cap[device_id]:
                return True
            # pinned replicas never leave the candidate list — the whole
            # point of ledger-owned pins: no per-object lock or busy()
            # walk on the eviction path
            candidates = [e.obj for e in self._lru[device_id].values()
                          if self._pins.get(id(e.obj), 0) == 0]
        for obj in candidates:
            if self._usage[device_id] + nbytes <= self._cap[device_id]:
                return True
            if evict(obj, device_id):
                self.evictions += 1
        with self._lock:
            return self._usage[device_id] + nbytes <= self._cap[device_id]

    # -- observability --------------------------------------------------
    def gauges(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "bytes_resident": dict(self._usage),
                "objects_resident": {d: len(lru)
                                     for d, lru in self._lru.items()},
                "evictions": self.evictions,
                "pinned_objects": len(self._pins),
            }


# ---------------------------------------------------------------------------
# placement cost models
# ---------------------------------------------------------------------------

class PlacementPolicy(abc.ABC):
    """Scores candidate devices for a task; lower is better. A ledger is
    bound by the runtime (``bind``); unbound policies fall back to the
    object-level ``has_copy`` walk so schedulers remain usable standalone.
    The runtime also binds its ``InterconnectModel`` (``bind_topology``)
    so cost models can price data movement in measured link terms."""

    def __init__(self):
        self.ledger: Optional[ResidencyLedger] = None
        self.topology = None      # Optional[InterconnectModel]

    def bind(self, ledger: ResidencyLedger) -> None:
        self.ledger = ledger

    def bind_topology(self, model) -> None:
        self.topology = model

    def _bytes_split(self, task, device_id: int) -> Tuple[int, int]:
        """(bytes_resident, bytes_to_move) for the task on device_id."""
        if self.ledger is not None:
            return (self.ledger.task_bytes_resident(task, device_id),
                    self.ledger.task_bytes_to_move(task, device_id))
        seen, res, move = set(), 0, 0
        for ref in task.args:
            if id(ref.obj) in seen:
                continue
            seen.add(id(ref.obj))
            if ref.obj.has_copy(device_id):
                res += ref.obj.nbytes
            else:
                move += ref.obj.nbytes
        return res, move

    @abc.abstractmethod
    def score(self, task, device_id: int, pressure: int) -> float: ...

    def choose(self, task, eligible: Sequence[int],
               pressure: Callable[[int], int]) -> int:
        """Best device: minimal score, ties broken by lowest device id
        (deterministic — tested)."""
        return min(eligible,
                   key=lambda d: (self.score(task, d, pressure(d)), d))


class DataGravityPolicy(PlacementPolicy):
    """The paper's data-locality placement as a cost model: prefer the
    device needing the fewest argument bytes copied in and holding the most
    already, with queue pressure converted to bytes so load still balances
    when residency ties.

    The pressure penalty is DERIVED from the interconnect model when one
    is bound (ROADMAP follow-up b): one queued task costs
    ``penalty_seconds`` of that device's measured host→device bandwidth,
    so a fast link tolerates more queueing before work migrates off its
    data and a slow link sheds load sooner. ``load_penalty_bytes`` is only
    the standalone fallback when no topology is bound."""

    def __init__(self, load_penalty_bytes: int = 256 << 10,
                 penalty_seconds: float = 50e-6):
        super().__init__()
        self.load_penalty = load_penalty_bytes
        self.penalty_seconds = penalty_seconds

    def penalty_bytes(self, device_id: int) -> int:
        """Byte cost of one queued/running task on ``device_id``."""
        if self.topology is None:
            return self.load_penalty
        from repro.core.hetero_object import HOST
        return self.topology.penalty_bytes(HOST, device_id,
                                           self.penalty_seconds)

    def transfer_cost_s(self, task, device_id: int) -> float:
        """Predicted seconds the coherence walk would spend staging the
        task's missing argument bytes onto ``device_id`` — the scheduler's
        transfer-cost estimate, surfaced for diagnostics and tests."""
        _, move = self._bytes_split(task, device_id)
        if not move:
            return 0.0
        if self.topology is None:
            from repro.core.topology import LinkEstimate
            return LinkEstimate().cost_s(move)    # default-link fallback
        from repro.core.hetero_object import HOST
        return self.topology.cost_s(HOST, device_id, move)

    def score(self, task, device_id: int, pressure: int) -> float:
        res, move = self._bytes_split(task, device_id)
        return move - res + pressure * self.penalty_bytes(device_id)


class LoadOnlyPolicy(PlacementPolicy):
    """Pure pressure balancing — ignores residency entirely. The control
    arm for the gravity model in benchmarks and tests."""

    def score(self, task, device_id: int, pressure: int) -> float:
        return float(pressure)


PLACEMENTS: Dict[str, Callable[[], PlacementPolicy]] = {
    "gravity": DataGravityPolicy,
    "load_only": LoadOnlyPolicy,
}
