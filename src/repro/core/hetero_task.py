"""hetero_task — device-independent task descriptor (paper §3.1.2).

A task consolidates: the kernel (a JAX function — the portable "dialect"
that lowers to every backend), hetero_object arguments with access modes,
requested processing dimensions (advisory on TPU/XLA), an optional scratch
request (the shared-memory analogue), explicit dependencies, and a device
*type* — never a device id; the scheduler picks the concrete device.
"""
from __future__ import annotations

import enum
import itertools
from typing import Any, Callable, List, Optional, Sequence, Tuple

from repro.core.futures import HFuture
from repro.core.hetero_object import HeteroObject

_ids = itertools.count()


class Access(enum.Enum):
    READ = "r"
    WRITE = "w"
    RW = "rw"

    @property
    def reads(self) -> bool:
        return self in (Access.READ, Access.RW)

    @property
    def writes(self) -> bool:
        return self in (Access.WRITE, Access.RW)


class TaskState(enum.Enum):
    CREATED = 0
    SUBMITTED = 1
    BLOCKED = 2
    READY = 3
    RUNNING = 4
    DONE = 5
    FAILED = 6


class ArgRef:
    __slots__ = ("obj", "access")

    def __init__(self, obj: HeteroObject, access: Access):
        self.obj = obj
        self.access = access


class HeteroTask:
    """Builder-style task, mirroring the paper's API:

        task = HeteroTask()
        task.arg(a).read()
        task.arg(c).write()
        task.device('tpu')            # a device TYPE, not an id
        task.set_threads((32,32,1), (32,32,1))   # advisory under XLA
        rt.submit(task, kernel)
    """

    def __init__(self, name: str = ""):
        self.id = next(_ids)
        self.name = name or f"task{self.id}"
        self.args: List[ArgRef] = []
        self.device_type: Optional[str] = None   # None = any
        self.grid: Optional[Tuple] = None
        self.block: Optional[Tuple] = None
        self.scratch_bytes: int = 0
        self.explicit_deps: List["HeteroTask"] = []
        self.kernel: Optional[Callable] = None
        self.state = TaskState.CREATED
        self.future = HFuture()
        self.outputs: List[HeteroObject] = []
        # runtime bookkeeping
        self.unresolved: int = 0
        self.dependents: List["HeteroTask"] = []
        self.chosen_device: Optional[int] = None

    # builder API -----------------------------------------------------------
    class _ArgMode:
        def __init__(self, task: "HeteroTask", obj: HeteroObject):
            self._t, self._o = task, obj

        def read(self):
            self._t.args.append(ArgRef(self._o, Access.READ))
            return self._t

        def write(self):
            self._t.args.append(ArgRef(self._o, Access.WRITE))
            return self._t

        def rw(self):
            self._t.args.append(ArgRef(self._o, Access.RW))
            return self._t

    def arg(self, obj: HeteroObject) -> "_ArgMode":
        return HeteroTask._ArgMode(self, obj)

    def device(self, device_type: Optional[str]) -> "HeteroTask":
        self.device_type = device_type
        return self

    def set_threads(self, grid: Tuple, block: Tuple) -> "HeteroTask":
        self.grid, self.block = grid, block
        return self

    def shared_memory(self, nbytes: int) -> "HeteroTask":
        self.scratch_bytes = nbytes
        return self

    def add_dependency(self, other: "HeteroTask") -> "HeteroTask":
        self.explicit_deps.append(other)
        return self

    # properties --------------------------------------------------------
    @property
    def read_objs(self) -> List[HeteroObject]:
        return [a.obj for a in self.args if a.access.reads]

    @property
    def write_objs(self) -> List[HeteroObject]:
        return [a.obj for a in self.args if a.access.writes]

    def arg_bytes_on(self, device_id: int) -> int:
        return sum(a.obj.nbytes for a in self.args
                   if a.obj.has_copy(device_id))

    def total_arg_bytes(self) -> int:
        return sum(a.obj.nbytes for a in self.args)

    def done(self) -> bool:
        return self.state in (TaskState.DONE, TaskState.FAILED)

    def wait(self, timeout: Optional[float] = None):
        return self.future.get(timeout)

    def __repr__(self):
        return f"HeteroTask({self.name}, state={self.state.name})"
