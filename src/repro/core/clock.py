"""Injectable clock plumbing — the ONE place `core/` and `distributed/`
may read a clock for deadline arithmetic.

Wall clocks (`time.time`) step under NTP adjustment and make timeout
logic silently wrong; `tools/lint_runtime.py` therefore bans
`time.time()`/`time.monotonic()` calls in `core/` + `distributed/`
outside this module (`time.perf_counter` stays allowed — it is the
measurement clock, never a deadline clock). Deadline code calls
``clock.now()``; components that take an injectable clock parameter
(e.g. ``ElasticController(clock=...)``) default it to ``clock.monotonic``
so tests can substitute a virtual clock.
"""
from __future__ import annotations

import time

# injectable default for components that accept a clock callable
monotonic = time.monotonic


def now() -> float:
    """Monotonic seconds for deadline/timeout arithmetic. Never a wall
    clock: immune to NTP steps and daylight-saving jumps."""
    return monotonic()
