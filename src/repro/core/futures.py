"""Futures for asynchronous runtime operations (paper §3.1.1/§3.1.3).

A ``HFuture`` is returned by every asynchronous runtime call (task submission,
data-access request, transfer). It supports non-blocking status queries —
the paper's requirement that PREMA can poll operation status without
blocking its time-slicing loop — and blocking waits with timeouts.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, List, Optional

from repro.core import sanitizer


class HFuture:
    __slots__ = ("_event", "_result", "_error", "_callbacks", "_lock")

    def __init__(self):
        self._event = threading.Event()
        self._result: Any = None
        self._error: Optional[BaseException] = None
        self._callbacks: List[Callable[["HFuture"], None]] = []
        self._lock = sanitizer.make_lock("HFuture._lock")

    # -- producer side -----------------------------------------------------
    def set_result(self, value: Any) -> None:
        with self._lock:
            self._result = value
            self._event.set()
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)

    def set_error(self, err: BaseException) -> None:
        with self._lock:
            self._error = err
            self._event.set()
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            cb(self)

    def reset(self) -> None:
        """Recycle (request-pool reuse, paper §4.1.4)."""
        self._event.clear()
        self._result = None
        self._error = None
        self._callbacks = []

    # -- consumer side ------------------------------------------------------
    def done(self) -> bool:
        return self._event.is_set()

    def get(self, timeout: Optional[float] = None) -> Any:
        if not self._event.is_set():
            # actually entering the wait path is a lane-discipline event:
            # a serial lane parked here could just as well park forever
            san = sanitizer.current()
            if san is not None:
                t0 = time.perf_counter()
                ok = self._event.wait(timeout)
                san.note_future_wait(time.perf_counter() - t0)
                if not ok:
                    raise TimeoutError("future not ready")
            elif not self._event.wait(timeout):
                raise TimeoutError("future not ready")
        if self._error is not None:
            raise self._error
        return self._result

    def add_done_callback(self, cb: Callable[["HFuture"], None]) -> None:
        fire = False
        with self._lock:
            if self._event.is_set():
                fire = True
            else:
                self._callbacks.append(cb)
        if fire:
            cb(self)
