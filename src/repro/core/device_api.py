"""Device API — the bottom layer of the tasking framework (paper §3.1.5).

Encapsulates vendor-specific device operations behind an abstract class, so
the Core Runtime never touches a backend directly. The JAX implementation
covers every XLA backend uniformly (CPU/GPU/TPU) — JAX plays the role the
paper's OpenCL-dialect kernel macro played: one kernel definition, every
backend. Hardware adaptation notes in DESIGN.md §2.
"""
from __future__ import annotations

import abc
import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.futures import HFuture


@dataclasses.dataclass
class DeviceInfo:
    device_id: int
    device_type: str            # 'cpu' | 'gpu' | 'tpu'
    memory_capacity: int        # bytes the runtime may use on this device
    name: str = ""


class Device(abc.ABC):
    """Abstract device: (a)synchronous task launch + data management."""

    def __init__(self, info: DeviceInfo):
        self.info = info

    @abc.abstractmethod
    def upload(self, host_array: np.ndarray) -> Any: ...

    @abc.abstractmethod
    def download(self, dev_array: Any) -> np.ndarray: ...

    @abc.abstractmethod
    def launch(self, kernel: Callable, args: Tuple[Any, ...],
               donate: Tuple[int, ...] = ()) -> Any: ...

    @abc.abstractmethod
    def synchronize(self, handle: Any) -> Any: ...

    @abc.abstractmethod
    def is_ready(self, handle: Any) -> bool: ...


class JaxDevice(Device):
    """A single jax.Device wrapped in the Device API.

    Kernel launches go through a per-(kernel, donation) jit cache —
    the "custom allocator" analogue: donation lets XLA reuse input buffers
    in place of fresh allocations (paper §4.1.2). Async dispatch gives the
    multi-stream overlap of §4.1.3: launches return immediately and
    ``is_ready`` polls without blocking.
    """

    def __init__(self, info: DeviceInfo, jax_device: jax.Device,
                 cache_jit: bool = True):
        super().__init__(info)
        self.jax_device = jax_device
        self.cache_jit = cache_jit
        self._jit_cache: Dict[Tuple[int, Tuple[int, ...]], Callable] = {}
        self._lock = threading.Lock()

    def upload(self, host_array: np.ndarray) -> Any:
        return jax.device_put(host_array, self.jax_device)

    def download(self, dev_array: Any) -> np.ndarray:
        return np.asarray(dev_array)

    def _get_jit(self, kernel: Callable, donate: Tuple[int, ...]) -> Callable:
        if not self.cache_jit:
            return jax.jit(kernel, donate_argnums=donate)
        key = (id(kernel), donate)
        with self._lock:
            fn = self._jit_cache.get(key)
            if fn is None:
                fn = jax.jit(kernel, donate_argnums=donate)
                self._jit_cache[key] = fn
        return fn

    def launch(self, kernel: Callable, args: Tuple[Any, ...],
               donate: Tuple[int, ...] = ()) -> Any:
        fn = self._get_jit(kernel, donate)
        with jax.default_device(self.jax_device):
            return fn(*args)

    def synchronize(self, handle: Any) -> Any:
        return jax.block_until_ready(handle)

    def is_ready(self, handle: Any) -> bool:
        try:
            leaves = jax.tree.leaves(handle)
            return all(l.is_ready() for l in leaves
                       if hasattr(l, "is_ready"))
        except Exception:
            return True


def discover_devices(memory_capacity: Optional[int] = None,
                     cache_jit: bool = True) -> List[JaxDevice]:
    """One runtime Device per jax.Device. ``memory_capacity`` caps the bytes
    the runtime's memory monitor allows per device (None → 3/4 of 16 GiB —
    the v5e-like default used in tests via small overrides)."""
    cap = memory_capacity if memory_capacity is not None \
        else int(16 * (1 << 30) * 0.75)
    devs = []
    for i, d in enumerate(jax.devices()):
        devs.append(JaxDevice(
            DeviceInfo(device_id=i, device_type=d.platform,
                       memory_capacity=cap, name=str(d)), d,
            cache_jit=cache_jit))
    return devs
