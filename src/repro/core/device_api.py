"""Device API — the bottom layer of the tasking framework (paper §3.1.5).

Encapsulates vendor-specific device operations behind an abstract class, so
the Core Runtime never touches a backend directly. The JAX implementation
covers every XLA backend uniformly (CPU/GPU/TPU) — JAX plays the role the
paper's OpenCL-dialect kernel macro played: one kernel definition, every
backend. Hardware adaptation notes in DESIGN.md §2.

Transfer engine primitives (paper §3.2.3/§4.1.3): besides the synchronous
``upload``/``download`` pair, devices expose asynchronous variants returning
``TransferHandle``s, plus a direct device→device ``transfer`` that never
bounces through host memory — the GPU-aware-interconnect analogue. The Core
Runtime's per-device transfer queues are built on these primitives.
"""
from __future__ import annotations

import abc
import dataclasses
import threading

from repro.core import sanitizer
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

# pseudo-device id for transfer sources not wrapped locally (a payload
# arriving from another rank's runtime) in interconnect observations
FOREIGN = -2


@dataclasses.dataclass
class DeviceInfo:
    device_id: int
    device_type: str            # 'cpu' | 'gpu' | 'tpu'
    memory_capacity: int        # bytes the runtime may use on this device
    name: str = ""


class TransferHandle:
    """Handle on an (a)synchronous copy. ``result()`` blocks until the data
    is resident; ``is_ready()`` polls without blocking (the PREMA
    requirement: status queries must never stall the time-slicing loop)."""

    __slots__ = ("_value", "_ready_fn")

    def __init__(self, value: Any, ready_fn: Optional[Callable[[], bool]]
                 = None):
        self._value = value
        self._ready_fn = ready_fn

    def is_ready(self) -> bool:
        return self._ready_fn() if self._ready_fn is not None else True

    def result(self) -> Any:
        v = self._value
        if hasattr(v, "block_until_ready"):
            v.block_until_ready()
        return v


class Device(abc.ABC):
    """Abstract device: (a)synchronous task launch + data management."""

    def __init__(self, info: DeviceInfo):
        self.info = info

    @abc.abstractmethod
    def upload(self, host_array: np.ndarray) -> Any: ...

    @abc.abstractmethod
    def download(self, dev_array: Any) -> np.ndarray: ...

    def download_into(self, dev_array: Any, out: np.ndarray) -> np.ndarray:
        """Copy a resident array into a caller-provided host buffer — the
        runtime's pooled D2H staging path (chunks of a device array land
        in slices of a StagingPool buffer). Backends with pinned-memory
        DMA override this; the default bounces through ``download``."""
        np.copyto(out, self.download(dev_array))
        return out

    @abc.abstractmethod
    def transfer_from(self, src: Optional["Device"], dev_array: Any) -> Any:
        """Copy ``dev_array`` (resident on ``src``, which may be None when
        the source device is foreign) onto this device without staging
        through host memory (paper Fig. 7: device-aware path)."""

    def upload_async(self, host_array: np.ndarray) -> TransferHandle:
        return TransferHandle(self.upload(host_array))

    def download_async(self, dev_array: Any) -> TransferHandle:
        return TransferHandle(self.download(dev_array))

    def clone(self, dev_array: Any) -> Any:
        """Private on-device copy of a resident array (no host bounce).
        Used to snapshot data that must survive buffer donation of the
        original. Backends without donation may return the array itself."""
        return dev_array

    @abc.abstractmethod
    def launch(self, kernel: Callable, args: Tuple[Any, ...],
               donate: Tuple[int, ...] = ()) -> Any: ...

    @abc.abstractmethod
    def synchronize(self, handle: Any) -> Any: ...

    @abc.abstractmethod
    def is_ready(self, handle: Any) -> bool: ...

    def completion_waiter(self, handle: Any) -> Callable[[], Any]:
        """Blocking ready-wait closure for an already-dispatched launch —
        what the progress engine's per-device completion lane runs to
        turn the handle into a completion event (the runtime never polls
        ``is_ready`` in its compute workers anymore). Backends may
        return a cheaper wait than full ``synchronize``."""
        return lambda: self.synchronize(handle)


def transfer(src_dev: Optional[Device], dst_dev: Device,
             dev_array: Any,
             observer: Optional[Callable[[int, int, int, float], None]]
             = None) -> Any:
    """Direct D2D copy: move ``dev_array`` from ``src_dev`` to ``dst_dev``
    with no host bounce. The single entry point every layer above (core
    runtime coherence walk, distributed DIRECT payload path) routes through.
    ``src_dev`` may be None when the source device is not wrapped locally
    (e.g. a payload arriving from another rank's runtime) — such sources
    are reported as ``FOREIGN``.

    ``observer(src_id, dst_id, nbytes, seconds)`` is the interconnect
    stats hook: every caller that owns an ``InterconnectModel`` passes
    its ``observe`` so the one primitive feeds all topology estimates.
    On asynchronously-dispatching backends the sample reflects dispatch +
    enqueue (a lower bound the EWMA smooths)."""
    if src_dev is not None and src_dev.info.device_id == dst_dev.info.device_id:
        return dev_array
    t0 = time.perf_counter() if observer is not None else 0.0
    out = dst_dev.transfer_from(src_dev, dev_array)
    if observer is not None:
        src_id = src_dev.info.device_id if src_dev is not None else FOREIGN
        observer(src_id, dst_dev.info.device_id,
                 int(getattr(dev_array, "nbytes", 0)),
                 time.perf_counter() - t0)
    return out


class JaxDevice(Device):
    """A single jax.Device wrapped in the Device API.

    Kernel launches go through a per-(kernel, donation) jit cache —
    the "custom allocator" analogue: donation lets XLA reuse input buffers
    in place of fresh allocations (paper §4.1.2). Async dispatch gives the
    multi-stream overlap of §4.1.3: launches return immediately and
    ``is_ready`` polls without blocking.
    """

    def __init__(self, info: DeviceInfo, jax_device: jax.Device,
                 cache_jit: bool = True):
        super().__init__(info)
        self.jax_device = jax_device
        self.cache_jit = cache_jit
        # Keyed on the kernel OBJECT (strong ref), never id(kernel): an id
        # can be recycled after the kernel is garbage-collected, silently
        # launching a stale compiled function for a new kernel.
        self._jit_cache: Dict[Tuple[Callable, Tuple[int, ...]], Callable] = {}
        self._lock = sanitizer.make_lock("Device._jit_lock")

    def upload(self, host_array: np.ndarray) -> Any:
        arr = jax.device_put(host_array, self.jax_device)
        # CPU backends may ZERO-COPY device_put (the device buffer aliases
        # the numpy one). The runtime recycles host staging buffers, so
        # upload must guarantee an independent device copy: re-put a private
        # host copy (only the jax array references it → aliasing is safe).
        if (self.info.device_type == "cpu"
                and np.may_share_memory(np.asarray(arr), host_array)):
            arr = jax.device_put(host_array.copy(), self.jax_device)
        return arr

    def download(self, dev_array: Any) -> np.ndarray:
        return np.asarray(dev_array)

    def transfer_from(self, src: "Device", dev_array: Any) -> Any:
        # jax.device_put on a committed jax.Array issues the copy directly
        # between the two buffers (ICI/NVLink/PCIe, backend permitting) —
        # no intermediate np.ndarray is ever materialized.
        return jax.device_put(dev_array, self.jax_device)

    def clone(self, dev_array: Any) -> Any:
        import jax.numpy as jnp
        with jax.default_device(self.jax_device):
            return jnp.array(dev_array, copy=True)

    def upload_async(self, host_array: np.ndarray) -> TransferHandle:
        arr = self.upload(host_array)
        ready = arr.is_ready if hasattr(arr, "is_ready") else None
        return TransferHandle(arr, ready)

    def _get_jit(self, kernel: Callable, donate: Tuple[int, ...]) -> Callable:
        if not self.cache_jit:
            return jax.jit(kernel, donate_argnums=donate)
        key = (kernel, donate)
        with self._lock:
            fn = self._jit_cache.get(key)
            if fn is None:
                fn = jax.jit(kernel, donate_argnums=donate)
                self._jit_cache[key] = fn
        return fn

    def launch(self, kernel: Callable, args: Tuple[Any, ...],
               donate: Tuple[int, ...] = ()) -> Any:
        fn = self._get_jit(kernel, donate)
        with jax.default_device(self.jax_device):
            return fn(*args)

    def synchronize(self, handle: Any) -> Any:
        return jax.block_until_ready(handle)

    def is_ready(self, handle: Any) -> bool:
        try:
            leaves = jax.tree.leaves(handle)
            return all(l.is_ready() for l in leaves
                       if hasattr(l, "is_ready"))
        except Exception:
            return True


def _host_memory_bytes() -> Optional[int]:
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemTotal:"):
                    return int(line.split()[1]) * 1024
    except OSError:
        pass
    return None


def device_capacity(jax_device: jax.Device, n_devices: int,
                    fraction: float = 0.75) -> int:
    """Honest per-device capacity: ask the backend for its byte limit
    (GPU/TPU expose one via memory_stats); CPU devices split the host's
    physical memory. Falls back to the 16 GiB v5e-like default."""
    try:
        stats = jax_device.memory_stats()
        if stats:
            limit = stats.get("bytes_limit") or stats.get("bytes_reservable_limit")
            if limit:
                return int(limit * fraction)
    except Exception:
        pass
    host = _host_memory_bytes()
    if host is not None and n_devices > 0:
        return int(host * fraction / n_devices)
    return int(16 * (1 << 30) * fraction)


def discover_devices(memory_capacity: Optional[int] = None,
                     cache_jit: bool = True) -> List[JaxDevice]:
    """One runtime Device per jax.Device. ``memory_capacity`` caps the bytes
    the runtime's memory monitor allows per device (None → honest per-device
    capacity reported by the backend, see ``device_capacity``)."""
    all_devs = jax.devices()
    devs = []
    for i, d in enumerate(all_devs):
        cap = memory_capacity if memory_capacity is not None \
            else device_capacity(d, len(all_devs))
        devs.append(JaxDevice(
            DeviceInfo(device_id=i, device_type=d.platform,
                       memory_capacity=cap, name=str(d)), d,
            cache_jit=cache_jit))
    return devs
