"""Content digests for end-to-end data integrity.

One fast digest shared by every data boundary: eager payloads,
rendezvous chunks, and checkpoint leaves.  The threat model is the
seeded :class:`~repro.distributed.messaging.FaultInjector` bit-flip
(and, in the real world, silent wire/storage corruption): we need to
*detect* flipped bytes cheaply, not authenticate them.

``digest_array`` is a vectorised 64-bit xor-fold: the byte stream is
viewed as little-endian ``uint64`` words, xor-reduced with numpy, and
mixed with any tail bytes plus the length.  This detects any single
bit-flip (and any odd corruption pattern) while running at memory
bandwidth (~18 GB/s on this container vs ~1.1 GB/s for ``zlib.crc32``)
— essential because the simulated wire moves 4 GB/s and the clean-path
overhead budget is ~5%.  It is order-*insensitive* across whole
aligned words (two swapped words cancel), which is fine here: chunk
identity and ordering are carried by the message ``seq``/``offset``
fields, the digest only guards the bytes themselves.
"""
from __future__ import annotations

from typing import Union

import numpy as np

_LEN_MIX = 0x9E3779B97F4A7C15  # golden-ratio odd constant
_MASK64 = 0xFFFFFFFFFFFFFFFF


class ChecksumError(RuntimeError):
    """Raised when stored/received bytes fail digest or metadata checks."""


def digest_array(arr: Union[np.ndarray, bytes, bytearray, memoryview]) -> int:
    """64-bit content digest of an array's (or buffer's) bytes.

    The result depends only on the raw byte stream and its length, not
    on shape or dtype — callers validate those separately from message
    meta / checkpoint manifests.
    """
    if isinstance(arr, (bytes, bytearray, memoryview)):
        b = np.frombuffer(arr, dtype=np.uint8)
    else:
        b = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
    n = b.nbytes
    head = n - (n % 8)
    acc = 0
    if head:
        acc = int(np.bitwise_xor.reduce(b[:head].view(np.uint64)))
    if head != n:
        acc ^= int.from_bytes(b[head:].tobytes(), "little")
    return (acc ^ ((n * _LEN_MIX) & _MASK64)) & _MASK64


def verify_array(arr, expected: int) -> bool:
    """True iff ``arr``'s bytes hash to ``expected``."""
    return digest_array(arr) == int(expected)
