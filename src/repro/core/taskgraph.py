"""Compiled task-graph fast path: trace → compile → replay (ROADMAP 4).

The paper's single-device headline comes from driving per-task overhead
below the kernel cost; once the runtime core is correct, what dominates
small tasks is pure Python — future resolution, ledger lookups, lane
hops, dependency inference. This module removes that tax for *recurring*
DAGs (Jacobi sweeps, serve steps, microbatch train steps) the way DaCe
optimizes a dataflow graph and then emits it as a unit, and the way CUDA
graphs replay a captured stream:

  trace    ``GraphTracer`` records each ``Runtime.submit`` between two
           window boundaries (``Runtime.step_boundary()`` or
           ``Runtime.barrier()``) as a canonical node: kernel identity,
           argument topology (object slots by first occurrence), access
           modes, shapes/dtypes, device-type preference. The per-window
           structural key — kernel ids × dependency shape × dtypes and
           shapes — detects recurrence across consecutive windows.

  compile  on the ``replay_after``-th identical window the tracer waits
           for that window's (already interpreted) tasks, captures the
           scheduler's placement decisions, and compiles a
           ``TracedGraph``: maximal same-device runs of nodes fuse into
           one jitted chain each (submission order is a topological
           order, so executing chains in order is dependency-correct);
           entry transfers are pre-planned once from the residency
           ledger's replica map.

  replay   subsequent submits that match the compiled structure are
           *parked* — no pins, no dependency inference, no scheduler, no
           per-task lane hop. At the window boundary the whole DAG runs
           as one replay: entry copies issued as a batch, one dispatch
           per chain (``jax.jit`` cache hit on the persistent chain
           callable), outputs rebound to their hetero_objects, and every
           parked future resolved at once. Interior futures are elided —
           they resolve with ``None`` rather than a per-task device
           handle (the documented contract for traced windows).

  invalidate  anything the trace can't vouch for falls back to
           interpreted mode and re-traces: a submit that deviates from
           the recorded structure (different kernel / objects / access
           modes — which is also how shape changes appear, since objects
           carry their shape), eviction of a pre-planned replica
           (detected at replay; the window still executes correctly via
           the coherence walk, then drops the graph), an
           ``ElasticRuntime`` epoch bump (``Runtime.invalidate_traces``),
           or a mid-window host access (parked tasks flush through the
           interpreted path so ``request_host`` observes every write).

Nothing here runs unless ``RuntimeConfig.trace_graphs`` is set: the
tracer is opt-in per runtime, and drivers mark step edges with
``runtime.step_boundary()`` (a no-op when tracing is off).
"""
from __future__ import annotations

import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import sanitizer
from repro.core import device_api
from repro.core.hetero_object import HOST
from repro.core.hetero_task import HeteroTask, TaskState

__all__ = ["GraphTracer", "TracedGraph"]

_COMPILE_WAIT_S = 120.0


class _Node:
    """One recorded submit, canonicalized against the window's slot map."""

    __slots__ = ("kernel", "device", "device_type", "arg_slots", "modes",
                 "write_slots")

    def __init__(self, kernel, device, device_type, arg_slots, modes,
                 write_slots):
        self.kernel = kernel
        self.device = device
        self.device_type = device_type
        self.arg_slots = arg_slots        # tuple[slot] in arg order
        self.modes = modes                # tuple[Access] in arg order
        self.write_slots = write_slots    # tuple[slot], write-args in order


class _Chain:
    """A maximal same-device run of nodes fused into one jitted dispatch."""

    __slots__ = ("device", "fn", "in_slots", "out_slots", "n_tasks")

    def __init__(self, device, fn, in_slots, out_slots, n_tasks):
        self.device = device
        self.fn = fn
        self.in_slots = in_slots
        self.out_slots = out_slots
        self.n_tasks = n_tasks


def _make_chain_fn(specs, in_slots, out_slots):
    """Compose a window chain into one traceable callable. ``specs`` is
    [(kernel, arg_slots, write_slots)] in submission order; the closure
    threads slot values through an env exactly the way the interpreted
    path threads written arrays through the hetero_objects."""

    def chain_fn(*xs):
        env = dict(zip(in_slots, xs, strict=True))
        for kern, arg_slots, write_slots in specs:
            res = kern(*(env[s] for s in arg_slots))
            outs = res if isinstance(res, (tuple, list)) else (res,)
            for ws, out in zip(write_slots, outs, strict=False):
                env[ws] = out
        return tuple(env[s] for s in out_slots)

    return chain_fn


class TracedGraph:
    """A compiled recurring window: fused chains + pre-planned entries.

    ``objects`` holds the window's hetero_objects by slot (strong refs —
    replay matching is by object identity). ``entries`` lists
    ``(slot, device, expected_resident)``: the batch of input copies the
    replay issues up front, with the residency expectation captured once
    from the ledger at compile time. ``chains`` run in submission order;
    cross-chain values travel through the replay env, not through the
    objects, so objects are rebound exactly once per window."""

    __slots__ = ("key", "nodes", "objects", "chains", "entries", "replays")

    def __init__(self, key, nodes, objects, chains, entries):
        self.key = key
        self.nodes = nodes
        self.objects = objects
        self.chains = chains
        self.entries = entries
        self.replays = 0

    def __repr__(self):
        return (f"TracedGraph(tasks={len(self.nodes)}, "
                f"chains={len(self.chains)}, entries={len(self.entries)}, "
                f"replays={self.replays})")


class GraphTracer:
    """Records submit windows, detects recurrence, compiles and replays.

    Driven by three runtime hooks: ``on_submit`` (park or record),
    ``on_boundary`` (close a window: replay, compile, or advance the
    recurrence streak), and ``flush`` (a mid-window host access forces
    parked tasks through the interpreted path). All state is guarded by
    one reentrant lock; the expected producer is the driver thread, but
    ``invalidate`` may arrive from an elastic controller thread."""

    def __init__(self, runtime, replay_after: int = 3):
        self.rt = runtime
        self.replay_after = max(1, int(replay_after))
        self._lock = sanitizer.make_rlock("GraphTracer._lock")
        self._window: List[Tuple[HeteroTask, Callable]] = []
        self._prev_key: Optional[Tuple] = None
        self._streak = 0
        self._graph: Optional[TracedGraph] = None
        self._parked: List[HeteroTask] = []
        self._match_idx = 0
        # set when the current window already diverged from the armed
        # graph for a benign reason (host access flush): skip matching
        # until the next boundary but keep the graph armed
        self._deviated = False

    # -- introspection -------------------------------------------------
    def graph(self) -> Optional[TracedGraph]:
        with self._lock:
            return self._graph

    # -- runtime hooks -------------------------------------------------
    def on_submit(self, task: HeteroTask, kernel: Callable) -> bool:
        """True → the task was parked for replay (caller must not
        schedule it); False → record it and run interpreted."""
        with self._lock:
            g = self._graph
            if g is not None and not self._deviated:
                if (self._match_idx < len(g.nodes)
                        and self._matches(g.nodes[self._match_idx], task,
                                          kernel)):
                    self._parked.append(task)
                    self._match_idx += 1
                    return True
                # structural deviation (kernel/objects/modes changed —
                # shape changes surface here too, as different objects):
                # drop the graph and fall back to interpreted re-tracing
                self._invalidate_locked()
            self._window.append((task, kernel))
            return False

    def on_boundary(self) -> None:
        """Close the current window: replay a fully-matched one, compile
        on the Nth recurrence, or just advance the streak."""
        with self._lock:
            g = self._graph
            if g is not None and not self._deviated and self._parked:
                if self._match_idx == len(g.nodes):
                    self._replay_locked()
                    return
                # fewer submits than the trace expects: structure changed
                self._invalidate_locked()
            self._deviated = False
            if not self._window:
                return
            key = tuple(self._sig(t, k) for t, k in self._window)
            if key == self._prev_key:
                self._streak += 1
            else:
                self._prev_key = key
                self._streak = 1
            window, self._window = self._window, []
            if self._graph is None and self._streak >= self.replay_after:
                self._compile(window, key)

    def flush(self) -> None:
        """A host access (``request_host`` / device view / rebind) landed
        mid-window: parked tasks must become real tasks so the access
        observes their writes. The graph stays armed — matching resumes
        at the next boundary."""
        with self._lock:
            if not self._parked:
                return
            self._deviated = True
            self._release_parked_locked()

    def invalidate(self) -> None:
        """External invalidation (elastic epoch bump, manual): drop the
        compiled graph and restart recurrence detection."""
        with self._lock:
            if self._graph is not None or self._parked:
                self._invalidate_locked()
            self._prev_key = None
            self._streak = 0

    # -- internals -----------------------------------------------------
    @staticmethod
    def _sig(task: HeteroTask, kernel: Callable) -> Tuple:
        return (id(kernel), task.device_type,
                tuple((id(r.obj), r.access.name, r.obj.shape,
                       str(r.obj.dtype)) for r in task.args),
                bool(task.explicit_deps))

    def _matches(self, node: _Node, task: HeteroTask,
                 kernel: Callable) -> bool:
        if kernel is not node.kernel or task.explicit_deps:
            return False
        if task.device_type != node.device_type:
            return False
        if len(task.args) != len(node.arg_slots):
            return False
        objects = self._graph.objects
        for ref, slot, mode in zip(task.args, node.arg_slots, node.modes,
                                   strict=False):
            if ref.obj is not objects[slot] or ref.access is not mode:
                return False
        return True

    def _release_parked_locked(self) -> None:
        """Move parked tasks back onto the interpreted path, in order,
        and fold them into the recording window so the re-trace sees the
        true submit sequence."""
        parked, self._parked = self._parked, []
        self._match_idx = 0
        for t in parked:
            self._window.append((t, t.kernel))
            self.rt._enqueue(t)

    def _invalidate_locked(self) -> None:
        if self._graph is not None:
            self._graph = None
            self.rt._stats["graph_invalidations"] += 1
        self._prev_key = None
        self._streak = 0
        self._release_parked_locked()

    def _compile(self, window, key) -> None:
        """Compile the just-executed window into a TracedGraph. The
        window's tasks ran interpreted; waiting on their futures captures
        the scheduler's placement decisions and guarantees the residency
        snapshot below describes the steady state a replayed window
        starts from."""
        rt = self.rt
        tasks = [t for t, _ in window]
        try:
            for t in tasks:
                t.future.get(timeout=_COMPILE_WAIT_S)
        except BaseException:
            self._streak = 0          # failing window: don't compile it
            return
        if any(t.chosen_device is None for t in tasks):
            return
        # slots by first occurrence across the window
        slot_of: Dict[int, int] = {}
        objects: List[Any] = []
        nodes: List[_Node] = []
        for task, kernel in window:
            arg_slots, modes, write_slots = [], [], []
            for ref in task.args:
                s = slot_of.get(id(ref.obj))
                if s is None:
                    s = slot_of[id(ref.obj)] = len(objects)
                    objects.append(ref.obj)
                arg_slots.append(s)
                modes.append(ref.access)
                if ref.access.writes:
                    write_slots.append(s)
            nodes.append(_Node(kernel, task.chosen_device, task.device_type,
                               tuple(arg_slots), tuple(modes),
                               tuple(write_slots)))
        # fuse maximal same-device runs (submission order is topological)
        chains: List[_Chain] = []
        entries: List[Tuple[int, int, bool]] = []
        produced: set = set()      # slots written by earlier chains
        planned: set = set()       # (slot, device) entry pairs planned
        i = 0
        while i < len(nodes):
            dev = nodes[i].device
            j = i
            while j < len(nodes) and nodes[j].device == dev:
                j += 1
            run = nodes[i:j]
            specs, in_slots, written = [], [], set()
            for node in run:
                for s in node.arg_slots:
                    if s not in written and s not in in_slots:
                        in_slots.append(s)
                written.update(node.write_slots)
                specs.append((node.kernel, node.arg_slots,
                              node.write_slots))
            out_slots = []
            for node in run:
                for s in node.write_slots:
                    if s not in out_slots:
                        out_slots.append(s)
            for s in in_slots:
                if s not in produced and (s, dev) not in planned:
                    planned.add((s, dev))
                    entries.append(
                        (s, dev,
                         dev in rt.residency.devices_of(objects[s])))
            produced.update(written)
            chains.append(_Chain(dev, _make_chain_fn(specs, tuple(in_slots),
                                                     tuple(out_slots)),
                                 tuple(in_slots), tuple(out_slots),
                                 len(run)))
            i = j
        self._graph = TracedGraph(key, nodes, objects, chains, entries)
        self._match_idx = 0
        rt._stats["graphs_traced"] += 1

    def _replay_locked(self) -> None:
        """Execute the whole parked window as one replay dispatch."""
        rt, g = self.rt, self._graph
        parked, self._parked = self._parked, []
        self._match_idx = 0
        stale = False
        rt.residency.pin_many(g.objects)
        try:
            # pre-planned entry transfers, issued as one batch up front;
            # LRU bumps for already-resident replicas are deferred and
            # applied under a single ledger acquisition
            staged: Dict[Tuple[int, int], Any] = {}
            touched: List[Tuple[int, Any]] = []
            for slot, dev, expected_resident in g.entries:
                obj = g.objects[slot]
                # lock-free replica read: every window object is pinned
                # (no eviction) and every task touching it is parked in
                # this window (no concurrent rebind), so ``copies``
                # cannot change underneath us; a stale miss only falls
                # back to the coherence walk below
                arr = obj.copies.get(dev)
                if arr is None:
                    if expected_resident:
                        # a replica the plan counted on was evicted: the
                        # coherence walk still makes this window correct,
                        # but the plan is stale — re-trace afterwards
                        stale = True
                    arr = rt._ensure_on_device(obj, dev, will_write=False)
                else:
                    touched.append((dev, obj))
                staged[(slot, dev)] = arr
            if touched:
                rt.residency.touch_many(touched)
            # one dispatch per fused chain, in submission (= topo) order;
            # cross-chain values travel through env, not the objects
            env: Dict[int, Tuple[int, Any]] = {}
            for ch in g.chains:
                inputs = []
                for s in ch.in_slots:
                    if s in env:
                        src_dev, arr = env[s]
                        if src_dev != ch.device:
                            arr = device_api.transfer(
                                rt._device(src_dev), rt._device(ch.device),
                                arr, observer=rt.topology.observe)
                            rt._stats["transfers_d2d"] += 1
                            rt._stats["bytes_d2d"] += g.objects[s].nbytes
                    else:
                        arr = staged.get((s, ch.device))
                        if arr is None:
                            stale = True
                            arr = rt._ensure_on_device(
                                g.objects[s], ch.device, will_write=False)
                    inputs.append(arr)
                handle = rt._device(ch.device).launch(
                    ch.fn, tuple(inputs), donate=())
                outs = handle if isinstance(handle, (tuple, list)) \
                    else (handle,)
                for s, arr in zip(ch.out_slots, outs, strict=False):
                    env[s] = (ch.device, arr)
            # rebind written objects once, exactly like _launch does:
            # drop every old copy, the chain output becomes the only one.
            # Each rebind is a new generation; fused-chain outputs have no
            # per-task lineage record, so drop any stale one — a lost
            # replayed object is NOT lineage-recoverable (documented in
            # the recovery taxonomy), but the generation bump alone
            # already makes stale records unreplayable.
            written: List[Tuple[int, Any]] = []
            dropped: List[Tuple[int, Any]] = []
            for s, (dev, arr) in env.items():
                obj = g.objects[s]
                with obj.lock:
                    for sp in list(obj.copies):
                        if sp == HOST:
                            # host copies go through _drop_copy so pooled
                            # staging buffers return to the pool
                            rt._drop_copy(obj, sp)
                        else:
                            del obj.copies[sp]
                            dropped.append((sp, obj))
                    obj.copies[dev] = arr
                    obj.generation += 1
                written.append((dev, obj))
            # ledger drops/records and lineage forgets are batched: one
            # lock acquisition each for the whole window. Eviction
            # consults the ledger under pins we still hold, so the brief
            # gap between a rebind and its record only delays
            # evictability.
            rt.residency.drop_many(dropped)
            rt.residency.record_many(written)
            if rt.lineage is not None:
                rt.lineage.forget_many(obj for _d, obj in written)
        except BaseException as e:
            self._retire_parked(parked, error=e)
            self._invalidate_locked()
            return
        finally:
            rt.residency.unpin_many(g.objects)
        g.replays += 1
        rt._stats["graph_replays"] += 1
        rt._stats["replayed_tasks"] += len(parked)
        self._retire_parked(parked, error=None)
        if stale:
            self._invalidate_locked()

    def _retire_parked(self, parked, error: Optional[BaseException]) -> None:
        rt = self.rt
        with rt._lock:
            rt._tasks_pending -= len(parked)
            rt._work.notify_all()
        for t in parked:
            if error is not None:
                t.state = TaskState.FAILED
                t.future.set_error(error)
            else:
                t.state = TaskState.DONE
                t.future.set_result(None)
