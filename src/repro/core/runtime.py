"""Core Runtime (paper §3.1.3/§3.1.5): the glue between application
preferences, the scheduler, and the Device API.

Execution model (faithful to the paper):
  submit() appends an execution request and returns immediately;
  dependencies are inferred (or explicit); blocked tasks wait for their
  dependencies; runnable tasks go to the scheduler; per-device worker
  threads ("dedicated threads", paper Fig. 9) pop work, stage argument
  copies onto their device, launch asynchronously through the Device API,
  and retire tasks as results become ready.

Configuration toggles map 1:1 to the paper's optimization ladder (Fig. 8)
so the benchmark can reproduce it:
  staging_pool     — §4.1.1 page-locked host memory pool
  cache_jit        — §4.1.2 custom device allocator (jit cache + donation)
  request_pool     — §4.1.4 request pools
  transfer_thread  — §4.1.3 dedicated transfer queue
  inflight         — §4.1.3 multiple compute queues (async window)
  dedicated_threads— §4.1.6 one worker per device
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dependency as dep
from repro.core.device_api import Device, JaxDevice, discover_devices
from repro.core.futures import HFuture
from repro.core.hetero_object import HOST, HeteroObject
from repro.core.hetero_task import Access, HeteroTask, TaskState
from repro.core.memory import MemoryMonitor, RequestPool, StagingPool
from repro.core.scheduler import SCHEDULERS, Scheduler


@dataclasses.dataclass
class RuntimeConfig:
    scheduler: str = "locality"
    staging_pool: bool = True
    cache_jit: bool = True
    request_pool: bool = True
    transfer_thread: bool = True
    inflight: int = 4             # async launches in flight per device
    dedicated_threads: bool = True
    sync_dispatch: bool = False   # TF-Baseline: block after every launch
    memory_capacity: Optional[int] = None
    poll_interval_s: float = 0.0005


class Runtime:
    def __init__(self, config: Optional[RuntimeConfig] = None,
                 devices: Optional[List[Device]] = None):
        self.cfg = config or RuntimeConfig()
        self.devices: List[Device] = devices if devices is not None else \
            discover_devices(self.cfg.memory_capacity, self.cfg.cache_jit)
        for d in self.devices:
            if isinstance(d, JaxDevice):
                d.cache_jit = self.cfg.cache_jit
        self.memory = MemoryMonitor(
            {d.info.device_id: d.info.memory_capacity for d in self.devices})
        self.scheduler: Scheduler = SCHEDULERS[self.cfg.scheduler](
            {d.info.device_id: d.info.device_type for d in self.devices})
        self.staging = StagingPool(self.cfg.staging_pool)
        self.futures = RequestPool(HFuture, self.cfg.request_pool)
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._tasks_pending = 0
        self._shutdown = False
        self._stats = {"tasks": 0, "transfers_h2d": 0, "transfers_d2h": 0,
                       "bytes_h2d": 0, "bytes_d2h": 0}
        self._threads: List[threading.Thread] = []
        self._xfer_q: "queue.Queue" = queue.Queue()
        self._start_workers()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def hetero_object(self, value=None, shape=None, dtype=None,
                      name: str = "") -> HeteroObject:
        return HeteroObject(self, value=value, shape=shape, dtype=dtype,
                            name=name)

    def submit(self, task: HeteroTask, kernel: Callable) -> HFuture:
        """Enqueue an execution request; returns the task's future."""
        task.kernel = kernel
        with self._lock:
            task.state = TaskState.SUBMITTED
            self._tasks_pending += 1
            self._stats["tasks"] += 1
            n = dep.infer_dependencies(task)
            if n > 0:
                task.state = TaskState.BLOCKED
            else:
                task.state = TaskState.READY
                self.scheduler.push(task)
            self._work.notify_all()
        return task.future

    def run(self, kernel: Callable, args: Sequence[Tuple[HeteroObject, str]],
            device_type: Optional[str] = None, name: str = "") -> HeteroTask:
        """Convenience: build + submit in one call.
        args: [(obj, 'r'|'w'|'rw'), ...]."""
        t = HeteroTask(name=name)
        for obj, mode in args:
            getattr(t.arg(obj), {"r": "read", "w": "write",
                                 "rw": "rw"}[mode])()
        t.device(device_type)
        self.submit(t, kernel)
        return t

    def barrier(self, timeout: Optional[float] = 120.0) -> None:
        """Wait until every submitted task has retired."""
        deadline = None if timeout is None else time.time() + timeout
        with self._lock:
            while self._tasks_pending > 0:
                remaining = None if deadline is None else \
                    max(deadline - time.time(), 0.0)
                if not self._work.wait(timeout=remaining):
                    raise TimeoutError(
                        f"barrier: {self._tasks_pending} tasks pending")

    def stats(self) -> Dict[str, Any]:
        s = dict(self._stats)
        s["staging_hits"] = self.staging.hits
        s["staging_misses"] = self.staging.misses
        s["evictions"] = self.memory.evictions
        return s

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            self._work.notify_all()
        self._xfer_q.put(None)
        for t in self._threads:
            t.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()

    # ------------------------------------------------------------------
    # host access protocol
    # ------------------------------------------------------------------
    def _request_host(self, obj: HeteroObject, write: bool) -> HFuture:
        fut = self.futures.acquire()

        def deliver():
            arr = self._stage_to_host(obj)
            with obj.lock:
                obj.host_pins += 1
                if write:
                    # invalidate device copies: host becomes the only valid one
                    for sp in [s for s in obj.copies if s != HOST]:
                        self._drop_copy(obj, sp)
            fut.set_result(arr)

        with self._lock:
            lw = obj.last_writer
        if lw is not None and not lw.done():
            lw.future.add_done_callback(lambda _: deliver())
        else:
            deliver()
        return fut

    def _release_host(self, obj: HeteroObject) -> None:
        with obj.lock:
            obj.host_pins = max(0, obj.host_pins - 1)

    def _free_object(self, obj: HeteroObject) -> None:
        with obj.lock:
            for sp in list(obj.copies):
                self._drop_copy(obj, sp)

    # ------------------------------------------------------------------
    # data movement / coherence
    # ------------------------------------------------------------------
    def _device(self, device_id: int) -> Device:
        return self.devices[device_id]

    def _drop_copy(self, obj: HeteroObject, space: int) -> None:
        if space in obj.copies:
            del obj.copies[space]
            if space != HOST:
                self.memory.unregister(space, obj, obj.nbytes)

    def _stage_to_host(self, obj: HeteroObject) -> np.ndarray:
        with obj.lock:
            if HOST in obj.copies:
                return obj.copies[HOST]
            src = next(iter(obj.copies), None)
        if src is None:
            arr = self.staging.acquire(obj.shape, obj.dtype)
            arr[...] = 0
        else:
            dev_arr = obj.copies[src]
            arr = self._device(src).download(dev_arr)
            self._stats["transfers_d2h"] += 1
            self._stats["bytes_d2h"] += obj.nbytes
        with obj.lock:
            obj.copies[HOST] = arr
        return arr

    def _evict(self, obj: HeteroObject, device_id: int) -> bool:
        """LRU eviction callback: spill to host unless busy (paper §3.1.1)."""
        if obj.busy():
            return False
        with obj.lock:
            if device_id not in obj.copies:
                return False
            if len(obj.copies) == 1:      # device holds the only valid copy
                pass                       # must stage out first
        self._stage_to_host(obj)
        with obj.lock:
            self._drop_copy(obj, device_id)
        return True

    def _ensure_on_device(self, obj: HeteroObject, device_id: int,
                          will_write: bool) -> Any:
        """Coherence walk: make a VALID copy resident on device_id."""
        with obj.lock:
            if device_id in obj.copies:
                arr = obj.copies[device_id]
                self.memory.touch(device_id, obj)
                if will_write:
                    for sp in [s for s in obj.copies if s != device_id]:
                        self._drop_copy(obj, sp)
                return arr
        # need a transfer: source preference: host, else any device (staged
        # through host — the paper's generic path)
        host_arr = self._stage_to_host(obj)
        self.memory.ensure_capacity(device_id, obj.nbytes, self._evict)
        dev_arr = self._device(device_id).upload(host_arr)
        self._stats["transfers_h2d"] += 1
        self._stats["bytes_h2d"] += obj.nbytes
        with obj.lock:
            obj.copies[device_id] = dev_arr
            self.memory.register(device_id, obj, obj.nbytes)
            if will_write:
                for sp in [s for s in obj.copies if s != device_id]:
                    self._drop_copy(obj, sp)
        return dev_arr

    # ------------------------------------------------------------------
    # workers
    # ------------------------------------------------------------------
    def _start_workers(self):
        n = len(self.devices) if self.cfg.dedicated_threads else 1
        for i in range(n):
            hint = self.devices[i].info.device_id \
                if self.cfg.dedicated_threads else None
            th = threading.Thread(target=self._worker, args=(hint,),
                                  daemon=True, name=f"repro-worker-{i}")
            th.start()
            self._threads.append(th)
        if self.cfg.transfer_thread:
            th = threading.Thread(target=self._transfer_worker, daemon=True,
                                  name="repro-xfer")
            th.start()
            self._threads.append(th)

    def _transfer_worker(self):
        while True:
            item = self._xfer_q.get()
            if item is None:
                return
            fn, fut = item
            try:
                fut.set_result(fn())
            except BaseException as e:   # pragma: no cover
                fut.set_error(e)

    def _async_transfer(self, fn: Callable) -> HFuture:
        fut = self.futures.acquire()
        if self.cfg.transfer_thread:
            self._xfer_q.put((fn, fut))
        else:
            try:
                fut.set_result(fn())
            except BaseException as e:   # pragma: no cover
                fut.set_error(e)
        return fut

    def _worker(self, device_hint: Optional[int]):
        inflight: List[Tuple[HeteroTask, Any]] = []
        while True:
            with self._lock:
                if self._shutdown:
                    return
                item = self.scheduler.pop(device_hint)
                if item is not None:
                    task, dev = item
                    task.state = TaskState.RUNNING
                    task.chosen_device = dev
                    self.scheduler.load[dev] += 1
            if item is None:
                # poll in-flight completions; park if nothing to do
                if inflight:
                    self._poll_inflight(inflight, block_one=True)
                    continue
                with self._lock:
                    if self._shutdown:
                        return
                    self._work.wait(timeout=self.cfg.poll_interval_s * 20)
                continue
            task, dev = item
            try:
                handle = self._launch(task, dev)
            except BaseException as e:
                self._finish(task, error=e)
                continue
            if self.cfg.sync_dispatch or self.cfg.inflight <= 1:
                self._device(dev).synchronize(handle)
                self._finish(task, result=handle)
            else:
                inflight.append((task, handle))
                if len(inflight) >= self.cfg.inflight:
                    self._poll_inflight(inflight, block_one=True)

    def _poll_inflight(self, inflight: List, block_one: bool = False):
        still: List = []
        finished = []
        for task, handle in inflight:
            if self._device(task.chosen_device).is_ready(handle):
                finished.append((task, handle))
            else:
                still.append((task, handle))
        if block_one and not finished and still:
            task, handle = still.pop(0)
            self._device(task.chosen_device).synchronize(handle)
            finished.append((task, handle))
        inflight[:] = still
        for task, handle in finished:
            self._finish(task, result=handle)

    def _launch(self, task: HeteroTask, device_id: int):
        """Stage args, then launch asynchronously via the Device API."""
        dev_args = []
        donate = []
        for i, ref in enumerate(task.args):
            arr = self._ensure_on_device(ref.obj, device_id,
                                         will_write=False)
            dev_args.append(arr)
            if ref.access.writes and self.cfg.cache_jit:
                donate.append(i)
        handle = self._device(device_id).launch(
            task.kernel, tuple(dev_args), donate=tuple(donate))
        # bind outputs back onto the written hetero_objects
        outs = handle if isinstance(handle, (tuple, list)) else (handle,)
        wi = 0
        for ref in task.args:
            if ref.access.writes:
                if wi < len(outs):
                    new_arr = outs[wi]
                    with ref.obj.lock:
                        for sp in list(ref.obj.copies):
                            self._drop_copy(ref.obj, sp)
                        ref.obj.copies[device_id] = new_arr
                        self.memory.register(device_id, ref.obj,
                                             ref.obj.nbytes)
                wi += 1
        return handle

    def _finish(self, task: HeteroTask, result=None, error=None):
        with self._lock:
            if error is not None:
                task.state = TaskState.FAILED
            else:
                task.state = TaskState.DONE
            if task.chosen_device is not None:
                self.scheduler.load[task.chosen_device] -= 1
            ready = dep.retire(task)
            for r in ready:
                r.state = TaskState.READY
                self.scheduler.push(r)
            self._tasks_pending -= 1
            self._work.notify_all()
        if error is not None:
            task.future.set_error(error)
        else:
            task.future.set_result(result)
