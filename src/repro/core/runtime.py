"""Core Runtime (paper §3.1.3/§3.1.5): the glue between application
preferences, the scheduler, and the Device API.

Execution model (faithful to the paper):
  submit() appends an execution request and returns immediately;
  dependencies are inferred (or explicit); blocked tasks wait for their
  dependencies; runnable tasks go to the scheduler; per-device worker
  threads ("dedicated threads", paper Fig. 9) pop work, stage argument
  copies onto their device, launch asynchronously through the Device API,
  and retire tasks as results become ready.

Transfer engine (paper §3.2.3 + §4.1.3)
---------------------------------------
Data movement is a first-class subsystem with three cooperating parts:

  * Direct device-to-device path (``d2d`` toggle): when a task needs an
    object whose only valid copies live on *other* devices, the coherence
    walk moves it with one Device API ``transfer`` (device→device over the
    interconnect) instead of the generic D2H + H2D bounce through host
    memory — the paper's "device-aware interconnect" path (Fig. 7), worth
    up to 20% over staged MPI+CUDA for large messages.
  * Per-device transfer queues (``transfer_thread`` toggle): one dedicated
    transfer worker per device (paper §4.1.3's dedicated transfer queue,
    generalized), so copies targeting different devices never serialize
    behind each other and always overlap compute.
  * Argument prefetch pipeline (``prefetch`` toggle): after launching a
    task, the worker immediately claims its *next* task from the scheduler
    (``Scheduler.assign``) and enqueues that task's argument transfers on
    the transfer queues — the copies run while the current task computes,
    and ``_launch`` merely awaits already-in-flight transfers. Hits are
    counted in ``stats()["prefetch_hits"]``.

Large host→device copies are chunked through the ``StagingPool``
(page-locked buffer analogue) in ``staging_chunk_bytes`` pieces, and pool
buffers are recycled: staging buffers return to the pool when a host copy
is dropped, transfer futures return to the ``RequestPool`` once consumed.

Configuration toggles map 1:1 to the paper's optimization ladder (Fig. 8)
so the benchmark can reproduce it:
  staging_pool     — §4.1.1 page-locked host memory pool
  cache_jit        — §4.1.2 custom device allocator (jit cache + donation)
  request_pool     — §4.1.4 request pools
  transfer_thread  — §4.1.3 dedicated transfer queues (one per device)
  inflight         — §4.1.3 multiple compute queues (async window)
  dedicated_threads— §4.1.6 one worker per device
  prefetch         — §4.1.3 transfer/compute overlap (argument pipeline)
  d2d              — §3.2.3 direct device-to-device transfers
"""
from __future__ import annotations

import collections
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dependency as dep
from repro.core import device_api
from repro.core.device_api import Device, JaxDevice, discover_devices
from repro.core.futures import HFuture
from repro.core.hetero_object import HOST, HeteroObject
from repro.core.hetero_task import Access, HeteroTask, TaskState
from repro.core.memory import MemoryMonitor, RequestPool, StagingPool
from repro.core.scheduler import SCHEDULERS, Scheduler


@dataclasses.dataclass
class RuntimeConfig:
    scheduler: str = "locality"
    staging_pool: bool = True
    cache_jit: bool = True
    request_pool: bool = True
    transfer_thread: bool = True
    inflight: int = 4             # async launches in flight per device
    dedicated_threads: bool = True
    sync_dispatch: bool = False   # TF-Baseline: block after every launch
    d2d: bool = True              # direct device→device transfers (§3.2.3)
    prefetch: bool = True         # argument prefetch pipeline (§4.1.3)
    memory_capacity: Optional[int] = None
    staging_chunk_bytes: int = 8 << 20   # chunk host uploads above this size
    poll_interval_s: float = 0.0005


class Runtime:
    def __init__(self, config: Optional[RuntimeConfig] = None,
                 devices: Optional[List[Device]] = None):
        self.cfg = config or RuntimeConfig()
        self.devices: List[Device] = devices if devices is not None else \
            discover_devices(self.cfg.memory_capacity, self.cfg.cache_jit)
        for d in self.devices:
            if isinstance(d, JaxDevice):
                d.cache_jit = self.cfg.cache_jit
        self.memory = MemoryMonitor(
            {d.info.device_id: d.info.memory_capacity for d in self.devices})
        self.scheduler: Scheduler = SCHEDULERS[self.cfg.scheduler](
            {d.info.device_id: d.info.device_type for d in self.devices})
        self.staging = StagingPool(self.cfg.staging_pool)
        self.futures = RequestPool(HFuture, self.cfg.request_pool)
        self._lock = threading.RLock()
        self._work = threading.Condition(self._lock)
        self._tasks_pending = 0
        self._shutdown = False
        self._stats = {"tasks": 0, "transfers_h2d": 0, "transfers_d2h": 0,
                       "transfers_d2d": 0, "bytes_h2d": 0, "bytes_d2h": 0,
                       "bytes_d2d": 0, "prefetch_hits": 0,
                       "prefetch_misses": 0}
        self._threads: List[threading.Thread] = []
        # one transfer queue per device (paper §4.1.3, generalized): copies
        # bound for different devices proceed independently
        self._xfer_qs: Dict[int, "queue.Queue"] = {}
        self._start_workers()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def hetero_object(self, value=None, shape=None, dtype=None,
                      name: str = "") -> HeteroObject:
        return HeteroObject(self, value=value, shape=shape, dtype=dtype,
                            name=name)

    def adopt_device_array(self, dev_array: Any, device_id: int = 0,
                           name: str = "") -> HeteroObject:
        """Wrap an array already resident on ``device_id`` into a
        HeteroObject without a host bounce — the receiver half of the
        distributed DIRECT payload path (paper §3.2.3)."""
        obj = HeteroObject(self, shape=tuple(dev_array.shape),
                           dtype=np.dtype(dev_array.dtype), name=name)
        self.memory.ensure_capacity(device_id, obj.nbytes, self._evict)
        with obj.lock:
            obj.copies[device_id] = dev_array
            self.memory.register(device_id, obj, obj.nbytes)
        return obj

    def submit(self, task: HeteroTask, kernel: Callable) -> HFuture:
        """Enqueue an execution request; returns the task's future."""
        task.kernel = kernel
        with self._lock:
            task.state = TaskState.SUBMITTED
            self._tasks_pending += 1
            self._stats["tasks"] += 1
            n = dep.infer_dependencies(task)
            if n > 0:
                task.state = TaskState.BLOCKED
            else:
                task.state = TaskState.READY
                self.scheduler.push(task)
            self._work.notify_all()
        return task.future

    def run(self, kernel: Callable, args: Sequence[Tuple[HeteroObject, str]],
            device_type: Optional[str] = None, name: str = "") -> HeteroTask:
        """Convenience: build + submit in one call.
        args: [(obj, 'r'|'w'|'rw'), ...]."""
        t = HeteroTask(name=name)
        for obj, mode in args:
            getattr(t.arg(obj), {"r": "read", "w": "write",
                                 "rw": "rw"}[mode])()
        t.device(device_type)
        self.submit(t, kernel)
        return t

    def barrier(self, timeout: Optional[float] = 120.0) -> None:
        """Wait until every submitted task has retired."""
        deadline = None if timeout is None else time.time() + timeout
        with self._lock:
            while self._tasks_pending > 0:
                remaining = None if deadline is None else \
                    max(deadline - time.time(), 0.0)
                if not self._work.wait(timeout=remaining):
                    raise TimeoutError(
                        f"barrier: {self._tasks_pending} tasks pending")

    def stats(self) -> Dict[str, Any]:
        s = dict(self._stats)
        s["staging_hits"] = self.staging.hits
        s["staging_misses"] = self.staging.misses
        s["evictions"] = self.memory.evictions
        return s

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            self._work.notify_all()
        for q_ in self._xfer_qs.values():
            q_.put(None)
        for t in self._threads:
            t.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()

    # ------------------------------------------------------------------
    # host access protocol
    # ------------------------------------------------------------------
    def _request_host(self, obj: HeteroObject, write: bool) -> HFuture:
        fut = self.futures.acquire()

        def deliver():
            arr = self._stage_to_host(obj)
            with obj.lock:
                if write and not arr.flags.writeable:
                    # downloads can be read-only zero-copy views of device
                    # buffers; a write pin must hand out a writable copy
                    arr = np.array(arr)
                    obj.copies[HOST] = arr
                    obj._pooled_host = False
                obj.host_pins += 1
                if write:
                    # invalidate device copies: host becomes the only valid one
                    for sp in [s for s in obj.copies if s != HOST]:
                        self._drop_copy(obj, sp)
            fut.set_result(arr)

        with self._lock:
            lw = obj.last_writer
        if lw is not None and not lw.done():
            lw.future.add_done_callback(lambda _: deliver())
        else:
            deliver()
        return fut

    def _request_device_view(self, obj: HeteroObject) -> HFuture:
        """Async view of an object's freshest copy WITHOUT host staging:
        resolves (after conflicting writers retire) to ``(space, array)``
        where space is a device id (jax array — snapshot-safe because jax
        arrays are immutable) or HOST (defensive np copy). The distributed
        DIRECT send path uses this so the payload never bounces via host.

        The view takes a *device pin* at request time (program order, like
        the paper's read-access request): while pinned, launches won't
        donate this object's buffers. Under that protection the deliver
        step snapshots a private on-device ``clone`` of the copy, then
        drops the pin — the clone is referenced by nothing else, so no
        later donation can delete the payload mid-flight."""
        with obj.lock:
            obj.device_pins += 1
        fut = self.futures.acquire()

        def deliver():
            try:
                with obj.lock:
                    dev_sp = next((s for s in obj.copies if s != HOST), None)
                    if dev_sp is not None:
                        snap = self._device(dev_sp).clone(obj.copies[dev_sp])
                    elif HOST in obj.copies:
                        snap = np.array(obj.copies[HOST])
                    else:
                        snap = np.zeros(obj.shape, obj.dtype)
                if dev_sp is not None and hasattr(snap, "block_until_ready"):
                    snap.block_until_ready()   # clone must finish reading
                fut.set_result((dev_sp if dev_sp is not None else HOST,
                                snap))
            finally:
                self._release_device_view(obj)

        with self._lock:
            lw = obj.last_writer
        if lw is not None and not lw.done():
            lw.future.add_done_callback(lambda _: deliver())
        else:
            deliver()
        return fut

    def _release_host(self, obj: HeteroObject) -> None:
        with obj.lock:
            obj.host_pins = max(0, obj.host_pins - 1)

    def _release_device_view(self, obj: HeteroObject) -> None:
        with obj.lock:
            obj.device_pins = max(0, obj.device_pins - 1)

    def _free_object(self, obj: HeteroObject) -> None:
        with obj.lock:
            for sp in list(obj.copies):
                self._drop_copy(obj, sp)

    # ------------------------------------------------------------------
    # data movement / coherence
    # ------------------------------------------------------------------
    def _device(self, device_id: int) -> Device:
        return self.devices[device_id]

    def _drop_copy(self, obj: HeteroObject, space: int) -> None:
        if space in obj.copies:
            arr = obj.copies.pop(space)
            if space != HOST:
                self.memory.unregister(space, obj, obj.nbytes)
            elif getattr(obj, "_pooled_host", False) and obj.host_pins == 0:
                # recycle the staging buffer (paper §4.1.1: the page-locked
                # pool only pays off if buffers actually return to it)
                self.staging.release(arr)
                obj._pooled_host = False

    def _stage_to_host(self, obj: HeteroObject) -> np.ndarray:
        with obj.lock:
            if HOST in obj.copies:
                return obj.copies[HOST]
            src = next(iter(obj.copies), None)
        if src is None:
            arr = self.staging.acquire(obj.shape, obj.dtype)
            arr[...] = 0
            pooled = True
        else:
            dev_arr = obj.copies[src]
            arr = self._device(src).download(dev_arr)
            self._stats["transfers_d2h"] += 1
            self._stats["bytes_d2h"] += obj.nbytes
            pooled = False
        with obj.lock:
            obj.copies[HOST] = arr
            obj._pooled_host = pooled
        return arr

    def _upload_host(self, device: Device, host_arr: np.ndarray) -> Any:
        """Host→device copy; large arrays stream through pooled staging
        buffers in ``staging_chunk_bytes`` pieces (page-locked pool
        analogue) so one giant transfer can't monopolize host memory."""
        chunk = self.cfg.staging_chunk_bytes
        if (not self.staging.enabled or chunk <= 0
                or host_arr.nbytes <= chunk or host_arr.ndim == 0
                or host_arr.shape[0] < 2):
            return device.upload(host_arr)
        import jax.numpy as jnp
        row_bytes = max(1, host_arr.nbytes // host_arr.shape[0])
        rows_per = max(1, chunk // row_bytes)
        pieces, bufs = [], []
        for i in range(0, host_arr.shape[0], rows_per):
            part = host_arr[i:i + rows_per]
            buf = self.staging.acquire(part.shape, part.dtype)
            np.copyto(buf, part)
            pieces.append(device.upload(buf))
            bufs.append(buf)
        # one barrier for the whole batch (chunk DMAs overlap each other);
        # buffers may only return to the pool once their DMA completed
        for piece in pieces:
            if hasattr(piece, "block_until_ready"):
                piece.block_until_ready()
        for buf in bufs:
            self.staging.release(buf)
        return jnp.concatenate(pieces, axis=0)

    def _evict(self, obj: HeteroObject, device_id: int) -> bool:
        """LRU eviction callback: spill to host unless busy (paper §3.1.1)."""
        if obj.busy():
            return False
        with obj.lock:
            if device_id not in obj.copies:
                return False
            if len(obj.copies) == 1:      # device holds the only valid copy
                pass                       # must stage out first
        self._stage_to_host(obj)
        with obj.lock:
            self._drop_copy(obj, device_id)
        return True

    def _ensure_on_device(self, obj: HeteroObject, device_id: int,
                          will_write: bool) -> Any:
        """Coherence walk: make a VALID copy resident on device_id.

        Source preference (paper §3.2.3): (1) already resident — no copy;
        (2) another device holds a copy and d2d is on — one direct
        device→device transfer; (3) generic path — stage through host."""
        with obj.lock:
            if device_id in obj.copies:
                arr = obj.copies[device_id]
                self.memory.touch(device_id, obj)
                if will_write:
                    for sp in [s for s in obj.copies if s != device_id]:
                        self._drop_copy(obj, sp)
                return arr
            src_dev = None
            src_arr = None
            if self.cfg.d2d:
                src_dev = next((s for s in obj.copies if s != HOST), None)
                if src_dev is not None:
                    src_arr = obj.copies[src_dev]
        if src_dev is not None:
            # direct D2D: never materializes a host copy (jax arrays are
            # immutable, so the snapshot taken above stays valid even if the
            # source copy is concurrently evicted)
            self.memory.ensure_capacity(device_id, obj.nbytes, self._evict)
            dev_arr = device_api.transfer(self._device(src_dev),
                                          self._device(device_id), src_arr)
            self._stats["transfers_d2d"] += 1
            self._stats["bytes_d2d"] += obj.nbytes
        else:
            host_arr = self._stage_to_host(obj)
            # the chunked path transiently holds pieces + their concatenated
            # result on device, so reserve double before choosing it
            chunked = (self.staging.enabled
                       and 0 < self.cfg.staging_chunk_bytes < obj.nbytes)
            self.memory.ensure_capacity(
                device_id, obj.nbytes * (2 if chunked else 1), self._evict)
            dev_arr = self._upload_host(self._device(device_id), host_arr)
            self._stats["transfers_h2d"] += 1
            self._stats["bytes_h2d"] += obj.nbytes
        with obj.lock:
            if device_id in obj.copies:        # raced with another walker
                dev_arr = obj.copies[device_id]
            else:
                obj.copies[device_id] = dev_arr
                self.memory.register(device_id, obj, obj.nbytes)
            if will_write:
                for sp in [s for s in obj.copies if s != device_id]:
                    self._drop_copy(obj, sp)
        return dev_arr

    # ------------------------------------------------------------------
    # workers
    # ------------------------------------------------------------------
    def _start_workers(self):
        n = len(self.devices) if self.cfg.dedicated_threads else 1
        for i in range(n):
            hint = self.devices[i].info.device_id \
                if self.cfg.dedicated_threads else None
            th = threading.Thread(target=self._worker, args=(hint,),
                                  daemon=True, name=f"repro-worker-{i}")
            th.start()
            self._threads.append(th)
        if self.cfg.transfer_thread:
            for d in self.devices:
                q_: "queue.Queue" = queue.Queue()
                self._xfer_qs[d.info.device_id] = q_
                th = threading.Thread(
                    target=self._transfer_worker, args=(q_,), daemon=True,
                    name=f"repro-xfer-{d.info.device_id}")
                th.start()
                self._threads.append(th)

    def _transfer_worker(self, q_: "queue.Queue"):
        while True:
            item = q_.get()
            if item is None:
                return
            fn, fut = item
            try:
                fut.set_result(fn())
            except BaseException as e:   # pragma: no cover
                fut.set_error(e)

    def _async_transfer(self, device_id: int, fn: Callable) -> HFuture:
        """Run ``fn`` on ``device_id``'s transfer queue (or inline when the
        transfer threads are disabled). Returns a pooled future."""
        fut = self.futures.acquire()
        q_ = self._xfer_qs.get(device_id)
        if q_ is not None:
            q_.put((fn, fut))
        else:
            try:
                fut.set_result(fn())
            except BaseException as e:   # pragma: no cover
                fut.set_error(e)
        return fut

    # -- argument prefetch pipeline ------------------------------------
    def _try_prefetch(self, device_hint: Optional[int]):
        """Claim the next task early (Scheduler.assign) and enqueue its
        argument transfers so they overlap the current task's compute.
        Returns (task, dev, transfer-future-or-None); the future resolves
        to {obj_id: device array}. All of a task's arguments stage as ONE
        transfer-queue item (per-argument handoffs cost more than they
        overlap), and fully-resident tasks skip the queue entirely."""
        with self._lock:
            if self._shutdown:
                return None
            item = self.scheduler.assign(device_hint)
            if item is None:
                return None
            task, dev = item
            task.state = TaskState.RUNNING
            task.chosen_device = dev
            self.scheduler.load[dev] += 1
        objs = []
        seen = set()
        for ref in task.args:
            if id(ref.obj) not in seen:
                seen.add(id(ref.obj))
                objs.append(ref.obj)
        need = frozenset(id(o) for o in objs if not o.has_copy(dev))
        if not need:
            return task, dev, None          # nothing to move
        fut = self._async_transfer(dev, lambda: (
            {id(o): self._ensure_on_device(o, dev, False) for o in objs},
            need))
        return task, dev, fut

    def _worker(self, device_hint: Optional[int]):
        inflight: List[Tuple[HeteroTask, Any]] = []
        staged: "collections.deque" = collections.deque()  # prefetched tasks
        while True:
            pmap = None
            if staged:
                task, dev, pmap = staged.popleft()
                item = (task, dev)
            else:
                with self._lock:
                    if self._shutdown:
                        return
                    item = self.scheduler.pop(device_hint)
                    if item is not None:
                        task, dev = item
                        task.state = TaskState.RUNNING
                        task.chosen_device = dev
                        self.scheduler.load[dev] += 1
            if item is None:
                # poll in-flight completions; park if nothing to do
                if inflight:
                    self._poll_inflight(inflight, block_one=True)
                    continue
                with self._lock:
                    if self._shutdown:
                        return
                    self._work.wait(timeout=self.cfg.poll_interval_s * 20)
                continue
            task, dev = item
            try:
                handle = self._launch(task, dev, pmap)
            except BaseException as e:
                self._finish(task, error=e)
                continue
            # pipeline: claim the next task + start its transfers while the
            # launch above computes
            if self.cfg.prefetch and not staged:
                nxt = self._try_prefetch(device_hint)
                if nxt is not None:
                    staged.append(nxt)
            if self.cfg.sync_dispatch or self.cfg.inflight <= 1:
                self._device(dev).synchronize(handle)
                self._finish(task, result=handle)
            else:
                inflight.append((task, handle))
                if len(inflight) >= self.cfg.inflight:
                    self._poll_inflight(inflight, block_one=True)

    def _poll_inflight(self, inflight: List, block_one: bool = False):
        still: List = []
        finished = []
        for task, handle in inflight:
            if self._device(task.chosen_device).is_ready(handle):
                finished.append((task, handle))
            else:
                still.append((task, handle))
        if block_one and not finished and still:
            task, handle = still.pop(0)
            self._device(task.chosen_device).synchronize(handle)
            finished.append((task, handle))
        inflight[:] = still
        for task, handle in finished:
            self._finish(task, result=handle)

    def _launch(self, task: HeteroTask, device_id: int,
                prefetched: Optional[HFuture] = None):
        """Await prefetched argument copies (or stage synchronously), then
        launch asynchronously via the Device API."""
        staged: Dict[int, Any] = {}
        needed: frozenset = frozenset()
        if prefetched is not None:
            # transfers were issued when the task was assigned; by now they
            # are usually done — the overlap the paper's transfer queue
            # buys (§4.1.3)
            staged, needed = prefetched.get()
            self.futures.release(prefetched)
        dev_args = []
        donate = []
        for i, ref in enumerate(task.args):
            arr = staged.get(id(ref.obj))
            if arr is not None:
                if id(ref.obj) in needed:   # an actually-overlapped copy
                    self._stats["prefetch_hits"] += 1
            else:
                if self.cfg.prefetch and prefetched is None \
                        and not ref.obj.has_copy(device_id):
                    # popped directly (pipeline empty): the copy could not
                    # be overlapped with compute
                    self._stats["prefetch_misses"] += 1
                arr = self._ensure_on_device(ref.obj, device_id,
                                             will_write=False)
            dev_args.append(arr)
            if (ref.access.writes and self.cfg.cache_jit
                    and ref.obj.device_pins == 0):
                donate.append(i)
        handle = self._device(device_id).launch(
            task.kernel, tuple(dev_args), donate=tuple(donate))
        # bind outputs back onto the written hetero_objects
        outs = handle if isinstance(handle, (tuple, list)) else (handle,)
        wi = 0
        for ref in task.args:
            if ref.access.writes:
                if wi < len(outs):
                    new_arr = outs[wi]
                    with ref.obj.lock:
                        for sp in list(ref.obj.copies):
                            self._drop_copy(ref.obj, sp)
                        ref.obj.copies[device_id] = new_arr
                        self.memory.register(device_id, ref.obj,
                                             ref.obj.nbytes)
                wi += 1
        return handle

    def _finish(self, task: HeteroTask, result=None, error=None):
        with self._lock:
            if error is not None:
                task.state = TaskState.FAILED
            else:
                task.state = TaskState.DONE
            if task.chosen_device is not None:
                self.scheduler.load[task.chosen_device] -= 1
            ready = dep.retire(task)
            for r in ready:
                r.state = TaskState.READY
                self.scheduler.push(r)
            self._tasks_pending -= 1
            self._work.notify_all()
        if error is not None:
            task.future.set_error(error)
        else:
            task.future.set_result(result)
