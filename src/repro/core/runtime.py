"""Core Runtime (paper §3.1.3/§3.1.5): the glue between application
preferences, the scheduler, and the Device API.

Execution model (faithful to the paper):
  submit() appends an execution request and returns immediately;
  dependencies are inferred (or explicit); blocked tasks wait for their
  dependencies; runnable tasks go to the scheduler; per-device worker
  threads ("dedicated threads", paper Fig. 9) pop work, stage argument
  copies onto their device, launch asynchronously through the Device API,
  and retire tasks as results become ready.

Transfer engine (paper §3.2.3 + §4.1.3)
---------------------------------------
Data movement is a first-class subsystem with three cooperating parts:

  * Direct device-to-device path (``d2d`` toggle): when a task needs an
    object whose only valid copies live on *other* devices, the coherence
    walk moves it with one Device API ``transfer`` (device→device over the
    interconnect) instead of the generic D2H + H2D bounce through host
    memory — the paper's "device-aware interconnect" path (Fig. 7), worth
    up to 20% over staged MPI+CUDA for large messages.
  * Per-device transfer queues (``transfer_thread`` toggle): one dedicated
    transfer worker per device (paper §4.1.3's dedicated transfer queue,
    generalized), so copies targeting different devices never serialize
    behind each other and always overlap compute.
  * Argument prefetch pipeline (``prefetch`` toggle, depth via
    ``prefetch_depth``): after launching a task, the worker claims up to
    ``prefetch_depth`` next tasks from the scheduler (``Scheduler.assign``)
    and enqueues their argument transfers on the transfer queues — the
    copies run while the current task computes, and ``_launch`` merely
    awaits already-in-flight transfers. The queues are *priority* queues,
    FIFO within a priority level: the immediately-next task's arguments
    (depth 1) are never scheduled behind deeper staging — in the default
    one-producer-per-queue pipeline enqueue order already guarantees
    this, and the explicit priorities keep the invariant for any future
    multi-producer path (e.g. cross-worker staging or queued demand
    transfers). ``stats()["prefetch_hits"]`` counts argument copies that had
    fully completed by launch time (true overlap);
    ``stats()["prefetch_stalls"]`` counts copies that were claimed early
    but still had to be awaited.

Residency & placement (paper §3.1.1 + §3.1.3): a ``ResidencyLedger``
(``core/residency.py``) is the single source of truth for which devices
hold valid replicas of each object, with per-device byte accounting and
LRU eviction. The scheduler's placement cost model scores devices against
the ledger (data-gravity: bytes-to-move minus bytes-resident), and the
distributed layer asks it where payloads with no known consumer should
land.

Large host→device copies are chunked through the ``StagingPool``
(page-locked buffer analogue) in ``staging_chunk_bytes`` pieces, and the
mirrored device→host path stages downloads into pooled buffers the same
way — so host copies never alias device buffers that donation might
recycle. Pool buffers are recycled: staging buffers return to the pool
when a host copy is dropped, transfer futures return to the
``RequestPool`` once consumed.

Configuration toggles map 1:1 to the paper's optimization ladder (Fig. 8)
so the benchmark can reproduce it:
  staging_pool     — §4.1.1 page-locked host memory pool
  cache_jit        — §4.1.2 custom device allocator (jit cache + donation)
  request_pool     — §4.1.4 request pools
  transfer_thread  — §4.1.3 dedicated transfer queues (one per device)
  inflight         — §4.1.3 multiple compute queues (async window)
  dedicated_threads— §4.1.6 one worker per device
  prefetch         — §4.1.3 transfer/compute overlap (argument pipeline)
  prefetch_depth   — §4.1.3 pipeline depth (tasks claimed ahead per worker)
  d2d              — §3.2.3 direct device-to-device transfers
  scheduler        — §3.1.4 placement policy ("gravity" = data-gravity)
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import clock
from repro.core import dependency as dep
from repro.core import device_api
from repro.core import sanitizer
from repro.core.device_api import Device, JaxDevice, discover_devices
from repro.core.futures import HFuture
from repro.core.hetero_object import HOST, HeteroObject
from repro.core.hetero_task import HeteroTask, TaskState
from repro.core.lineage import LineageLedger
from repro.core.memory import RequestPool, StagingPool
from repro.core.progress import ProgressEngine
from repro.core.residency import PLACEMENTS, ResidencyLedger
from repro.core.scheduler import SCHEDULERS, Scheduler
from repro.core.taskgraph import GraphTracer
from repro.core.topology import (InterconnectModel, probe_link,
                                 probe_runtime_links)


class InjectedTaskFault(RuntimeError):
    """Deterministic kernel fault planted by FaultInjector.fail_task."""


@dataclasses.dataclass
class RuntimeConfig:
    scheduler: str = "gravity"
    placement: Optional[str] = None   # override the scheduler's cost model
    staging_pool: bool = True
    cache_jit: bool = True
    request_pool: bool = True
    transfer_thread: bool = True
    inflight: int = 4             # async launches in flight per device
    dedicated_threads: bool = True
    sync_dispatch: bool = False   # TF-Baseline: block after every launch
    d2d: bool = True              # direct device→device transfers (§3.2.3)
    prefetch: bool = True         # argument prefetch pipeline (§4.1.3)
    prefetch_depth: int = 1       # tasks claimed ahead per worker
    memory_capacity: Optional[int] = None
    staging_chunk_bytes: int = 8 << 20   # chunk host uploads above this size
    poll_interval_s: float = 0.0005
    # -- interconnect topology / message protocol (paper §3.2.3 + §4.2) --
    topology_probe: bool = True   # startup micro-probe seeds the model
    topology_probe_bytes: int = 64 << 10
    # device pairs the startup host+ring probe did not cover are probed
    # lazily, once, on their first real transfer (ROADMAP follow-up c)
    lazy_probe: bool = True
    # distributed messages above this size switch from the eager
    # (monolithic) protocol to chunk-streamed rendezvous
    eager_threshold: int = 64 << 10
    # rendezvous chunk size targets this many ms per chunk at the
    # measured link bandwidth (bandwidth-delay-product sizing; several ms
    # per chunk keeps fixed per-chunk dispatch cost amortized);
    # chunk_bytes pins an explicit size instead (tests/benchmarks)
    chunk_target_ms: float = 4.0
    chunk_bytes: Optional[int] = None
    # rendezvous sliding window: how many chunks the receiver lets the
    # sender keep in flight per stream (credit-based flow control). None
    # runs the ADAPTIVE controller: the window starts at the measured
    # bandwidth-delay product of the rank pair and adapts mid-stream to
    # the receiver's drain rate (transfer-lane backlog halves it, min 1;
    # an empty lane widens it back toward the BDP ceiling). An explicit
    # int pins the window and bypasses adaptation (tests/benchmarks).
    net_window: Optional[int] = None
    # strict asynchronous-error mode: errors swallowed by fire-and-forget
    # progress-lane jobs or distributed pump handlers are re-raised at
    # the next barrier instead of only being counted
    # (stats()["progress_errors"] / Rank.stats["handler_errors"])
    strict_errors: bool = False
    # -- fault tolerance / elasticity (distributed layer) --
    # heartbeat cadence: each rank's pump emits a 0-byte control-VC
    # heartbeat to the monitor rank every interval; the elastic
    # controller declares a rank dead after timeout without one
    heartbeat_interval_s: float = 0.05
    heartbeat_timeout_s: float = 0.5
    # reliability layer (engaged by Cluster.fault_injector): eager
    # messages, RTS announcements and stream tails are retransmitted with
    # exponential backoff up to send_retries attempts before the send is
    # counted failed; receivers NACK stalled rendezvous streams on the
    # same backoff schedule
    send_retries: int = 5
    retry_backoff_s: float = 0.05
    retry_backoff_mult: float = 2.0
    retry_tick_s: float = 0.005
    # protocol timeouts (formerly hardcoded): tail-upload wait when a
    # rendezvous stream completes, the peer-removal sweep's net-send
    # rendezvous, and the pump-thread join at shutdown
    rdzv_finish_timeout_s: float = 120.0
    peer_sweep_timeout_s: float = 10.0
    pump_join_timeout_s: float = 5.0
    # -- compiled task-graph fast path (core/taskgraph.py) --
    # trace recurring submit windows (delimited by step_boundary()/
    # barrier()) and, once the same DAG recurred replay_after times,
    # replay it as fused per-chain dispatches that bypass per-task
    # scheduling. Opt-in: interior futures of replayed windows resolve
    # with None instead of a device handle.
    trace_graphs: bool = False
    replay_after: int = 3
    # shared progress-engine worker pool width (base threads servicing
    # ALL lanes; overflow workers spawn transiently when every base
    # worker is parked in a blocking job). 0 = legacy thread-per-lane.
    pool_workers: int = 4
    # -- end-to-end data integrity (core/integrity.py, core/lineage.py) --
    # verify_payloads: compute a content digest once at serialization for
    # every host-visible payload/chunk and verify it on receive; a failed
    # check counts in Rank.stats["checksum_fail"] and the bytes are
    # treated as never-arrived (the reliability layer retransmits), so
    # corruption surfaces as a retry — never a hang or a wrong answer
    verify_payloads: bool = True
    # ckpt_digest: per-leaf content digests in checkpoint manifests,
    # verified by restore/restore_leaf (Checkpointer honors this default
    # unless its own ctor argument overrides it)
    ckpt_digest: bool = True
    # lineage_depth: max producer-chain replay depth when coherence finds
    # an object with no valid replica anywhere (evicted-and-lost). 0
    # disables the lineage ledger entirely.
    lineage_depth: int = 4
    # task_retries: relaunch budget for a task whose kernel launch raised
    # (injected kernel faults, transient device errors) before the error
    # surfaces on the task future / strict barrier
    task_retries: int = 0
    # -- runtime collectives (distributed/collectives_rt.py) --
    # algorithm cutover: payloads at or below this many bytes run as
    # eager binomial trees (latency-bound regime), larger ones as
    # pipelined chunked rings (bandwidth-bound). Matches eager_threshold
    # by default — below it every ring hop would be an eager message
    # anyway, so the ring's pipelining buys nothing
    coll_ring_cutover_bytes: int = 64 << 10
    # cap on the credit window of op="reduce" rendezvous streams: every
    # in-flight reduce chunk is a fused add pending on the consumer
    # device's transfer lane, so this bounds accumulator-side device
    # work/memory independently of the AIMD ceiling. 0 = uncapped
    coll_max_inflight_chunks: int = 4
    # collective tag namespace: tags (which scope every stream and
    # handler invocation to one collective op) wrap at this size, so at
    # most this many collectives may be in flight per group at once
    coll_tag_space: int = 1 << 12
    # -- concurrency sanitizer (core/sanitizer.py) --
    # sanitize: install the process-global RuntimeSanitizer before this
    # runtime builds its locks — lock-order tracking, lane-discipline
    # enforcement, wait-graph barrier diagnostics, and gauge-hygiene
    # assertions at Rank shutdown. Defaults on when REPRO_SANITIZE=1
    # (the CI sanitize shard sets only the env var)
    sanitize: bool = dataclasses.field(default_factory=sanitizer.env_enabled)
    # contended-lock threshold: a tracked-lock acquire that waits at
    # least this long on a strict lane counts as a lane-blocking event
    sanitize_block_s: float = 0.010


class Runtime:
    def __init__(self, config: Optional[RuntimeConfig] = None,
                 devices: Optional[List[Device]] = None):
        self.cfg = config or RuntimeConfig()
        if self.cfg.sanitize:
            # must precede every lock construction below: the factories
            # consult the global sanitizer at creation time
            sanitizer.install(self.cfg.sanitize_block_s)
        self.devices: List[Device] = devices if devices is not None else \
            discover_devices(self.cfg.memory_capacity, self.cfg.cache_jit)
        for d in self.devices:
            if isinstance(d, JaxDevice):
                d.cache_jit = self.cfg.cache_jit
        self.residency = ResidencyLedger(
            {d.info.device_id: d.info.memory_capacity for d in self.devices})
        # measured per-link bandwidth/latency (paper §3.2.3): seeded by a
        # startup micro-probe, refined by every real transfer below, and
        # consumed by the gravity penalty, the scheduler's transfer-cost
        # estimates, and the distributed message protocol's chunk sizing
        self.topology = InterconnectModel()
        self.scheduler: Scheduler = SCHEDULERS[self.cfg.scheduler](
            {d.info.device_id: d.info.device_type for d in self.devices})
        if self.cfg.placement is not None:
            self.scheduler.placement = PLACEMENTS[self.cfg.placement]()
        self.scheduler.bind_residency(self.residency)
        self.scheduler.bind_topology(self.topology)
        if self.cfg.topology_probe:
            probe_runtime_links(self.topology, self.devices,
                                self.cfg.topology_probe_bytes)
        self.staging = StagingPool(self.cfg.staging_pool)
        self.futures = RequestPool(HFuture, self.cfg.request_pool)
        self._lock = sanitizer.make_rlock("Runtime._lock")
        self._work = sanitizer.make_condition(self._lock)
        self._tasks_pending = 0
        self._shutdown = False
        self._stats = {"tasks": 0, "transfers_h2d": 0, "transfers_d2h": 0,
                       "transfers_d2d": 0, "bytes_h2d": 0, "bytes_d2h": 0,
                       "bytes_d2d": 0, "prefetch_hits": 0,
                       "prefetch_misses": 0, "prefetch_stalls": 0,
                       "graphs_traced": 0, "graph_replays": 0,
                       "graph_invalidations": 0, "replayed_tasks": 0,
                       "lineage_recomputes": 0, "recompute_depth_peak": 0,
                       "task_retries": 0, "tasks_failed": 0}
        # lineage ledger: producer records for lost-replica recovery
        self.lineage: Optional[LineageLedger] = (
            LineageLedger() if self.cfg.lineage_depth > 0 else None)
        self._lineage_lock = sanitizer.make_rlock("Runtime._lineage_lock")
        self._recovering: set = set()       # cycle guard (object ids)
        self._failed_tasks: List[BaseException] = []
        self._inject_task_faults = 0        # FaultInjector.fail_task budget
        self._threads: List[threading.Thread] = []
        # unified progress engine (core/progress.py): one reactor owns
        # every asynchronous context this runtime needs — per-device
        # transfer lanes (paper §4.1.3, priority queues: the next task's
        # arguments outrank deeper prefetch staging), per-device launch
        # completion lanes (in-flight retire without the old block_one
        # polling loop), and — when a distributed Rank wraps this runtime
        # — its net-send / net-recv lanes
        self.engine = ProgressEngine(name="rt",
                                     strict=self.cfg.strict_errors,
                                     pool_workers=self.cfg.pool_workers)
        # compiled task-graph fast path (core/taskgraph.py): opt-in
        # tracer that turns recurring submit windows into fused replays
        self._tracer: Optional[GraphTracer] = (
            GraphTracer(self, self.cfg.replay_after)
            if self.cfg.trace_graphs else None)
        self._start_workers()

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------
    def hetero_object(self, value=None, shape=None, dtype=None,
                      name: str = "") -> HeteroObject:
        return HeteroObject(self, value=value, shape=shape, dtype=dtype,
                            name=name)

    def adopt_device_array(self, dev_array: Any, device_id: int = 0,
                           name: str = "") -> HeteroObject:
        """Wrap an array already resident on ``device_id`` into a
        HeteroObject without a host bounce — the receiver half of the
        distributed DIRECT payload path (paper §3.2.3)."""
        obj = HeteroObject(self, shape=tuple(dev_array.shape),
                           dtype=np.dtype(dev_array.dtype), name=name)
        self.residency.ensure_capacity(device_id, obj.nbytes, self._evict)
        with obj.lock:
            obj.copies[device_id] = dev_array
            self.residency.record(device_id, obj)
        return obj

    def rebind_device_copy(self, obj: HeteroObject, dev_array: Any,
                           device_id: int,
                           timeout: Optional[float] = 120.0) -> None:
        """Overwrite ``obj`` with an array already resident on
        ``device_id`` — the device half of the distributed put (paper
        §4.2.4): once conflicting writers retire, every existing copy is
        invalidated and the new device array becomes the only valid one.
        No host staging on either side."""
        if self._tracer is not None:
            self._tracer.flush()   # parked writes must be observable
        with self._lock:
            lw = obj.last_writer
        if lw is not None and not lw.done():
            lw.future.get(timeout)
        self.residency.ensure_capacity(device_id, obj.nbytes, self._evict)
        with obj.lock:
            for sp in list(obj.copies):
                self._drop_copy(obj, sp)
            obj.copies[device_id] = dev_array
            obj.generation += 1     # externally-written version
            self.residency.record(device_id, obj)

    def pick_landing_device(self, preferred: Optional[int] = None,
                            device_type: Optional[str] = None) -> int:
        """Where should externally-arriving data (a distributed DIRECT
        payload) land? The consumer task's device when the sender named
        one, else the residency ledger's least-loaded device (optionally
        restricted to ``device_type``) — never a hardwired device 0."""
        ids = {d.info.device_id for d in self.devices}
        if preferred is not None and preferred in ids:
            return preferred
        if device_type is not None:
            typed = {d.info.device_id for d in self.devices
                     if d.info.device_type == device_type}
            ids = typed or ids
        queued = getattr(self.scheduler, "queued", {})

        def pressure(d: int) -> int:
            return self.scheduler.load.get(d, 0) + queued.get(d, 0)

        return self.residency.least_loaded_device(pressure, among=ids)

    def submit(self, task: HeteroTask, kernel: Callable) -> HFuture:
        """Enqueue an execution request; returns the task's future."""
        task.kernel = kernel
        tracer = self._tracer
        if tracer is not None:
            with self._lock:
                task.state = TaskState.SUBMITTED
                self._tasks_pending += 1
                self._stats["tasks"] += 1
            # the tracer either parks the task for a compiled replay
            # (skipping pins / dependency inference / scheduling) or
            # tells us to run it interpreted while it records the window
            if not tracer.on_submit(task, kernel):
                self._enqueue(task)
            return task.future
        with self._lock:
            task.state = TaskState.SUBMITTED
            self._tasks_pending += 1
            self._stats["tasks"] += 1
            self._pin_and_schedule_locked(task)
        return task.future

    def _pin_and_schedule_locked(self, task: HeteroTask) -> None:
        # ledger-owned pins: every argument is protected from
        # eviction for the task's whole submitted→finished window
        # (the busy() object-lock walk the eviction path used to do)
        for obj in {id(r.obj): r.obj for r in task.args}.values():
            self.residency.pin(obj)
        n = dep.infer_dependencies(task)
        if n > 0:
            task.state = TaskState.BLOCKED
        else:
            task.state = TaskState.READY
            self.scheduler.push(task)
        self._work.notify_all()

    def _enqueue(self, task: HeteroTask) -> None:
        """Interpreted-path scheduling for an already-accounted task
        (normal submits under tracing, and parked tasks the tracer
        flushes back when a window deviates from its compiled graph)."""
        with self._lock:
            self._pin_and_schedule_locked(task)

    def step_boundary(self) -> None:
        """Declare the edge between two application steps — the window
        delimiter the task-graph tracer keys recurrence detection on
        (Jacobi iterations, serve steps, microbatch train steps). A
        no-op unless ``trace_graphs`` is enabled; ``barrier()`` is also
        a boundary, so drivers that barrier every step need no change."""
        if self._tracer is not None:
            self._tracer.on_boundary()

    def invalidate_traces(self) -> None:
        """Drop any compiled task graph and restart recurrence detection
        (called on ElasticRuntime epoch bumps: placements captured under
        the old epoch may name devices that rescaled away)."""
        if self._tracer is not None:
            self._tracer.invalidate()

    def run(self, kernel: Callable, args: Sequence[Tuple[HeteroObject, str]],
            device_type: Optional[str] = None, name: str = "") -> HeteroTask:
        """Convenience: build + submit in one call.
        args: [(obj, 'r'|'w'|'rw'), ...]."""
        t = HeteroTask(name=name)
        for obj, mode in args:
            getattr(t.arg(obj), {"r": "read", "w": "write",
                                 "rw": "rw"}[mode])()
        t.device(device_type)
        self.submit(t, kernel)
        return t

    def barrier(self, timeout: Optional[float] = 120.0) -> None:
        """Wait until every submitted task has retired."""
        if self._tracer is not None:
            # a barrier is a window boundary: replay a fully-matched
            # window (synchronously, so the wait below sees it retired)
            # or advance recurrence detection
            self._tracer.on_boundary()
        deadline = None if timeout is None else clock.now() + timeout
        with self._lock:
            while self._tasks_pending > 0:
                remaining = None if deadline is None else \
                    max(deadline - clock.now(), 0.0)
                if not self._work.wait(timeout=remaining):
                    raise TimeoutError(
                        f"barrier: {self._tasks_pending} tasks pending")
        # strict mode: a swallowed fire-and-forget progress error fails
        # the barrier instead of leaving a silently-dead continuation
        self.engine.check()
        if self.cfg.strict_errors:
            with self._lock:
                failed, self._failed_tasks = self._failed_tasks, []
            if failed:
                raise RuntimeError(
                    f"{len(failed)} task(s) failed since last barrier: "
                    f"{failed[0]!r}") from failed[0]

    def stats(self) -> Dict[str, Any]:
        s = dict(self._stats)
        s["staging_hits"] = self.staging.hits
        s["staging_misses"] = self.staging.misses
        s["request_pool_hits"] = self.futures.hits
        s["request_pool_misses"] = self.futures.misses
        s.update(self.residency.gauges())
        s["topology"] = self.topology.snapshot()
        s["progress_lanes"] = self.engine.lanes_snapshot()
        s["progress_errors"] = self.engine.error_count()
        san = sanitizer.current()
        if san is not None:
            s["sanitizer"] = san.stats_snapshot()
        return s

    def shutdown(self) -> None:
        with self._lock:
            self._shutdown = True
            self._work.notify_all()
        for t in self._threads:
            t.join(timeout=5)
        self.engine.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()

    # ------------------------------------------------------------------
    # host access protocol
    # ------------------------------------------------------------------
    def _request_host(self, obj: HeteroObject, write: bool) -> HFuture:
        if self._tracer is not None:
            # a mid-window host access must observe parked writes: the
            # tracer flushes parked tasks through the interpreted path
            self._tracer.flush()
        self.residency.pin(obj)      # until _release_host
        fut = self.futures.acquire()

        def deliver():
            arr = self._stage_to_host(obj)
            with obj.lock:
                if write and not arr.flags.writeable:
                    # downloads can be read-only zero-copy views of device
                    # buffers; a write pin must hand out a writable copy
                    arr = np.array(arr)
                    obj.copies[HOST] = arr
                    obj._pooled_host = False
                obj.host_pins += 1
                if write:
                    # invalidate device copies: host becomes the only valid
                    # one — a new generation (stale lineage records must
                    # not be able to resurrect the pre-write bytes)
                    obj.generation += 1
                    for sp in [s for s in obj.copies if s != HOST]:
                        self._drop_copy(obj, sp)
            fut.set_result(arr)

        with self._lock:
            lw = obj.last_writer
        if lw is not None and not lw.done():
            lw.future.add_done_callback(lambda _: deliver())
        else:
            deliver()
        return fut

    def _request_device_view(self, obj: HeteroObject) -> HFuture:
        """Async view of an object's freshest copy WITHOUT host staging:
        resolves (after conflicting writers retire) to ``(space, array)``
        where space is a device id (jax array — snapshot-safe because jax
        arrays are immutable) or HOST (defensive np copy). The distributed
        DIRECT send path uses this so the payload never bounces via host.

        The view takes a *device pin* at request time (program order, like
        the paper's read-access request): while pinned, launches won't
        donate this object's buffers. Under that protection the deliver
        step snapshots a private on-device ``clone`` of the copy, then
        drops the pin — the clone is referenced by nothing else, so no
        later donation can delete the payload mid-flight."""
        if self._tracer is not None:
            self._tracer.flush()   # parked writes must be observable
        with obj.lock:
            obj.device_pins += 1
        self.residency.pin(obj)      # until _release_device_view
        fut = self.futures.acquire()

        def deliver():
            try:
                with obj.lock:
                    dev_sp = next((s for s in obj.copies if s != HOST), None)
                    if dev_sp is not None:
                        snap = self._device(dev_sp).clone(obj.copies[dev_sp])
                    elif HOST in obj.copies:
                        snap = np.array(obj.copies[HOST])
                    else:
                        snap = np.zeros(obj.shape, obj.dtype)
                if dev_sp is not None and hasattr(snap, "block_until_ready"):
                    snap.block_until_ready()   # clone must finish reading
                fut.set_result((dev_sp if dev_sp is not None else HOST,
                                snap))
            finally:
                self._release_device_view(obj)

        with self._lock:
            lw = obj.last_writer
        if lw is not None and not lw.done():
            lw.future.add_done_callback(lambda _: deliver())
        else:
            deliver()
        return fut

    def _release_host(self, obj: HeteroObject) -> None:
        self.residency.unpin(obj)
        with obj.lock:
            obj.host_pins = max(0, obj.host_pins - 1)
            # a pooled buffer whose HOST copy was dropped while pinned
            # (e.g. free() between request and release) is handed back to
            # the pool once the last pin goes away
            orphan = getattr(obj, "_orphan_host", None)
            if obj.host_pins == 0 and orphan is not None:
                self.staging.release(orphan)
                obj._orphan_host = None

    def _release_device_view(self, obj: HeteroObject) -> None:
        self.residency.unpin(obj)
        with obj.lock:
            obj.device_pins = max(0, obj.device_pins - 1)

    def _free_object(self, obj: HeteroObject) -> None:
        with obj.lock:
            for sp in list(obj.copies):
                self._drop_copy(obj, sp)

    # ------------------------------------------------------------------
    # data movement / coherence
    # ------------------------------------------------------------------
    def _device(self, device_id: int) -> Device:
        return self.devices[device_id]

    def _drop_copy(self, obj: HeteroObject, space: int) -> None:
        if space in obj.copies:
            arr = obj.copies.pop(space)
            if space != HOST:
                self.residency.drop(space, obj)
            elif getattr(obj, "_pooled_host", False):
                # recycle the staging buffer (paper §4.1.1: the page-locked
                # pool only pays off if buffers actually return to it); if
                # a pin still hands the buffer out, park it as an orphan —
                # _release_host returns it to the pool with the last pin
                if obj.host_pins == 0:
                    self.staging.release(arr)
                else:
                    obj._orphan_host = arr
                obj._pooled_host = False

    def _stage_to_host(self, obj: HeteroObject) -> np.ndarray:
        with obj.lock:
            if HOST in obj.copies:
                return obj.copies[HOST]
            src = next(iter(obj.copies), None)
        if src is None and self.lineage is not None:
            # no valid replica anywhere: before conjuring zeros, try to
            # replay the recorded producer chain (bounded, cycle-safe)
            if self._lineage_recover(obj):
                with obj.lock:
                    if HOST in obj.copies:
                        return obj.copies[HOST]
                    src = next(iter(obj.copies), None)
        if src is None:
            arr = self.staging.acquire(obj.shape, obj.dtype)
            arr[...] = 0
            pooled = True
        else:
            dev_arr = obj.copies[src]
            t0 = time.perf_counter()
            arr, pooled = self._download_device(self._device(src), dev_arr)
            self.topology.observe(src, HOST, obj.nbytes,
                                  time.perf_counter() - t0)
            self._stats["transfers_d2h"] += 1
            self._stats["bytes_d2h"] += obj.nbytes
        with obj.lock:
            obj.copies[HOST] = arr
            obj._pooled_host = pooled
        return arr

    def _download_device(self, device: Device,
                         dev_arr: Any) -> Tuple[np.ndarray, bool]:
        """Device→host staging mirroring ``_upload_host``: the host copy
        lands in a pooled StagingPool buffer (chunked above
        ``staging_chunk_bytes``) and NEVER aliases the device buffer —
        ``download`` on CPU backends returns zero-copy views of XLA
        buffers, which donation may recycle under the view. Returns
        (host array, is_pooled)."""
        if not self.staging.enabled:
            # no pool: still a private copy, never an aliasing view
            return np.array(device.download(dev_arr)), False
        shape = tuple(dev_arr.shape)
        dtype = np.dtype(dev_arr.dtype)
        buf = self.staging.acquire(shape, dtype)
        chunk = self.cfg.staging_chunk_bytes
        nbytes = buf.nbytes
        if (chunk <= 0 or nbytes <= chunk or buf.ndim == 0
                or shape[0] < 2):
            device.download_into(dev_arr, buf)
            return buf, True
        # chunked: slice on device, download piecewise into the pool
        # buffer so no full-size intermediate host array materializes
        row_bytes = max(1, nbytes // shape[0])
        rows_per = max(1, chunk // row_bytes)
        for i in range(0, shape[0], rows_per):
            device.download_into(dev_arr[i:i + rows_per],
                                 buf[i:i + rows_per])
        return buf, True

    def _upload_host(self, device: Device, host_arr: np.ndarray) -> Any:
        """Host→device copy; large arrays stream through pooled staging
        buffers in ``staging_chunk_bytes`` pieces (page-locked pool
        analogue) so one giant transfer can't monopolize host memory.
        Every upload is timed into the interconnect model (the chunked
        path blocks, so its sample is honest; the simple path measures
        dispatch+copy, which the EWMA smooths)."""
        t0 = time.perf_counter()
        arr = self._upload_host_inner(device, host_arr)
        self.topology.observe(HOST, device.info.device_id,
                              host_arr.nbytes, time.perf_counter() - t0)
        return arr

    def _upload_host_inner(self, device: Device, host_arr: np.ndarray) -> Any:
        chunk = self.cfg.staging_chunk_bytes
        if (not self.staging.enabled or chunk <= 0
                or host_arr.nbytes <= chunk or host_arr.ndim == 0
                or host_arr.shape[0] < 2):
            return device.upload(host_arr)
        import jax.numpy as jnp
        row_bytes = max(1, host_arr.nbytes // host_arr.shape[0])
        rows_per = max(1, chunk // row_bytes)
        pieces, bufs = [], []
        for i in range(0, host_arr.shape[0], rows_per):
            part = host_arr[i:i + rows_per]
            buf = self.staging.acquire(part.shape, part.dtype)
            np.copyto(buf, part)
            pieces.append(device.upload(buf))
            bufs.append(buf)
        # one barrier for the whole batch (chunk DMAs overlap each other);
        # buffers may only return to the pool once their DMA completed
        for piece in pieces:
            if hasattr(piece, "block_until_ready"):
                piece.block_until_ready()
        for buf in bufs:
            self.staging.release(buf)
        return jnp.concatenate(pieces, axis=0)

    # -- lineage-based recovery ----------------------------------------
    def _lineage_recover(self, obj: HeteroObject,
                         depth: Optional[int] = None) -> bool:
        """Rebuild a lost object by replaying its recorded producer task.

        Bounded by ``cfg.lineage_depth`` and cycle-safe: a record is only
        replayable when every input it *read* still sits at the exact
        generation it read (in-place ``rw`` chains therefore refuse to
        replay past their own overwrite), and a per-object guard set
        breaks any residual recursion. Serialised under one recursive
        lock so concurrent coherence walks don't double-recompute."""
        if self.lineage is None:
            return False
        if depth is None:
            depth = self.cfg.lineage_depth
        if depth <= 0:
            return False
        with self._lineage_lock:
            return self._lineage_recover_locked(obj, depth)

    def _lineage_recover_locked(self, obj: HeteroObject, depth: int) -> bool:
        with obj.lock:
            if obj.copies:
                return True          # raced: already restored
        if id(obj) in self._recovering:
            return False             # cycle guard
        rec = self.lineage.producer(obj)
        if rec is None:
            return False
        self._recovering.add(id(obj))
        try:
            for iobj, pre_gen, reads, _writes in rec.args:
                if not reads:
                    continue         # pure write: placeholder below
                if iobj.generation != pre_gen:
                    return False     # input moved on: chain broken
                with iobj.lock:
                    have = bool(iobj.copies)
                if not have and (depth <= 1 or not
                                 self._lineage_recover_locked(iobj,
                                                              depth - 1)):
                    return False
            dev = rec.device_id if 0 <= rec.device_id < len(self.devices) \
                else self.pick_landing_device()
            device = self._device(dev)
            dev_args = []
            for iobj, _pre, reads, _writes in rec.args:
                if reads:
                    dev_args.append(self._ensure_on_device(iobj, dev,
                                                           will_write=False))
                else:
                    # write-only slot: content never read by the kernel,
                    # any correctly-shaped array will do (and avoids
                    # recursing into the object we are recovering)
                    dev_args.append(device.upload(
                        np.zeros(iobj.shape, iobj.dtype)))
            handle = device.launch(rec.kernel, tuple(dev_args), donate=())
            device.synchronize(handle)
            outs = handle if isinstance(handle, (tuple, list)) else (handle,)
            wi = 0
            for oobj, _pre, _reads, writes in rec.args:
                if not writes:
                    continue
                if wi < len(outs):
                    new_arr = outs[wi]
                    self.residency.ensure_capacity(dev, oobj.nbytes,
                                                   self._evict)
                    with oobj.lock:
                        restore = (oobj is obj) or (
                            not oobj.copies and self.lineage.producer(oobj)
                            is rec)
                        if restore and dev not in oobj.copies:
                            # restoring the SAME logical version: do NOT
                            # bump the generation
                            oobj.copies[dev] = new_arr
                            self.residency.record(dev, oobj)
                wi += 1
            self._stats["lineage_recomputes"] += 1
            used = self.cfg.lineage_depth - depth + 1
            if used > self._stats["recompute_depth_peak"]:
                self._stats["recompute_depth_peak"] = used
            with obj.lock:
                return bool(obj.copies)
        finally:
            self._recovering.discard(id(obj))

    def _evict(self, obj: HeteroObject, device_id: int) -> bool:
        """LRU eviction callback: spill to host unless pinned (paper
        §3.1.1). Pin state is the ledger's — no obj.busy() lock walk;
        ``ensure_capacity`` already filters pinned candidates, this check
        only covers direct callers and pins taken mid-eviction."""
        if self.residency.pinned(obj):
            return False
        with obj.lock:
            if device_id not in obj.copies:
                return False
            if len(obj.copies) == 1:      # device holds the only valid copy
                pass                       # must stage out first
        self._stage_to_host(obj)
        with obj.lock:
            self._drop_copy(obj, device_id)
        return True

    def _ensure_on_device(self, obj: HeteroObject, device_id: int,
                          will_write: bool) -> Any:
        """Coherence walk: make a VALID copy resident on device_id.

        Source preference (paper §3.2.3): (1) already resident — no copy;
        (2) the residency ledger knows another device holding a replica and
        d2d is on — one direct device→device transfer; (3) generic path —
        stage through host."""
        with obj.lock:
            if device_id in obj.copies:
                arr = obj.copies[device_id]
                self.residency.touch(device_id, obj)
                if will_write:
                    for sp in [s for s in obj.copies if s != device_id]:
                        self._drop_copy(obj, sp)
                return arr
            src_dev = None
            src_arr = None
            if self.cfg.d2d:
                for cand in sorted(self.residency.devices_of(obj)):
                    if cand != device_id and cand in obj.copies:
                        src_dev, src_arr = cand, obj.copies[cand]
                        break
        if src_dev is not None:
            # direct D2D: never materializes a host copy (jax arrays are
            # immutable, so the snapshot taken above stays valid even if the
            # source copy is concurrently evicted)
            if (self.cfg.lazy_probe
                    and not self.topology.measured(src_dev, device_id)):
                # first use of a pair the startup host+ring probe skipped
                # (ROADMAP follow-up c): seed from the measured two-hop
                # path over host, then time one small real transfer so
                # the estimate is link-local before the payload's own
                # sample refines it
                self.topology.seed_from_path(src_dev, device_id)
                try:
                    probe_link(self._device(src_dev),
                               self._device(device_id), self.topology,
                               self.cfg.topology_probe_bytes)
                except Exception:   # probe failure must never block data
                    pass
            self.residency.ensure_capacity(device_id, obj.nbytes,
                                           self._evict)
            dev_arr = device_api.transfer(self._device(src_dev),
                                          self._device(device_id), src_arr,
                                          observer=self.topology.observe)
            self._stats["transfers_d2d"] += 1
            self._stats["bytes_d2d"] += obj.nbytes
        else:
            host_arr = self._stage_to_host(obj)
            # the chunked path transiently holds pieces + their concatenated
            # result on device, so reserve double before choosing it
            chunked = (self.staging.enabled
                       and 0 < self.cfg.staging_chunk_bytes < obj.nbytes)
            self.residency.ensure_capacity(
                device_id, obj.nbytes * (2 if chunked else 1), self._evict)
            dev_arr = self._upload_host(self._device(device_id), host_arr)
            self._stats["transfers_h2d"] += 1
            self._stats["bytes_h2d"] += obj.nbytes
        with obj.lock:
            if device_id in obj.copies:        # raced with another walker
                dev_arr = obj.copies[device_id]
            else:
                obj.copies[device_id] = dev_arr
                self.residency.record(device_id, obj)
            if will_write:
                for sp in [s for s in obj.copies if s != device_id]:
                    self._drop_copy(obj, sp)
        return dev_arr

    # ------------------------------------------------------------------
    # workers
    # ------------------------------------------------------------------
    def _start_workers(self):
        n = len(self.devices) if self.cfg.dedicated_threads else 1
        for i in range(n):
            hint = self.devices[i].info.device_id \
                if self.cfg.dedicated_threads else None
            th = threading.Thread(target=self._worker, args=(hint,),
                                  daemon=True, name=f"repro-worker-{i}")
            th.start()
            self._threads.append(th)
        if self.cfg.transfer_thread:
            # materialize the transfer lanes up front so a burst of first
            # transfers never races lane creation with heavy traffic
            for d in self.devices:
                self.engine.lane("transfer", d.info.device_id)

    def _async_transfer(self, device_id: int, fn: Callable,
                        priority: int = 0) -> HFuture:
        """Run ``fn`` on ``device_id``'s transfer lane (or inline when the
        transfer lanes are disabled). Lower ``priority`` runs first —
        deep prefetch staging (priority 2+) never delays the next task's
        arguments (priority 1). Returns a pooled future; the completion
        event fires through the future's done-callbacks."""
        fut = self.futures.acquire()
        if self.cfg.transfer_thread:
            self.engine.submit("transfer", device_id, fn, fut,
                               priority=priority)
        else:
            try:
                fut.set_result(fn())
            except BaseException as e:   # pragma: no cover
                fut.set_error(e)
        return fut

    # -- argument prefetch pipeline ------------------------------------
    def _try_prefetch(self, device_hint: Optional[int], depth: int = 1):
        """Claim the next task early (Scheduler.assign) and enqueue its
        argument transfers so they overlap the current task's compute.
        ``depth`` is the task's position in the pipeline (1 = runs next)
        and doubles as the transfer priority. Returns (task, dev,
        transfer-future-or-None); the future resolves to
        ({obj_id: device array}, needed-ids). All of a task's arguments
        stage as ONE transfer-queue item (per-argument handoffs cost more
        than they overlap), and fully-resident tasks skip the queue
        entirely."""
        with self._lock:
            if self._shutdown:
                return None
            item = self.scheduler.assign(device_hint)
            if item is None:
                return None
            task, dev = item
            task.state = TaskState.RUNNING
            task.chosen_device = dev
            self.scheduler.load[dev] += 1
        objs = []
        seen = set()
        for ref in task.args:
            if id(ref.obj) not in seen:
                seen.add(id(ref.obj))
                objs.append(ref.obj)
        need = frozenset(id(o) for o in objs if not o.has_copy(dev))
        if not need:
            return task, dev, None          # nothing to move
        fut = self._async_transfer(dev, lambda: (
            {id(o): self._ensure_on_device(o, dev, False) for o in objs},
            need), priority=depth)
        return task, dev, fut

    def _worker(self, device_hint: Optional[int]):
        """Per-device compute lane. Launches are asynchronous; their
        retirement is a progress-engine completion event on the device's
        ``("complete", dev)`` lane — the worker never polls in-flight
        handles (the old block_one loop). ``gate`` counts this worker's
        un-retired launches; at ``cfg.inflight`` the worker parks on the
        runtime condition until a completion event frees a slot."""
        staged: "collections.deque" = collections.deque()  # prefetched tasks
        depth = max(1, self.cfg.prefetch_depth)
        gate = {"n": 0}
        async_mode = not self.cfg.sync_dispatch and self.cfg.inflight > 1

        def retire(task, handle):
            # runs on the completion lane: free the window slot first so
            # the notify inside _finish wakes a worker that can launch
            with self._lock:
                gate["n"] -= 1
            self._finish(task, result=handle)

        while True:
            pmap = None
            item = None
            with self._lock:
                if self._shutdown:
                    return
                if async_mode and gate["n"] >= self.cfg.inflight:
                    self._work.wait(timeout=self.cfg.poll_interval_s * 20)
                    continue
            if staged:
                task, dev, pmap = staged.popleft()
                item = (task, dev)
            else:
                with self._lock:
                    if self._shutdown:
                        return
                    item = self.scheduler.pop(device_hint)
                    if item is not None:
                        task, dev = item
                        task.state = TaskState.RUNNING
                        task.chosen_device = dev
                        self.scheduler.load[dev] += 1
            if item is None:
                # nothing runnable: park until a push or a completion
                # event (retire → _finish) notifies the condition
                with self._lock:
                    if self._shutdown:
                        return
                    self._work.wait(timeout=self.cfg.poll_interval_s * 20)
                continue
            task, dev = item
            try:
                handle = self._launch(task, dev, pmap)
            except BaseException as e:
                # bounded relaunch (cfg.task_retries) before the error
                # surfaces: injected kernel faults / transient device
                # errors retry with pins intact — _finish unpins exactly
                # once at the final retirement
                attempts = getattr(task, "attempts", 0)
                if attempts < self.cfg.task_retries and not self._shutdown:
                    task.attempts = attempts + 1
                    with self._lock:
                        self._stats["task_retries"] += 1
                        self.scheduler.load[dev] -= 1
                        task.state = TaskState.READY
                        task.chosen_device = None
                        self.scheduler.push(task)
                        self._work.notify_all()
                    continue
                self._finish(task, error=e)
                continue
            # pipeline: claim the next prefetch_depth tasks + start their
            # transfers while the launch above computes; deeper positions
            # stage at lower transfer-queue priority
            if self.cfg.prefetch:
                while len(staged) < depth:
                    nxt = self._try_prefetch(device_hint,
                                             depth=1 + len(staged))
                    if nxt is None:
                        break
                    staged.append(nxt)
            if not async_mode:
                self._device(dev).synchronize(handle)
                self._finish(task, result=handle)
            else:
                with self._lock:
                    gate["n"] += 1
                self.engine.complete(
                    "complete", dev,
                    waiter=self._device(dev).completion_waiter(handle),
                    callback=lambda _r, _e, task=task, handle=handle:
                    retire(task, handle))

    def _launch(self, task: HeteroTask, device_id: int,
                prefetched: Optional[HFuture] = None):
        """Await prefetched argument copies (or stage synchronously), then
        launch asynchronously via the Device API."""
        staged: Dict[int, Any] = {}
        needed: frozenset = frozenset()
        overlapped = False
        # argument versions at launch time — the lineage record must pin
        # inputs to the generations this launch actually read
        pre_gens = [ref.obj.generation for ref in task.args] \
            if self.lineage is not None else None
        if prefetched is not None:
            # transfers were issued when the task was assigned; when they
            # completed during the previous task's compute the copy was
            # truly overlapped (a hit), otherwise the pipeline still had
            # to wait here (a stall) — the distinction the paper's
            # transfer-queue depth trades on (§4.1.3)
            overlapped = prefetched.done()
            staged, needed = prefetched.get()
            self.futures.release(prefetched)
        dev_args = []
        donate = []
        for i, ref in enumerate(task.args):
            arr = staged.get(id(ref.obj))
            if arr is not None:
                if id(ref.obj) in needed:
                    key = "prefetch_hits" if overlapped else \
                        "prefetch_stalls"
                    self._stats[key] += 1
            else:
                if self.cfg.prefetch and prefetched is None \
                        and not ref.obj.has_copy(device_id):
                    # popped directly (pipeline empty): the copy could not
                    # be overlapped with compute
                    self._stats["prefetch_misses"] += 1
                arr = self._ensure_on_device(ref.obj, device_id,
                                             will_write=False)
            dev_args.append(arr)
            if (ref.access.writes and self.cfg.cache_jit
                    and ref.obj.device_pins == 0):
                donate.append(i)
        if self._inject_task_faults > 0:
            # FaultInjector.fail_task planted a deterministic kernel fault
            with self._lock:
                if self._inject_task_faults > 0:
                    self._inject_task_faults -= 1
                    raise InjectedTaskFault(
                        f"injected kernel fault (task {task.name!r})")
        handle = self._device(device_id).launch(
            task.kernel, tuple(dev_args), donate=tuple(donate))
        # bind outputs back onto the written hetero_objects
        outs = handle if isinstance(handle, (tuple, list)) else (handle,)
        wi = 0
        for ref in task.args:
            if ref.access.writes:
                if wi < len(outs):
                    new_arr = outs[wi]
                    with ref.obj.lock:
                        for sp in list(ref.obj.copies):
                            self._drop_copy(ref.obj, sp)
                        ref.obj.copies[device_id] = new_arr
                        # every write-rebind is a new generation: lineage
                        # records are valid for exactly one version
                        ref.obj.generation += 1
                        self.residency.record(device_id, ref.obj)
                wi += 1
        if self.lineage is not None and wi:
            seen_w: set = set()
            out_gens = {}
            for ref in task.args:
                if ref.access.writes and id(ref.obj) not in seen_w:
                    seen_w.add(id(ref.obj))
                    out_gens[id(ref.obj)] = ref.obj.generation
            self.lineage.record(
                task.kernel,
                [(ref.obj, g, ref.access.reads, ref.access.writes)
                 for ref, g in zip(task.args, pre_gens, strict=True)],
                out_gens, device_id)
        return handle

    def _finish(self, task: HeteroTask, result=None, error=None):
        for obj in {id(r.obj): r.obj for r in task.args}.values():
            self.residency.unpin(obj)
        with self._lock:
            if error is not None:
                task.state = TaskState.FAILED
                self._stats["tasks_failed"] += 1
                if self.cfg.strict_errors and len(self._failed_tasks) < 64:
                    self._failed_tasks.append(error)
            else:
                task.state = TaskState.DONE
            if task.chosen_device is not None:
                self.scheduler.load[task.chosen_device] -= 1
            ready = dep.retire(task)
            for r in ready:
                r.state = TaskState.READY
                self.scheduler.push(r)
            self._tasks_pending -= 1
            self._work.notify_all()
        if error is not None:
            task.future.set_error(error)
        else:
            task.future.set_result(result)
