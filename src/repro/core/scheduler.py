"""Modular scheduler (paper §3.1.4): an abstract class with push/pop as the
only operations the runtime requires; policies are pluggable.

Indexed ready queues: every built-in policy now routes through
``IndexedScheduler`` — tasks are placed into a per-device deque at ``push``
time (the policy decides the placement), with a shared overflow deque for
tasks that have no placement preference. ``pop(device_hint)`` is O(1) in
the common case: pop the head of the hint's own deque, else the head of the
overflow deque. The old implementations re-scanned the whole global queue
under one lock on every pop — O(queue length) per worker wake-up, which
serialized the dedicated per-device threads (paper §4.1.6) behind the scan.

Data-gravity placement (paper §3.1.3: "the scheduler optimizes data
locality to reduce memory transfers"): the ready queues are re-keyed by
*best placement* — a pluggable cost model (``core.residency.PLACEMENTS``)
scores candidate devices by bytes-to-move minus bytes-resident (plus a
pressure penalty) against the runtime's residency ledger, and ``push``
indexes the task under the winner. The caller's device hint only selects
*which queue to pop*, it no longer decides placement.

Two extra hooks support the runtime's argument-prefetch pipeline
(paper §4.1.3 — overlap transfers with compute):
  peek(device_hint)   — the next task this device would receive (no removal)
  assign(device_hint) — pop + commit in one step; the prefetcher uses this
                        to claim the next task early and enqueue its
                        argument transfers while the current task computes.
"""
from __future__ import annotations

import abc
import collections
import threading
from typing import Deque, Dict, List, Optional, Tuple

from repro.core import sanitizer
from repro.core.hetero_task import HeteroTask
from repro.core.residency import (DataGravityPolicy, PlacementPolicy,
                                  ResidencyLedger)


class Scheduler(abc.ABC):
    """Device table: {device_id: device_type}. ``load`` is maintained by the
    runtime (tasks queued+running per device) and may be used by policies.
    ``placement`` is an optional cost model; the runtime binds its residency
    ledger to it via ``bind_residency``."""

    def __init__(self, device_types: Dict[int, str],
                 placement: Optional[PlacementPolicy] = None):
        self.device_types = dict(device_types)
        self.load: Dict[int, int] = {d: 0 for d in device_types}
        self.placement = placement
        self._lock = sanitizer.make_lock("Scheduler._lock")

    def bind_residency(self, ledger: ResidencyLedger) -> None:
        if self.placement is not None:
            self.placement.bind(ledger)

    def bind_topology(self, model) -> None:
        """Hand the runtime's InterconnectModel to the placement cost
        model so transfer costs are priced from measured bandwidth."""
        if self.placement is not None:
            self.placement.bind_topology(model)

    @abc.abstractmethod
    def push(self, task: HeteroTask) -> None: ...

    @abc.abstractmethod
    def pop(self, device_hint: Optional[int] = None
            ) -> Optional[Tuple[HeteroTask, int]]: ...

    def peek(self, device_hint: Optional[int] = None
             ) -> Optional[HeteroTask]:
        """Next task ``pop(device_hint)`` would return, without removing it.
        Policies may return None when peeking is unsupported."""
        return None

    def assign(self, device_hint: Optional[int] = None
               ) -> Optional[Tuple[HeteroTask, int]]:
        """Claim the next (task, device) pair — identical to ``pop`` but
        named for the prefetch pipeline, which commits the assignment before
        the worker is ready to launch."""
        return self.pop(device_hint)

    def __len__(self) -> int:  # pragma: no cover - informational
        return 0

    # helpers ---------------------------------------------------------------
    def eligible(self, task: HeteroTask) -> List[int]:
        if task.device_type is None:
            return list(self.device_types)
        return [d for d, t in self.device_types.items()
                if t == task.device_type]


class IndexedScheduler(Scheduler):
    """Per-device indexed ready queues + shared overflow deque.

    Subclasses implement ``_place(task) -> Optional[device_id]`` (None →
    overflow) and ``_choose(task) -> device_id`` (device selection for
    overflow tasks popped without a hint). ``steals`` controls whether an
    idle device may take the oldest task indexed to another device — on for
    throughput policies, off for locality (stealing would defeat it).
    """

    steals = True
    # re-score the head of a ready queue at pop time when residency moved
    # since it was placed (ROADMAP follow-up a: placement is decided at
    # push time and can be stale once replicas shifted). Only locality
    # policies opt in — for load-only policies staleness is meaningless.
    rescore_on_pop = False
    # bound work per pop: at most this many stale heads are re-homed
    # before falling through to the normal pop path
    _RESCORE_LIMIT = 4

    def __init__(self, device_types: Dict[int, str],
                 placement: Optional[PlacementPolicy] = None):
        super().__init__(device_types, placement)
        self._ready: Dict[int, Deque[HeteroTask]] = {
            d: collections.deque() for d in device_types}
        self._overflow: Deque[HeteroTask] = collections.deque()
        # tasks indexed per device but not yet popped; policies add it to
        # ``load`` so placement sees queued work, not only running work
        self.queued: Dict[int, int] = {d: 0 for d in device_types}

    # policy hooks ----------------------------------------------------------
    def _place(self, task: HeteroTask) -> Optional[int]:
        return None

    def _choose(self, task: HeteroTask) -> int:
        elig = self.eligible(task) or list(self.device_types)
        return min(elig, key=lambda d: self.load[d] + self.queued[d])

    def _pressure(self, dev: int) -> int:
        return self.load[dev] + self.queued[dev]

    def _ledger_version(self) -> Optional[int]:
        led = self.placement.ledger if self.placement is not None else None
        return led.version if led is not None else None

    # queue mechanics -------------------------------------------------------
    def push(self, task: HeteroTask) -> None:
        with self._lock:
            dev = self._place(task)
            if dev is None:
                self._overflow.append(task)
            else:
                task._placement_version = self._ledger_version()
                self._ready[dev].append(task)
                self.queued[dev] += 1

    def _rescore_head(self, device_hint: int) -> None:
        """Aged-entry repair (ROADMAP follow-up a): if residency changed
        since the head of this device's queue was placed, score it again
        and re-home it to the new best device's queue. Bounded so a pop
        stays O(1)-ish; the re-homed task keeps its FIFO position at the
        tail of the winner's queue (its placement is the freshest)."""
        version = self._ledger_version()
        if version is None:
            return
        q = self._ready[device_hint]
        for _ in range(self._RESCORE_LIMIT):
            if not q:
                return
            head = q[0]
            if getattr(head, "_placement_version", None) == version:
                return
            head._placement_version = version
            best = self._place(head)
            if best is None or best == device_hint:
                return
            q.popleft()
            self.queued[device_hint] -= 1
            self._ready[best].append(head)
            self.queued[best] += 1

    def _take_overflow(self, device_hint: int) -> Optional[HeteroTask]:
        # O(1) when the head is eligible (the common, untyped-task case);
        # the scan only happens while type-restricted tasks sit at the head
        for i, task in enumerate(self._overflow):
            if device_hint in self.eligible(task):
                del self._overflow[i]
                return task
        return None

    def _steal(self, device_hint: int) -> Optional[HeteroTask]:
        victim = max((d for d in self._ready if d != device_hint),
                     key=lambda d: len(self._ready[d]), default=None)
        if victim is None or not self._ready[victim]:
            return None
        # steal the oldest so the victim keeps its freshest placements
        task = self._ready[victim][0]
        if device_hint not in self.eligible(task):
            return None
        self._ready[victim].popleft()
        self.queued[victim] -= 1
        return task

    def pop(self, device_hint: Optional[int] = None
            ) -> Optional[Tuple[HeteroTask, int]]:
        with self._lock:
            if device_hint is not None:
                if self.rescore_on_pop:
                    self._rescore_head(device_hint)
                q = self._ready[device_hint]
                if q:
                    self.queued[device_hint] -= 1
                    return q.popleft(), device_hint
                task = self._take_overflow(device_hint)
                if task is not None:
                    return task, device_hint
                if self.steals:
                    task = self._steal(device_hint)
                    if task is not None:
                        return task, device_hint
                return None
            # hintless worker: own indexed queues first, then overflow
            for d, q in self._ready.items():
                if q:
                    self.queued[d] -= 1
                    return q.popleft(), d
            for i, task in enumerate(self._overflow):
                if self.eligible(task):
                    del self._overflow[i]
                    return task, self._choose(task)
            return None

    def peek(self, device_hint: Optional[int] = None
             ) -> Optional[HeteroTask]:
        with self._lock:
            if device_hint is not None:
                q = self._ready[device_hint]
                if q:
                    return q[0]
                for task in self._overflow:
                    if device_hint in self.eligible(task):
                        return task
                return None
            for q in self._ready.values():
                if q:
                    return q[0]
            return self._overflow[0] if self._overflow else None

    def __len__(self) -> int:
        return sum(len(q) for q in self._ready.values()) + \
            len(self._overflow)


class FifoScheduler(IndexedScheduler):
    """Single shared FIFO (all tasks overflow); device = hint if eligible,
    else least-loaded. Pop from the head is O(1)."""
    # _place -> None inherited: every task goes to the overflow deque


class LeastLoadedScheduler(IndexedScheduler):
    """Place each task, at push time, on the least-pressured eligible device
    (running + queued) — the multi-GPU load-balancing policy behind the
    paper's Fig. 9. Idle devices steal, so imbalance self-corrects."""

    def _place(self, task):
        elig = self.eligible(task)
        if not elig:
            return None
        return min(elig, key=self._pressure)


class LocalityAwareScheduler(IndexedScheduler):
    """PR 1 locality heuristic, kept as the baseline control arm: prefer
    the device already holding the most argument bytes, minus a flat 1 MiB
    load penalty per queued task. The penalty routinely overwhelms the
    residency term for megabyte-scale arguments, so placement degenerates
    to load balancing and resident objects bounce between devices — the
    failure mode ``GravityScheduler`` fixes. No stealing."""

    steals = False

    def __init__(self, device_types, load_penalty_bytes: int = 1 << 20):
        super().__init__(device_types)
        self.load_penalty = load_penalty_bytes

    def _score(self, task: HeteroTask, dev: int) -> float:
        return (task.arg_bytes_on(dev)
                - self.load_penalty * self._pressure(dev))

    def _place(self, task):
        elig = self.eligible(task)
        if not elig:
            return None
        return max(elig, key=lambda d: self._score(task, d))

    def _choose(self, task):
        elig = self.eligible(task) or list(self.device_types)
        return max(elig, key=lambda d: self._score(task, d))


class GravityScheduler(IndexedScheduler):
    """Data-gravity placement (the default): the ready queues are re-keyed
    by the placement cost model's best device — bytes-to-move minus
    bytes-resident plus pressure, answered by the runtime's residency
    ledger. No stealing: a stolen task pays exactly the transfers the
    placement avoided. Aged entries are re-scored at pop time when the
    ledger moved underneath them (push-time placement can be stale)."""

    steals = False
    rescore_on_pop = True

    def __init__(self, device_types,
                 placement: Optional[PlacementPolicy] = None):
        super().__init__(device_types, placement or DataGravityPolicy())

    def _place(self, task):
        elig = self.eligible(task)
        if not elig:
            return None
        return self.placement.choose(task, elig, self._pressure)

    def _choose(self, task):
        elig = self.eligible(task) or list(self.device_types)
        return self.placement.choose(task, elig, self._pressure)


class RoundRobinScheduler(IndexedScheduler):
    def __init__(self, device_types):
        super().__init__(device_types)
        self._next = 0

    def _place(self, task):
        elig = self.eligible(task)
        if not elig:
            return None
        dev = elig[self._next % len(elig)]
        self._next += 1
        return dev


SCHEDULERS = {
    "fifo": FifoScheduler,
    "gravity": GravityScheduler,
    "least_loaded": LeastLoadedScheduler,
    "locality": LocalityAwareScheduler,
    "round_robin": RoundRobinScheduler,
}
