"""Modular scheduler (paper §3.1.4): an abstract class with exactly two
operations — push(task) adds a runnable task; pop(device_hint) returns the
next (task, device_id) pair. Policies are pluggable; the runtime never
assumes more than push/pop.
"""
from __future__ import annotations

import abc
import collections
import threading
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.hetero_task import HeteroTask


class Scheduler(abc.ABC):
    """Device table: {device_id: device_type}. ``load`` is maintained by the
    runtime (tasks queued+running per device) and may be used by policies."""

    def __init__(self, device_types: Dict[int, str]):
        self.device_types = dict(device_types)
        self.load: Dict[int, int] = {d: 0 for d in device_types}
        self._lock = threading.Lock()

    @abc.abstractmethod
    def push(self, task: HeteroTask) -> None: ...

    @abc.abstractmethod
    def pop(self, device_hint: Optional[int] = None
            ) -> Optional[Tuple[HeteroTask, int]]: ...

    def __len__(self) -> int:  # pragma: no cover - informational
        return 0

    # helpers ---------------------------------------------------------------
    def eligible(self, task: HeteroTask) -> List[int]:
        if task.device_type is None:
            return list(self.device_types)
        return [d for d, t in self.device_types.items()
                if t == task.device_type]


class FifoScheduler(Scheduler):
    """Single global FIFO; device = hint if eligible, else least-loaded."""

    def __init__(self, device_types):
        super().__init__(device_types)
        self._q: Deque[HeteroTask] = collections.deque()

    def push(self, task):
        with self._lock:
            self._q.append(task)

    def pop(self, device_hint=None):
        with self._lock:
            for i, task in enumerate(self._q):
                elig = self.eligible(task)
                if not elig:
                    continue
                if device_hint is not None and device_hint in elig:
                    dev = device_hint
                elif device_hint is not None:
                    continue   # let the right device's worker take it
                else:
                    dev = min(elig, key=lambda d: self.load[d])
                del self._q[i]
                return task, dev
        return None

    def __len__(self):
        return len(self._q)


class LeastLoadedScheduler(FifoScheduler):
    """FIFO order, but always place on the least-loaded eligible device —
    the multi-GPU load-balancing policy behind the paper's Fig. 9."""

    def pop(self, device_hint=None):
        with self._lock:
            if not self._q:
                return None
            if device_hint is not None:
                # only take work if we're (one of) the least loaded
                for i, task in enumerate(self._q):
                    elig = self.eligible(task)
                    if device_hint not in elig:
                        continue
                    best = min(self.load[d] for d in elig)
                    if self.load[device_hint] <= best:
                        del self._q[i]
                        return task, device_hint
                return None
            task = self._q.popleft()
            elig = self.eligible(task) or list(self.device_types)
            return task, min(elig, key=lambda d: self.load[d])


class LocalityAwareScheduler(Scheduler):
    """Prefer the device already holding the most argument bytes (paper:
    "scheduler optimizes data locality to reduce memory transfers"), with a
    load penalty so one hot device does not serialize the queue."""

    def __init__(self, device_types, load_penalty_bytes: int = 1 << 20):
        super().__init__(device_types)
        self._q: Deque[HeteroTask] = collections.deque()
        self.load_penalty = load_penalty_bytes

    def push(self, task):
        with self._lock:
            self._q.append(task)

    def _score(self, task: HeteroTask, dev: int) -> float:
        return (task.arg_bytes_on(dev)
                - self.load_penalty * self.load[dev])

    def pop(self, device_hint=None):
        with self._lock:
            for i, task in enumerate(self._q):
                elig = self.eligible(task)
                if not elig:
                    continue
                best = max(elig, key=lambda d: self._score(task, d))
                if device_hint is not None and best != device_hint:
                    continue
                del self._q[i]
                return task, best
        return None

    def __len__(self):
        return len(self._q)


class RoundRobinScheduler(Scheduler):
    def __init__(self, device_types):
        super().__init__(device_types)
        self._q: Deque[HeteroTask] = collections.deque()
        self._next = 0

    def push(self, task):
        with self._lock:
            self._q.append(task)

    def pop(self, device_hint=None):
        with self._lock:
            for i, task in enumerate(self._q):
                elig = self.eligible(task)
                if not elig:
                    continue
                if device_hint is not None:
                    if device_hint in elig:
                        del self._q[i]
                        return task, device_hint
                    continue
                dev = elig[self._next % len(elig)]
                self._next += 1
                del self._q[i]
                return task, dev
        return None

    def __len__(self):
        return len(self._q)


SCHEDULERS = {
    "fifo": FifoScheduler,
    "least_loaded": LeastLoadedScheduler,
    "locality": LocalityAwareScheduler,
    "round_robin": RoundRobinScheduler,
}
