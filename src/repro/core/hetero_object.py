"""hetero_object — location-transparent, coherence-tracked data (paper §3.1.1).

A HeteroObject owns every copy of one logical datum across memory spaces
(HOST = -1, or a device id). A MESI-like two-state protocol per copy
(VALID / absent) with a single rule — a write invalidates every other copy —
gives the paper's guarantee: "the most recent version of the data will be
available at the target device when needed".

Applications never hold raw device pointers; they access data through tasks
(optimal path) or via ``request_host`` which pins the host copy and blocks
writer tasks until ``release`` (paper: request_data/release).
"""
from __future__ import annotations

import itertools
import threading
from typing import Any, Dict, Optional, Set, Tuple

import numpy as np

from repro.core import sanitizer
from repro.core.futures import HFuture

HOST = -1
_ids = itertools.count()


class HeteroObject:
    """Created through Runtime.hetero_object(...) — not directly."""

    def __init__(self, runtime, value: Optional[np.ndarray] = None,
                 shape: Optional[Tuple[int, ...]] = None, dtype=None,
                 name: str = ""):
        self.id = next(_ids)
        self.name = name or f"hobj{self.id}"
        self._rt = runtime
        self.lock = sanitizer.make_rlock("HeteroObject.lock")
        # space -> array (HOST: np.ndarray, device: jax.Array)
        self.copies: Dict[int, Any] = {}
        # dependency bookkeeping (owned by DependencyTracker, kept here for
        # O(1) lookup): last writer task + readers since that write
        self.last_writer = None
        self.readers: Set[Any] = set()
        # host pin: while > 0, writer tasks must wait (request_host/release)
        self.host_pins = 0
        # device-view pin: while > 0, launches must not DONATE this object's
        # buffers (a snapshot — e.g. a distributed DIRECT send — still
        # references them; donation would delete the array under the NIC)
        self.device_pins = 0
        self._pin_waiters: list = []
        # monotonically-increasing write version: bumped on every
        # write-rebind (task output, distributed put, host write pin,
        # compiled-graph replay). Lineage records are valid for exactly
        # one generation — the cycle-safety anchor for in-place chains.
        self.generation = 0
        if value is not None:
            value = np.asarray(value)
            self.shape, self.dtype = value.shape, value.dtype
            self.copies[HOST] = value
        else:
            assert shape is not None and dtype is not None
            self.shape, self.dtype = tuple(shape), np.dtype(dtype)

    # ------------------------------------------------------------------
    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64) *
                   np.dtype(self.dtype).itemsize) if self.shape else \
            np.dtype(self.dtype).itemsize

    def valid_spaces(self) -> Set[int]:
        with self.lock:
            return set(self.copies)

    def resident_devices(self) -> Set[int]:
        """Devices holding a valid replica, answered by the runtime's
        residency ledger (the placement/landing source of truth; never
        includes HOST)."""
        return self._rt.residency.devices_of(self)

    def has_copy(self, space: int) -> bool:
        with self.lock:
            return space in self.copies

    def busy(self) -> bool:
        with self.lock:
            return (self.last_writer is not None or bool(self.readers)
                    or self.host_pins > 0)

    # ------------------------------------------------------------------
    # host access protocol (paper: request_data -> future; release)
    # ------------------------------------------------------------------
    def request_host(self, write: bool = False) -> HFuture:
        """Async request for host access. Resolves with the np.ndarray once
        (a) conflicting tasks finished and (b) data staged to host."""
        return self._rt._request_host(self, write)

    def release(self) -> None:
        self._rt._release_host(self)

    def get(self, timeout: Optional[float] = None) -> np.ndarray:
        """Convenience: request, wait, copy out, release."""
        fut = self.request_host(write=False)
        arr = np.array(fut.get(timeout))
        self.release()
        return arr

    def free(self) -> None:
        """Explicitly drop all copies (paper: early cleanup request)."""
        self._rt._free_object(self)

    def __repr__(self):
        return (f"HeteroObject({self.name}, {self.shape}, {self.dtype}, "
                f"spaces={sorted(self.copies)})")
