"""Interconnect topology model (paper §3.2.3 + §4.2).

The paper's message engine adapts its protocol to the link it is using:
small messages go eagerly, large ones are pipelined in chunks sized so
that network receive and device copy overlap. Both decisions need the
same thing — a per-link estimate of bandwidth and latency — and so does
the scheduler's transfer-cost model (ROADMAP follow-up b: the gravity
penalty must come from measured bandwidth, not a fixed byte constant).

``InterconnectModel`` is that single estimate. Endpoints are integers:
``HOST`` (-1) for host memory, device ids inside one runtime, or rank ids
when the distributed ``Cluster`` models its network. Every estimate is a
``LinkEstimate`` holding exponentially-weighted moving averages of
bandwidth and latency, seeded by a cheap startup micro-probe
(``Runtime`` with ``topology_probe=True``) and refined online by
``observe`` calls from every real transfer the runtime performs. The
model is deliberately clock-free: callers pass ``(nbytes, seconds)``
samples, so tests can drive it deterministically.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import sanitizer
from repro.core.hetero_object import HOST

# defaults before any sample arrives: a conservative PCIe-gen3-ish link.
DEFAULT_BANDWIDTH = 8e9          # bytes/s
DEFAULT_LATENCY = 20e-6          # seconds
# samples shorter than this are treated as latency measurements; the
# bandwidth term of such a transfer is noise (dispatch dominates).
_LATENCY_SAMPLE_BYTES = 4 << 10
_MIN_SECONDS = 1e-9

# adaptive credit-window controller (AIMD): the receiver's transfer-lane
# queue depth and landing-slab occupancy arrive with every credit; a
# backlog at or above WINDOW_BACKLOG_DEPTH chunks — or landing slabs
# holding more than WINDOW_SLAB_LIMIT bytes — halves the window (never
# below 1), an empty queue widens it by one chunk toward the BDP ceiling.
WINDOW_BACKLOG_DEPTH = 2
WINDOW_SLAB_LIMIT = 32 << 20


class LinkEstimate:
    """EWMA bandwidth/latency for one directed (src, dst) link.
    Latency and bandwidth first-samples are tracked separately: a link
    whose first traffic is small (latency-only) messages must still have
    its first REAL bandwidth sample replace the default outright, not be
    blended 3:1 with the guess."""

    __slots__ = ("bandwidth", "latency", "samples", "bw_samples",
                 "lat_samples", "chunk_choice", "window_choice")

    def __init__(self, bandwidth: float = DEFAULT_BANDWIDTH,
                 latency: float = DEFAULT_LATENCY):
        self.bandwidth = bandwidth
        self.latency = latency
        self.samples = 0          # total observations (either kind)
        self.bw_samples = 0
        self.lat_samples = 0
        # sticky chunk-size choice per (target_s, lo, hi) — see
        # InterconnectModel.chunk_bytes hysteresis
        self.chunk_choice: Dict[Tuple[float, int, int], int] = {}
        # adaptive credit-window controller state (window_chunks with
        # receiver feedback); None until the first adaptive decision
        self.window_choice: Optional[int] = None

    def cost_s(self, nbytes: int) -> float:
        """Predicted transfer time: latency + nbytes / bandwidth."""
        return self.latency + nbytes / max(self.bandwidth, 1.0)


class InterconnectModel:
    """Directed-link bandwidth/latency estimates with EWMA refinement.

    ``alpha`` weights new samples; the first sample replaces the default
    outright (a measured number always beats the guess).
    """

    def __init__(self, alpha: float = 0.25,
                 default_bandwidth: float = DEFAULT_BANDWIDTH,
                 default_latency: float = DEFAULT_LATENCY):
        self.alpha = alpha
        self._default_bw = default_bandwidth
        self._default_lat = default_latency
        self._links: Dict[Tuple[int, int], LinkEstimate] = {}
        self._lock = sanitizer.make_lock("InterconnectModel._lock")

    def _link(self, src: int, dst: int) -> LinkEstimate:
        key = (src, dst)
        est = self._links.get(key)
        if est is None:
            est = LinkEstimate(self._default_bw, self._default_lat)
            self._links[key] = est
        return est

    # -- refinement ----------------------------------------------------
    def observe(self, src: int, dst: int, nbytes: int,
                seconds: float) -> None:
        """Fold one real transfer into the (src → dst) estimate. Tiny
        transfers update latency (their duration is dispatch-dominated);
        larger ones update bandwidth after subtracting the current
        latency estimate."""
        seconds = max(seconds, _MIN_SECONDS)
        with self._lock:
            est = self._link(src, dst)
            if nbytes <= _LATENCY_SAMPLE_BYTES:
                a = self.alpha if est.lat_samples else 1.0
                est.latency = (1 - a) * est.latency + a * seconds
                est.lat_samples += 1
            else:
                a = self.alpha if est.bw_samples else 1.0
                payload_s = max(seconds - est.latency, _MIN_SECONDS)
                bw = nbytes / payload_s
                est.bandwidth = (1 - a) * est.bandwidth + a * bw
                est.bw_samples += 1
            est.samples += 1

    # -- queries -------------------------------------------------------
    def bandwidth(self, src: int, dst: int) -> float:
        with self._lock:
            return self._link(src, dst).bandwidth

    def latency(self, src: int, dst: int) -> float:
        with self._lock:
            return self._link(src, dst).latency

    def samples(self, src: int, dst: int) -> int:
        with self._lock:
            est = self._links.get((src, dst))
            return est.samples if est is not None else 0

    def cost_s(self, src: int, dst: int, nbytes: int) -> float:
        """Predicted seconds to move ``nbytes`` over (src → dst) — the
        scheduler's transfer-cost estimate."""
        with self._lock:
            return self._link(src, dst).cost_s(nbytes)

    def chunk_bytes(self, src: int, dst: int, target_s: float,
                    lo: int = 64 << 10, hi: int = 8 << 20) -> int:
        """Pipeline chunk size for (src → dst): the bandwidth-delay
        product at ``target_s`` per chunk, clamped to [lo, hi] so a wild
        estimate can neither devolve into per-byte messages nor disable
        pipelining outright. QUANTIZED to a power of two with hysteresis:
        the EWMA drifts a little on every sample, and an un-quantized (or
        boundary-flapping) size would give messages fresh chunk shapes —
        defeating jit/transfer caches keyed on shapes (XLA recompiles per
        shape signature). The stored choice only moves once the raw
        bandwidth-delay product leaves a ~2.7× band around it."""
        import math
        with self._lock:
            est = self._link(src, dst)
            raw = min(max(est.bandwidth * target_s, lo), hi)
            key = (target_s, lo, hi)
            prev = est.chunk_choice.get(key)
            if prev is not None and prev / 2.66 <= raw <= prev * 2.66:
                return prev
            q = 1 << max(round(math.log2(raw)), 0)  # nearest power of two
            q = min(max(q, lo), hi)
            est.chunk_choice[key] = q
            return q

    def measured(self, src: int, dst: int) -> bool:
        """True once at least one real sample refined (src → dst)."""
        with self._lock:
            est = self._links.get((src, dst))
            return est is not None and est.samples > 0

    def seed_from_path(self, src: int, dst: int, via: int = HOST) -> bool:
        """Seed an UNMEASURED (src → dst) link from the measured two-hop
        path src → via → dst: bandwidth is the path's bottleneck, latency
        the hops' sum (ROADMAP follow-up c — a first estimate better than
        the global default, without probing all pairs at startup). The
        seed does not count as a sample, so the first real transfer still
        replaces it outright. Returns True when a seed was installed."""
        with self._lock:
            est = self._link(src, dst)
            if est.samples > 0:
                return False
            up = self._links.get((src, via))
            down = self._links.get((via, dst))
            if up is None or down is None \
                    or not (up.samples and down.samples):
                return False
            est.bandwidth = min(up.bandwidth, down.bandwidth)
            est.latency = up.latency + down.latency
            return True

    def window_chunks(self, src: int, dst: int, chunk_bytes: int,
                      lo: int = 2, hi: int = 16,
                      queue_depth: Optional[int] = None,
                      slab_bytes: Optional[int] = None) -> int:
        """Credit window for a chunk-streamed (src → dst) transfer.

        Without feedback (``queue_depth``/``slab_bytes`` both None) this
        is the static BDP sizing: how many chunks must be in flight to
        cover the link's bandwidth-delay product (one round-trip of
        credits at the measured bandwidth), plus one so the sender always
        has a chunk ready when a credit returns. Clamped to [lo, hi]: ≥2
        keeps the pipeline sustained even on degenerate estimates, and
        the cap bounds receiver-side landing memory.

        With feedback it is a CONTROLLER (AIMD), stepped on every credit
        the receiver considers — mid-stream, not just at CTS: a
        transfer-lane backlog of ``WINDOW_BACKLOG_DEPTH``+ chunks (or
        landing slabs above ``WINDOW_SLAB_LIMIT`` bytes) halves the
        window, never below 1 — the receiver is the bottleneck, and
        piling more chunks into its queue only grows latency for
        everything sharing the lane; an empty queue (the receiver drains
        ahead of arrival) widens it by one chunk back toward the BDP
        ceiling. The controller state is per directed link, so concurrent
        streams on one link share (and jointly adapt) the window."""
        with self._lock:
            est = self._link(src, dst)
            bdp = est.bandwidth * 2.0 * est.latency
            bdp_win = int(min(max(bdp // max(chunk_bytes, 1) + 1, lo), hi))
            if queue_depth is None and slab_bytes is None:
                return bdp_win
            cur = est.window_choice
            if cur is None:
                cur = bdp_win
            backed_up = (queue_depth or 0) >= WINDOW_BACKLOG_DEPTH \
                or (slab_bytes or 0) > WINDOW_SLAB_LIMIT
            if backed_up:
                cur = max(cur // 2, 1)           # multiplicative decrease
            elif (queue_depth or 0) == 0:
                cur = min(cur + 1, max(bdp_win, 1))   # additive increase
            est.window_choice = cur
            return cur

    def latency_outliers(self, sources, dst: int) -> Dict[int, float]:
        """Per-source EWMA latency toward ``dst``, as a ratio against the
        median across ``sources`` — the straggler-detection signal: a
        frozen/overloaded rank's (fault-delayed) traffic inflates its
        link latency while its peers' stays flat. Unmeasured links ratio
        to 1.0 (no evidence is not evidence of slowness)."""
        with self._lock:
            lats = {}
            for s in sources:
                est = self._links.get((s, dst))
                if est is not None and est.lat_samples > 0:
                    lats[s] = est.latency
        if not lats:
            return {s: 1.0 for s in sources}
        med = sorted(lats.values())[len(lats) // 2]
        med = max(med, _MIN_SECONDS)
        return {s: (lats[s] / med if s in lats else 1.0) for s in sources}

    def current_window(self, src: int, dst: int) -> Optional[int]:
        """The adaptive controller's current (src → dst) window, or None
        when no adaptive decision has been made on that link yet."""
        with self._lock:
            est = self._links.get((src, dst))
            return est.window_choice if est is not None else None

    def reset_window(self, src: int, dst: int) -> None:
        """Forget the adaptive controller state for (src → dst) — the
        next adaptive decision restarts from the BDP sizing (benchmarks
        use this for clean A/B arms; estimates are untouched)."""
        with self._lock:
            est = self._links.get((src, dst))
            if est is not None:
                est.window_choice = None

    # -- collective shape selection (distributed/collectives_rt.py) ----
    def ring_order(self, members: Sequence[int],
                   nbytes: int = 1 << 20) -> List[int]:
        """Topology-aware ring order over ``members`` for chunk-streamed
        collectives: a greedy nearest-neighbor walk over the EWMA link
        table, so each ring hop rides the cheapest still-available link
        out of the current endpoint (predicted ``cost_s`` at ``nbytes``
        per hop — the bandwidth-phase payload size, since ring
        collectives are bandwidth-bound). Deterministic: the walk starts
        at the smallest member id and breaks cost ties by member id, so
        an unmeasured table (all defaults) degrades to sorted order and
        two runs over the same estimates choose the same ring — which is
        what keeps ring-reduction order, and therefore float bits,
        reproducible."""
        members = sorted(set(members))
        if len(members) <= 2:
            return members
        with self._lock:
            def cost(a: int, b: int) -> float:
                est = self._links.get((a, b))
                if est is None:
                    est = LinkEstimate(self._default_bw, self._default_lat)
                return est.cost_s(nbytes)

            order = [members[0]]
            rest = set(members[1:])
            while rest:
                cur = order[-1]
                order.append(min(rest, key=lambda c: (cost(cur, c), c)))
                rest.discard(order[-1])
        return order

    def tree_order(self, root: int, members: Sequence[int],
                   nbytes: int = 4 << 10) -> List[int]:
        """Binomial-tree position order for eager (latency-bound)
        collectives: ``root`` at position 0, remaining members sorted by
        predicted (root → member) link cost at the small-message size,
        ties by member id. Binomial trees put low positions nearest the
        root and give them the most children, so ranks behind the
        fastest links carry the widest fan-out while slow links hang off
        the leaves. Deterministic under equal estimates (sorted order),
        for the same bit-reproducibility reason as ``ring_order``."""
        members = sorted(set(members))
        if root not in members:
            raise ValueError(f"tree root {root} not in members {members}")
        rest = [m for m in members if m != root]
        with self._lock:
            def cost(m: int) -> float:
                est = self._links.get((root, m))
                if est is None:
                    est = LinkEstimate(self._default_bw, self._default_lat)
                return est.cost_s(nbytes)

            rest.sort(key=lambda m: (cost(m), m))
        return [root] + rest

    def penalty_bytes(self, src: int, dst: int, seconds: float,
                      lo: int = 64 << 10, hi: int = 1 << 20) -> int:
        """Byte-equivalent of ``seconds`` of queueing on the (src → dst)
        link — how the gravity placement converts queue pressure into the
        byte space its score lives in (clamped: a degenerate bandwidth
        estimate must not swamp or erase real residency)."""
        with self._lock:
            bw = self._link(src, dst).bandwidth
        return int(min(max(bw * seconds, lo), hi))

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Stats view: ``{"src->dst": {bw_MBps, lat_us, samples}}``."""
        with self._lock:
            return {
                f"{src}->{dst}": {
                    "bw_MBps": round(e.bandwidth / 1e6, 3),
                    "lat_us": round(e.latency * 1e6, 3),
                    "samples": e.samples,
                }
                for (src, dst), e in sorted(self._links.items())
            }


def probe_link(src_dev, dst_dev, model: InterconnectModel,
               nbytes: int = 64 << 10) -> None:
    """Lazy first-use micro-probe of one device pair (ROADMAP follow-up
    c): the startup probe covers host→device plus a device ring in O(n);
    any pair it skipped gets ONE timed ``nbytes`` transfer here, the
    moment the runtime first moves real data across it. The staging
    upload onto the source device is not timed — only the src→dst hop
    under measurement is."""
    import time

    import numpy as np

    payload = np.ones(max(nbytes // 4, 1), np.float32)
    staged = src_dev.upload(payload)
    if hasattr(staged, "block_until_ready"):
        staged.block_until_ready()
    t0 = time.perf_counter()
    moved = dst_dev.transfer_from(src_dev, staged)
    if hasattr(moved, "block_until_ready"):
        moved.block_until_ready()
    model.observe(src_dev.info.device_id, dst_dev.info.device_id,
                  payload.nbytes, time.perf_counter() - t0)


def probe_runtime_links(model: InterconnectModel, devices,
                        nbytes: int = 64 << 10) -> None:
    """Cheap startup micro-probe: one ``nbytes`` upload per device (host →
    device) and one ring hop per adjacent device pair (device → device,
    both directions), each timed and folded into ``model``. Ring, not
    all-pairs: the probe must stay O(n) so runtimes with many devices
    start fast; online refinement fills in the rest."""
    import time

    import numpy as np

    from repro.core.hetero_object import HOST

    payload = np.ones(max(nbytes // 4, 1), np.float32)
    staged = {}
    for dev in devices:
        t0 = time.perf_counter()
        arr = dev.upload(payload)
        if hasattr(arr, "block_until_ready"):
            arr.block_until_ready()
        model.observe(HOST, dev.info.device_id, payload.nbytes,
                      time.perf_counter() - t0)
        staged[dev.info.device_id] = arr
    n = len(devices)
    seen = set()
    for i in range(n if n > 1 else 0):
        src, dst = devices[i], devices[(i + 1) % n]
        for a, b in ((src, dst), (dst, src)):
            if (a.info.device_id, b.info.device_id) in seen:
                continue
            seen.add((a.info.device_id, b.info.device_id))
            t0 = time.perf_counter()
            moved = b.transfer_from(a, staged[a.info.device_id])
            if hasattr(moved, "block_until_ready"):
                moved.block_until_ready()
            model.observe(a.info.device_id, b.info.device_id,
                          payload.nbytes, time.perf_counter() - t0)
