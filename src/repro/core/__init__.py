"""Heterogeneous tasking framework — the paper's primary contribution.

hetero_objects (coherence-tracked data), hetero_tasks (device-type-targeted
tasks with implicit dependency inference), a modular push/pop scheduler, a
memory layer (staging pools, LRU offload), and the Core Runtime gluing them
to the Device API.
"""
from repro.core.futures import HFuture  # noqa: F401
from repro.core.hetero_object import HOST, HeteroObject  # noqa: F401
from repro.core.hetero_task import Access, HeteroTask, TaskState  # noqa: F401
from repro.core.residency import (PLACEMENTS, DataGravityPolicy,  # noqa: F401
                                  LoadOnlyPolicy, PlacementPolicy,
                                  ResidencyLedger)
from repro.core.progress import Lane, ProgressEngine  # noqa: F401
from repro.core.integrity import (ChecksumError, digest_array,  # noqa: F401
                                  verify_array)
from repro.core.lineage import LineageLedger, LineageRecord  # noqa: F401
from repro.core.runtime import (InjectedTaskFault, Runtime,  # noqa: F401
                                RuntimeConfig)
from repro.core.taskgraph import GraphTracer, TracedGraph  # noqa: F401
from repro.core.topology import (InterconnectModel,  # noqa: F401
                                 LinkEstimate, probe_runtime_links)
from repro.core.scheduler import (SCHEDULERS, FifoScheduler,  # noqa: F401
                                  GravityScheduler, LeastLoadedScheduler,
                                  LocalityAwareScheduler, RoundRobinScheduler,
                                  Scheduler)
