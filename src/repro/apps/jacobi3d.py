"""Jacobi3D proxy application (paper §4.3–4.4).

Four execution modes on the same numerics:

  run_reference   — single-array jnp oracle
  run_tasked      — PREMA-style: the domain is over-decomposed into mobile
                    chunks executed as hetero_tasks with implicit
                    dependencies; halo exchange = put operations; compute and
                    halo traffic of different chunks overlap (paper Fig. 14)
  run_cluster     — distributed proxy on the message engine: slabs are
                    scattered over ranks through ``Rank.send`` (large slabs
                    ride the chunk-streamed rendezvous protocol), halo
                    planes travel as eager ``Rank.put`` operations into
                    preregistered halo objects, and the result is gathered
                    back through the same protocol — the paper's §4.3
                    distributed Jacobi on the topology-aware pipeline.
  run_cluster_elastic — run_cluster's numerics under the elastic fault-
                    tolerance runtime: slabs are mobile chunks tracked by
                    an OwnerMap, every iteration commits a checkpoint, and
                    a fault schedule (kill / revive / freeze) exercises the
                    detect → shrink → restore → resume loop live. The run
                    survives losing a rank mid-flight with a bounded stall
                    and NO restart, and the answer stays bit-identical.
  run_spmd        — production path: shard_map over a mesh axis with
                    ppermute halo exchange — the compiled TPU analogue;
                    ``bulk_sync=True`` emulates the MPI+CUDA baseline
                    (exchange, barrier, then compute), ``False`` lets XLA
                    overlap per-slab compute with the next face transfer.

The stencil itself also exists as a Pallas kernel (repro.kernels.jacobi3d).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.core import HeteroTask, Runtime
from repro.distributed.collectives import halo_exchange_1d
from repro.distributed.handlers import handler
from repro.distributed.overdecomp import DecompPlan, plan_decomposition


def stencil_update(u: jax.Array, lo0, hi0, lo1, hi1, lo2, hi2) -> jax.Array:
    """One Jacobi sweep over the interior given face halos (each a slab of
    thickness 1; zeros at physical boundaries)."""
    up = jnp.pad(u, 1)
    up = up.at[0, 1:-1, 1:-1].set(lo0).at[-1, 1:-1, 1:-1].set(hi0)
    up = up.at[1:-1, 0, 1:-1].set(lo1).at[1:-1, -1, 1:-1].set(hi1)
    up = up.at[1:-1, 1:-1, 0].set(lo2).at[1:-1, 1:-1, -1].set(hi2)
    return ((up[:-2, 1:-1, 1:-1] + up[2:, 1:-1, 1:-1] +
             up[1:-1, :-2, 1:-1] + up[1:-1, 2:, 1:-1] +
             up[1:-1, 1:-1, :-2] + up[1:-1, 1:-1, 2:]) / 6.0).astype(u.dtype)


# ---------------------------------------------------------------------------
# reference
# ---------------------------------------------------------------------------

def run_reference(u0: np.ndarray, iters: int) -> np.ndarray:
    u = jnp.asarray(u0)

    @jax.jit
    def step(u):
        z = jnp.zeros
        return stencil_update(
            u,
            z(u.shape[1:]), z(u.shape[1:]),
            z((u.shape[0], u.shape[2])), z((u.shape[0], u.shape[2])),
            z(u.shape[:2]), z(u.shape[:2]))

    for _ in range(iters):
        u = step(u)
    return np.asarray(u)


# ---------------------------------------------------------------------------
# PREMA-tasked over-decomposed version
# ---------------------------------------------------------------------------

def run_tasked(u0: np.ndarray, iters: int, runtime: Runtime,
               over_decomposition: int = 1) -> np.ndarray:
    """Over-decomposed Jacobi on the heterogeneous tasking runtime. Chunks
    are hetero_objects; each iteration submits per-chunk face-extraction and
    update tasks whose dependencies the runtime infers — independent chunks
    overlap automatically (the paper's Fig. 14 pipeline)."""
    n_workers = len(runtime.devices)
    plan = plan_decomposition(u0.shape, n_workers, over_decomposition)
    chunks = {c.cid: runtime.hetero_object(
        np.ascontiguousarray(u0[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1],
                                c.lo[2]:c.hi[2]]), name=f"chunk{c.cid}")
        for c in plan.chunks}
    # halo buffers per (chunk, face)
    faces = {}
    for c in plan.chunks:
        s = c.shape
        face_shapes = {"lo0": (s[1], s[2]), "hi0": (s[1], s[2]),
                       "lo1": (s[0], s[2]), "hi1": (s[0], s[2]),
                       "lo2": (s[0], s[1]), "hi2": (s[0], s[1])}
        for tag, fs in face_shapes.items():
            faces[(c.cid, tag)] = runtime.hetero_object(
                np.zeros(fs, u0.dtype), name=f"halo{c.cid}:{tag}")

    # kernels created once → the runtime's jit cache hits across iterations
    def make_face_kernel(tag: str):
        d = int(tag[-1])
        hi = tag.startswith("hi")

        def extract(u, out):
            idx = [slice(None)] * 3
            idx[d] = -1 if hi else 0
            return u[tuple(idx)]
        return extract

    face_kernels = {tag: make_face_kernel(tag)
                    for tag in ("lo0", "hi0", "lo1", "hi1", "lo2", "hi2")}

    def update_kernel(u, l0, h0, l1, h1, l2, h2):
        return stencil_update(u, l0, h0, l1, h1, l2, h2)

    opposite = {"lo0": "hi0", "hi0": "lo0", "lo1": "hi1", "hi1": "lo1",
                "lo2": "hi2", "hi2": "lo2"}

    for _ in range(iters):
        # 1) extract + "send" faces into the neighbour's halo buffers (put)
        for c in plan.chunks:
            nb = plan.neighbors(c.cid)
            for tag, other in nb.items():
                if other is None:
                    continue
                runtime.run(
                    face_kernels[tag],
                    [(chunks[c.cid], "r"),
                     (faces[(other, opposite[tag])], "w")],
                    name=f"halo{c.cid}->{other}")
        # 2) update each chunk from its halo buffers
        for c in plan.chunks:
            args = [(chunks[c.cid], "rw")]
            for tag in ("lo0", "hi0", "lo1", "hi1", "lo2", "hi2"):
                args.append((faces[(c.cid, tag)], "r"))
            runtime.run(update_kernel, args, name=f"update{c.cid}")
        # iteration edge: the task-graph tracer keys recurrence detection
        # on this (no-op unless cfg.trace_graphs is set) — after
        # replay_after identical sweeps the whole iteration replays as
        # fused per-chain dispatches
        runtime.step_boundary()
    runtime.barrier(timeout=600)

    out = np.empty_like(u0)
    for c in plan.chunks:
        out[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1], c.lo[2]:c.hi[2]] = \
            chunks[c.cid].get()
    return out


# ---------------------------------------------------------------------------
# distributed version on the message engine (paper §4.3)
# ---------------------------------------------------------------------------
# handler-side state lives on the Rank objects themselves (one driver
# thread coordinates; handlers only deposit data and trip events)

@handler(name="jacobi_slab")
def _recv_slab(ctx, obj):
    st = ctx.rank._jacobi
    st["slab"] = obj
    st["slab_evt"].set()


@handler(name="jacobi_halo_done")
def _halo_done(ctx, obj):
    st = ctx.rank._jacobi
    with st["lock"]:
        st["halos"] += 1
        if st["halos"] >= st["halos_expected"]:
            st["halo_evt"].set()


@handler(name="jacobi_gather")
def _recv_gather(ctx, obj):
    st = ctx.rank._jacobi
    with st["lock"]:
        st["gathered"][ctx.message.user["part"]] = obj
        if len(st["gathered"]) >= st["gather_expected"]:
            st["gather_evt"].set()


def _slab_bounds(n: int, parts: int) -> List[Tuple[int, int]]:
    return [(p * n // parts, (p + 1) * n // parts) for p in range(parts)]


def run_cluster(u0: np.ndarray, iters: int, cluster, *,
                residual_every: int = 0,
                residuals: Optional[list] = None) -> np.ndarray:
    """Distributed Jacobi over ``cluster``'s ranks: axis-0 slab
    decomposition, scatter/gather through ``Rank.send`` (credit-windowed
    rendezvous streams for slabs above the eager threshold — big slabs
    never head-of-line block the halo control traffic), per-iteration
    halo planes through DIRECT ``Rank.put`` into preregistered halo
    objects (the freshly-extracted face already lives on a device, so the
    plane travels device-to-device; oversized planes would chunk-stream
    through the same rendezvous path).

    ``residual_every=k`` computes the global update-residual norm
    ``||u_new - u_old||_2`` every k iterations through a runtime
    allreduce of per-rank partial sums (``(iter, norm)`` appended to
    ``residuals``) — no slab ever travels to rank 0 for it, unlike the
    final gather."""
    ranks = cluster.ranks
    n = len(ranks)
    bounds = _slab_bounds(u0.shape[0], n)
    for i, r in enumerate(ranks):
        r._jacobi = {
            "lock": threading.Lock(), "slab": None,
            "slab_evt": threading.Event(), "halos": 0,
            "halos_expected": (1 if i > 0 else 0) + (1 if i < n - 1 else 0),
            "halo_evt": threading.Event(),
            "gathered": {}, "gather_expected": n - 1,
            "gather_evt": threading.Event(),
        }
    # scatter: rank 0 owns u0; remote slabs travel the message protocol
    for i, (lo, hi) in enumerate(bounds):
        part = np.ascontiguousarray(u0[lo:hi])
        if i == 0:
            ranks[0]._jacobi["slab"] = ranks[0].runtime.hetero_object(part)
        else:
            src = ranks[0].runtime.hetero_object(part)
            ranks[0].send(i, "jacobi_slab", src)
    for i in range(1, n):
        assert ranks[i]._jacobi["slab_evt"].wait(60), f"scatter to {i}"

    # per-rank halo objects + frozen zero faces for the untouched dims
    zeros = {}
    for i, r in enumerate(ranks):
        s = r._jacobi["slab"].shape
        rt = r.runtime
        r.register_object("jlo", rt.hetero_object(
            np.zeros((s[1], s[2]), u0.dtype)))
        r.register_object("jhi", rt.hetero_object(
            np.zeros((s[1], s[2]), u0.dtype)))
        zeros[i] = (rt.hetero_object(np.zeros((s[0], s[2]), u0.dtype)),
                    rt.hetero_object(np.zeros((s[0], s[1]), u0.dtype)))

    def lo_face(u, out):
        return u[0]

    def hi_face(u, out):
        return u[-1]

    def update(u, l0, h0, z1, z2):
        return stencil_update(u, l0, h0, z1, z1, z2, z2)

    coll = None
    if residual_every > 0:
        from repro.distributed.collectives_rt import CollectiveGroup
        coll = CollectiveGroup(cluster)

    for it in range(iters):
        res_tick = coll is not None and (it + 1) % residual_every == 0
        prev = {i: np.array(r._jacobi["slab"].get())
                for i, r in enumerate(ranks)} if res_tick else None
        for r in ranks:
            r._jacobi["halos"] = 0
            r._jacobi["halo_evt"].clear()
        # extract boundary planes + put them into the neighbours' halos
        for i, r in enumerate(ranks):
            rt, slab = r.runtime, r._jacobi["slab"]
            s = slab.shape
            if i > 0:
                f = rt.hetero_object(shape=(s[1], s[2]), dtype=u0.dtype)
                rt.run(lo_face, [(slab, "r"), (f, "w")])
                r.put(i - 1, "jhi", f, on_done="jacobi_halo_done",
                      path="direct")
            if i < n - 1:
                f = rt.hetero_object(shape=(s[1], s[2]), dtype=u0.dtype)
                rt.run(hi_face, [(slab, "r"), (f, "w")])
                r.put(i + 1, "jlo", f, on_done="jacobi_halo_done",
                      path="direct")
        for r in ranks:
            if r._jacobi["halos_expected"]:
                assert r._jacobi["halo_evt"].wait(60), "halo exchange"
        # update each slab from its (now current) halo objects
        for i, r in enumerate(ranks):
            rt, slab = r.runtime, r._jacobi["slab"]
            z1, z2 = zeros[i]
            rt.run(update, [(slab, "rw"), (r.objects["jlo"], "r"),
                            (r.objects["jhi"], "r"), (z1, "r"), (z2, "r")])
        for r in ranks:
            r.runtime.barrier(timeout=120)
        if res_tick:
            # per-rank partial ||du||^2, summed by a (tiny, eager-tree)
            # runtime allreduce — bit-identical on every member
            parts = [np.array(
                [np.sum((np.asarray(r._jacobi["slab"].get(),
                                    dtype=np.float64)
                         - prev[i]) ** 2)])
                for i, r in enumerate(ranks)]
            total = coll.allreduce(parts)[0]
            if residuals is not None:
                residuals.append((it + 1, float(np.sqrt(total[0]))))

    # gather back to rank 0 through the protocol
    for i in range(1, n):
        ranks[i].send(0, "jacobi_gather", ranks[i]._jacobi["slab"],
                      user={"part": i})
    if n > 1:
        assert ranks[0]._jacobi["gather_evt"].wait(60), "gather"
    out = np.empty_like(u0)
    out[bounds[0][0]:bounds[0][1]] = ranks[0]._jacobi["slab"].get()
    for i in range(1, n):
        lo, hi = bounds[i]
        out[lo:hi] = ranks[0]._jacobi["gathered"][i].get()
    return out


# ---------------------------------------------------------------------------
# elastic fault-tolerant version (ISSUE: ELASTIC-Recover)
# ---------------------------------------------------------------------------
# Slabs are mobile chunks keyed ("jslab", i) in an OwnerMap; halo planes
# land in per-slab objects ("jhalo", side, i) at the slab's CURRENT owner.
# The driver never assumes the world is stable: each iteration snapshots
# the elastic epoch under er.hold(), issues the halo puts against that
# snapshot, and redoes the phase from scratch if a recovery or drain
# bumped the epoch mid-exchange. Redo is safe because slabs only change
# inside the committed update phase — a re-extracted face is bitwise the
# face the first attempt extracted.

@handler(name="jacobi_eslab")
def _recv_eslab(ctx, obj):
    ctx.rank.register_object(("jslab", ctx.message.user["slab"]), obj)


@handler(name="jacobi_replica")
def _recv_replica(ctx, obj):
    """Landing half of slab replication: register the committed bytes as
    a live replica under the slab's global key (so ``ElasticRuntime``'s
    replica-first recovery finds it) and mark the (iteration, slab) pair
    arrived for the driver's replication barrier."""
    u = ctx.message.user
    old = ctx.rank.objects.get(("jslab", u["slab"]))
    if old is not None and old is not obj:
        ctx.rank.runtime.residency.forget(old)
    ctx.rank.register_object(("jslab", u["slab"]), obj)
    st = getattr(ctx.rank, "_jac_rep", None)
    if st is not None:
        with st["lock"]:
            st["got"].add((u["it"], u["slab"]))


@handler(name="jac_halo_mark")
def _halo_mark(ctx, obj):
    # obj is the preregistered halo target; None would mean the put beat
    # the registration (can't happen: registration is driver-side, before
    # the put issues) — refuse to mark rather than count lost data.
    st = getattr(ctx.rank, "_jac_halos", None)
    if st is None or obj is None:
        return
    with st["lock"]:
        st["got"].add(ctx.message.object_key)


def run_cluster_elastic(u0: np.ndarray, iters: int, cluster, *,
                        slabs: Optional[int] = None,
                        ckpt_dir: Optional[str] = None,
                        kill: Optional[Tuple[int, int]] = None,
                        revive_at: Optional[Tuple[int, int]] = None,
                        freeze: Optional[Tuple[int, int, float]] = None,
                        replicate: bool = False,
                        corrupt_links: float = 0.0,
                        corrupt_leaf_at: Optional[Tuple[int, str]] = None,
                        heartbeat_interval_s: float = 0.02,
                        heartbeat_timeout_s: float = 0.5,
                        straggler_factor: float = 25.0,
                        poll_period_s: Optional[float] = None,
                        wait_timeout_s: float = 120.0,
                        ) -> Tuple[np.ndarray, Dict[str, Any]]:
    """Distributed Jacobi that SURVIVES rank loss and stragglers mid-run.

    ``kill=(rank, it)`` kills ``rank`` after iteration ``it`` commits its
    checkpoint; ``revive_at=(rank, it)`` folds it back in with live
    rebalancing migrations; ``freeze=(rank, it, secs)`` freezes a rank's
    network (it keeps computing) so the straggler path drains chunks off
    it. Recovery restores lost slabs from the per-iteration checkpoint —
    exact committed bytes, so a faulted run matches an unfaulted one
    bit-for-bit. Returns ``(result, report)``.

    Integrity knobs (ISSUE: INTEG-Recover): ``replicate=True`` streams
    each slab's committed bytes to a buddy rank (next alive rank in the
    ring) every iteration, so recovery prefers a live replica over disk.
    ``corrupt_links=p`` bit-flips every host-staged payload on every
    directed link with probability ``p`` — the checksum layer rejects
    the flipped bytes and the reliability layer retransmits, so the run
    still converges bit-identically. ``corrupt_leaf_at=(it, key)`` flips
    one bit in that committed checkpoint leaf right after iteration
    ``it`` commits (silent storage corruption); the digest-validated
    restore path detects it and falls back to a replica or older step.
    """
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.distributed.elastic import ElasticRuntime
    from repro.distributed.mobile_object import OwnerMap, block_distribution

    ranks = cluster.ranks
    n = len(ranks)
    S = slabs or n
    bounds = _slab_bounds(u0.shape[0], S)
    owner = OwnerMap()
    for i, r in block_distribution(S, n).items():
        owner.assign(i, r)

    faults = cluster.faults
    if (kill or revive_at or freeze or corrupt_links
            or corrupt_leaf_at) and faults is None:
        faults = cluster.fault_injector()
    if kill is not None and ckpt_dir is None and not replicate:
        raise ValueError("kill schedule needs ckpt_dir or replicate=True: "
                         "lost slabs are restored from the committed "
                         "checkpoint or a live replica")
    if corrupt_leaf_at is not None and ckpt_dir is None:
        raise ValueError("corrupt_leaf_at needs ckpt_dir")
    if corrupt_links:
        for a in range(n):
            for b in range(n):
                if a != b:
                    faults.set_link(a, b, corrupt=corrupt_links)

    ckpt = (Checkpointer(ckpt_dir, keep=3, async_save=False)
            if ckpt_dir else None)

    def restore_fn(oid):
        # newest committed copy of the leaf that passes digest/shape
        # validation — a corrupted newest step falls back to an older one
        if ckpt.latest_step() is None:
            raise RuntimeError("rank loss before the first checkpoint")
        _step, arr = ckpt.restore_leaf_fallback(f"slab{oid}")
        return arr

    er = ElasticRuntime(
        cluster, owner, key_fn=lambda oid: ("jslab", oid),
        restore_fn=restore_fn if ckpt is not None else None,
        monitor=0, heartbeat_interval_s=heartbeat_interval_s,
        heartbeat_timeout_s=heartbeat_timeout_s,
        straggler_factor=straggler_factor)

    for r in ranks:
        r._jac_halos = {"lock": threading.Lock(), "got": set()}
        r._jac_rep = {"lock": threading.Lock(), "got": set()}

    # -- scatter against the initial owner map -------------------------
    for i, (lo, hi) in enumerate(bounds):
        part = np.ascontiguousarray(u0[lo:hi])
        dst = owner.owner(i)
        obj = ranks[0].runtime.hetero_object(part)
        if dst == 0:
            ranks[0].register_object(("jslab", i), obj)
        else:
            ranks[0].send(dst, "jacobi_eslab", obj, user={"slab": i})
    t_end = time.time() + wait_timeout_s
    for i in range(S):
        while ("jslab", i) not in ranks[owner.owner(i)].objects:
            assert time.time() < t_end, f"scatter of slab {i} stalled"
            time.sleep(0.002)

    # kernels created once → per-shape jit cache hits on EVERY rank, so a
    # migrated slab computes the same bits wherever it lands
    def lo_face(u, out):
        return u[0]

    def hi_face(u, out):
        return u[-1]

    def update(u, l0, h0, z1, z2):
        return stencil_update(u, l0, h0, z1, z1, z2, z2)

    zcache: Dict[Tuple[int, Tuple[int, ...]], Tuple[Any, Any]] = {}

    def zeros_for(r, s):
        z = zcache.get((r.rank, s))
        if z is None:
            z = (r.runtime.hetero_object(np.zeros((s[0], s[2]), u0.dtype)),
                 r.runtime.hetero_object(np.zeros((s[0], s[1]), u0.dtype)))
            zcache[(r.rank, s)] = z
        return z

    def ensure_halos():
        # halo targets must exist at a slab's current owner BEFORE any put
        # for this epoch issues (registration is driver-side + in-process,
        # so it happens-before the put's network delivery)
        for i in range(S):
            r = ranks[owner.owner(i)]
            s = r.objects[("jslab", i)].shape
            for side in ("lo", "hi"):
                key = ("jhalo", side, i)
                if key not in r.objects:
                    r.register_object(key, r.runtime.hetero_object(
                        np.zeros((s[1], s[2]), u0.dtype)))

    def issue_halos():
        expected = []
        for i in range(S):
            src = ranks[owner.owner(i)]
            rt = src.runtime
            slab = src.objects[("jslab", i)]
            s = slab.shape
            if i > 0:
                f = rt.hetero_object(shape=(s[1], s[2]), dtype=u0.dtype)
                rt.run(lo_face, [(slab, "r"), (f, "w")])
                src.put(owner.owner(i - 1), ("jhalo", "hi", i - 1), f,
                        on_done="jac_halo_mark", path="direct")
                expected.append((owner.owner(i - 1), ("jhalo", "hi", i - 1)))
            if i < S - 1:
                f = rt.hetero_object(shape=(s[1], s[2]), dtype=u0.dtype)
                rt.run(hi_face, [(slab, "r"), (f, "w")])
                src.put(owner.owner(i + 1), ("jhalo", "lo", i + 1), f,
                        on_done="jac_halo_mark", path="direct")
                expected.append((owner.owner(i + 1), ("jhalo", "lo", i + 1)))
        return expected

    er.start(poll_period_s)
    try:
        for it in range(iters):
            rep_expected: List[Tuple[int, int]] = []
            while True:               # redo loop: one pass per world epoch
                with er.hold():
                    epoch0 = er.epoch
                    for r in ranks:
                        with r._jac_halos["lock"]:
                            r._jac_halos["got"].clear()
                    ensure_halos()
                    expected = issue_halos()
                # wait outside the hold so the monitor can reshape the
                # world underneath us; epoch bump → redo from scratch
                t_end = time.time() + wait_timeout_s
                done = False
                while not done and er.epoch == epoch0:
                    done = all(key in ranks[dst]._jac_halos["got"]
                               for dst, key in expected)
                    if done:
                        break
                    assert time.time() < t_end, \
                        f"halo exchange stalled at iteration {it}"
                    time.sleep(0.002)
                if not done:
                    continue
                with er.hold():
                    if er.epoch != epoch0:
                        continue       # world changed after the wait; redo
                    for i in range(S):
                        r = ranks[owner.owner(i)]
                        slab = r.objects[("jslab", i)]
                        z1, z2 = zeros_for(r, slab.shape)
                        r.runtime.run(
                            update,
                            [(slab, "rw"),
                             (r.objects[("jhalo", "lo", i)], "r"),
                             (r.objects[("jhalo", "hi", i)], "r"),
                             (z1, "r"), (z2, "r")])
                    alive = set(er.controller.alive_workers())
                    for r in ranks:
                        if r.rank in alive:
                            r.runtime.barrier(timeout=wait_timeout_s)
                    if ckpt is not None:
                        ckpt.save(it, {
                            f"slab{i}": np.asarray(
                                ranks[owner.owner(i)]
                                .objects[("jslab", i)].get())
                            for i in range(S)}, block=True)
                    if replicate:
                        # stream each slab's committed bytes to its ring
                        # buddy; recovery will prefer this live replica
                        # over a disk read. Stale replicas elsewhere are
                        # dropped first — a later recovery must never
                        # resurrect an older iteration's bytes.
                        for i in range(S):
                            own = owner.owner(i)
                            cands = sorted(w for w in alive if w != own)
                            if not cands:
                                continue
                            buddy = next((w for w in cands if w > own),
                                         cands[0])
                            for r in ranks:
                                if r.rank in (own, buddy):
                                    continue
                                stale = r.objects.pop(("jslab", i), None)
                                if stale is not None:
                                    r.runtime.residency.forget(stale)
                            ranks[own].send(
                                buddy, "jacobi_replica",
                                ranks[own].objects[("jslab", i)],
                                user={"slab": i, "it": it})
                            rep_expected.append((buddy, i))
                    break              # iteration committed
            # replication barrier OUTSIDE the hold (the buddy's pump must
            # run to land the stream) and BEFORE the fault schedule: the
            # replica must exist before the rank it protects against dies
            t_end = time.time() + wait_timeout_s
            for buddy, i in rep_expected:
                while (it, i) not in ranks[buddy]._jac_rep["got"]:
                    assert time.time() < t_end, \
                        f"replica of slab {i} stalled at iteration {it}"
                    time.sleep(0.002)
            # fault schedule fires AFTER the commit point, so a restore
            # replays exactly this iteration's bytes
            if faults is not None:
                if corrupt_leaf_at is not None and it == corrupt_leaf_at[0]:
                    faults.corrupt_checkpoint_leaf(ckpt_dir, it,
                                                   corrupt_leaf_at[1])
                if kill is not None and it == kill[1]:
                    faults.kill_rank(kill[0])
                if freeze is not None and it == freeze[1]:
                    faults.freeze_rank(freeze[0], freeze[2])
                if revive_at is not None and it == revive_at[1]:
                    faults.revive_rank(revive_at[0])
                    er.grow([revive_at[0]])
    finally:
        er.close()

    report = er.report()
    report["epochs"] = er.epoch
    if faults is not None:
        report["faults"] = dict(faults.stats)
    report["integrity"] = {
        "checksum_fail": sum(r.stats["checksum_fail"] for r in ranks),
        "chunks_rejected": sum(r.stats["chunks_rejected"] for r in ranks),
        "retries": sum(r.stats["retries"] for r in ranks),
        "task_retries": sum(r.runtime.stats()["task_retries"]
                            for r in ranks),
        "lineage_recomputes": sum(r.runtime.stats()["lineage_recomputes"]
                                  for r in ranks),
        "ckpt_verify_fail": ckpt.stats["ckpt_verify_fail"] if ckpt else 0,
        "restore_fallbacks": er.stats["restore_fallbacks"],
    }
    report["collectives"] = {
        "coll_bytes_reduced": sum(
            r.stats["coll_bytes_reduced"] for r in ranks),
        "coll_chunks_in_flight_peak": max(
            r.stats["coll_chunks_in_flight_peak"] for r in ranks),
        "coll_aborts": sum(r.stats["coll_aborts"] for r in ranks),
    }
    out = np.empty_like(u0)
    for i, (lo, hi) in enumerate(bounds):
        out[lo:hi] = np.asarray(
            ranks[owner.owner(i)].objects[("jslab", i)].get())
    return out, report


# ---------------------------------------------------------------------------
# SPMD production version (shard_map + ppermute)
# ---------------------------------------------------------------------------

def make_spmd_step(mesh: Mesh, axis: str = "data", bulk_sync: bool = False):
    """Returns a jitted step: u sharded along dim 0 of [X,Y,Z] over ``axis``.
    bulk_sync=True forces the halo exchange to complete before any compute
    (optimization barrier) — the MPI+CUDA baseline schedule."""

    def local_step(u):
        lo0, hi0 = halo_exchange_1d(u, axis)
        if bulk_sync:
            u, lo0, hi0 = jax.lax.optimization_barrier((u, lo0, hi0))
        z = jnp.zeros
        return stencil_update(
            u, lo0[0], hi0[0],
            z((u.shape[0], u.shape[2]), u.dtype),
            z((u.shape[0], u.shape[2]), u.dtype),
            z((u.shape[0], u.shape[1]), u.dtype),
            z((u.shape[0], u.shape[1]), u.dtype))

    step = jax.shard_map(local_step, mesh=mesh,
                         in_specs=PS(axis), out_specs=PS(axis))
    return jax.jit(step)


def run_spmd(u0: np.ndarray, iters: int, mesh: Mesh, axis: str = "data",
             bulk_sync: bool = False) -> np.ndarray:
    step = make_spmd_step(mesh, axis, bulk_sync)
    sharding = NamedSharding(mesh, PS(axis))
    u = jax.device_put(jnp.asarray(u0), sharding)
    for _ in range(iters):
        u = step(u)
    return np.asarray(u)
