"""Jacobi3D proxy application (paper §4.3–4.4).

Three execution modes on the same numerics:

  run_reference   — single-array jnp oracle
  run_tasked      — PREMA-style: the domain is over-decomposed into mobile
                    chunks executed as hetero_tasks with implicit
                    dependencies; halo exchange = put operations; compute and
                    halo traffic of different chunks overlap (paper Fig. 14)
  run_spmd        — production path: shard_map over a mesh axis with
                    ppermute halo exchange — the compiled TPU analogue;
                    ``bulk_sync=True`` emulates the MPI+CUDA baseline
                    (exchange, barrier, then compute), ``False`` lets XLA
                    overlap per-slab compute with the next face transfer.

The stencil itself also exists as a Pallas kernel (repro.kernels.jacobi3d).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.core import HeteroTask, Runtime
from repro.distributed.collectives import halo_exchange_1d
from repro.distributed.overdecomp import DecompPlan, plan_decomposition


def stencil_update(u: jax.Array, lo0, hi0, lo1, hi1, lo2, hi2) -> jax.Array:
    """One Jacobi sweep over the interior given face halos (each a slab of
    thickness 1; zeros at physical boundaries)."""
    up = jnp.pad(u, 1)
    up = up.at[0, 1:-1, 1:-1].set(lo0).at[-1, 1:-1, 1:-1].set(hi0)
    up = up.at[1:-1, 0, 1:-1].set(lo1).at[1:-1, -1, 1:-1].set(hi1)
    up = up.at[1:-1, 1:-1, 0].set(lo2).at[1:-1, 1:-1, -1].set(hi2)
    return ((up[:-2, 1:-1, 1:-1] + up[2:, 1:-1, 1:-1] +
             up[1:-1, :-2, 1:-1] + up[1:-1, 2:, 1:-1] +
             up[1:-1, 1:-1, :-2] + up[1:-1, 1:-1, 2:]) / 6.0).astype(u.dtype)


# ---------------------------------------------------------------------------
# reference
# ---------------------------------------------------------------------------

def run_reference(u0: np.ndarray, iters: int) -> np.ndarray:
    u = jnp.asarray(u0)

    @jax.jit
    def step(u):
        z = jnp.zeros
        return stencil_update(
            u,
            z(u.shape[1:]), z(u.shape[1:]),
            z((u.shape[0], u.shape[2])), z((u.shape[0], u.shape[2])),
            z(u.shape[:2]), z(u.shape[:2]))

    for _ in range(iters):
        u = step(u)
    return np.asarray(u)


# ---------------------------------------------------------------------------
# PREMA-tasked over-decomposed version
# ---------------------------------------------------------------------------

def run_tasked(u0: np.ndarray, iters: int, runtime: Runtime,
               over_decomposition: int = 1) -> np.ndarray:
    """Over-decomposed Jacobi on the heterogeneous tasking runtime. Chunks
    are hetero_objects; each iteration submits per-chunk face-extraction and
    update tasks whose dependencies the runtime infers — independent chunks
    overlap automatically (the paper's Fig. 14 pipeline)."""
    n_workers = len(runtime.devices)
    plan = plan_decomposition(u0.shape, n_workers, over_decomposition)
    chunks = {c.cid: runtime.hetero_object(
        np.ascontiguousarray(u0[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1],
                                c.lo[2]:c.hi[2]]), name=f"chunk{c.cid}")
        for c in plan.chunks}
    # halo buffers per (chunk, face)
    faces = {}
    for c in plan.chunks:
        s = c.shape
        face_shapes = {"lo0": (s[1], s[2]), "hi0": (s[1], s[2]),
                       "lo1": (s[0], s[2]), "hi1": (s[0], s[2]),
                       "lo2": (s[0], s[1]), "hi2": (s[0], s[1])}
        for tag, fs in face_shapes.items():
            faces[(c.cid, tag)] = runtime.hetero_object(
                np.zeros(fs, u0.dtype), name=f"halo{c.cid}:{tag}")

    # kernels created once → the runtime's jit cache hits across iterations
    def make_face_kernel(tag: str):
        d = int(tag[-1])
        hi = tag.startswith("hi")

        def extract(u, out):
            idx = [slice(None)] * 3
            idx[d] = -1 if hi else 0
            return u[tuple(idx)]
        return extract

    face_kernels = {tag: make_face_kernel(tag)
                    for tag in ("lo0", "hi0", "lo1", "hi1", "lo2", "hi2")}

    def update_kernel(u, l0, h0, l1, h1, l2, h2):
        return stencil_update(u, l0, h0, l1, h1, l2, h2)

    opposite = {"lo0": "hi0", "hi0": "lo0", "lo1": "hi1", "hi1": "lo1",
                "lo2": "hi2", "hi2": "lo2"}

    for _ in range(iters):
        # 1) extract + "send" faces into the neighbour's halo buffers (put)
        for c in plan.chunks:
            nb = plan.neighbors(c.cid)
            for tag, other in nb.items():
                if other is None:
                    continue
                runtime.run(
                    face_kernels[tag],
                    [(chunks[c.cid], "r"),
                     (faces[(other, opposite[tag])], "w")],
                    name=f"halo{c.cid}->{other}")
        # 2) update each chunk from its halo buffers
        for c in plan.chunks:
            args = [(chunks[c.cid], "rw")]
            for tag in ("lo0", "hi0", "lo1", "hi1", "lo2", "hi2"):
                args.append((faces[(c.cid, tag)], "r"))
            runtime.run(update_kernel, args, name=f"update{c.cid}")
    runtime.barrier(timeout=600)

    out = np.empty_like(u0)
    for c in plan.chunks:
        out[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1], c.lo[2]:c.hi[2]] = \
            chunks[c.cid].get()
    return out


# ---------------------------------------------------------------------------
# SPMD production version (shard_map + ppermute)
# ---------------------------------------------------------------------------

def make_spmd_step(mesh: Mesh, axis: str = "data", bulk_sync: bool = False):
    """Returns a jitted step: u sharded along dim 0 of [X,Y,Z] over ``axis``.
    bulk_sync=True forces the halo exchange to complete before any compute
    (optimization barrier) — the MPI+CUDA baseline schedule."""

    def local_step(u):
        lo0, hi0 = halo_exchange_1d(u, axis)
        if bulk_sync:
            u, lo0, hi0 = jax.lax.optimization_barrier((u, lo0, hi0))
        z = jnp.zeros
        return stencil_update(
            u, lo0[0], hi0[0],
            z((u.shape[0], u.shape[2]), u.dtype),
            z((u.shape[0], u.shape[2]), u.dtype),
            z((u.shape[0], u.shape[1]), u.dtype),
            z((u.shape[0], u.shape[1]), u.dtype))

    step = jax.shard_map(local_step, mesh=mesh,
                         in_specs=PS(axis), out_specs=PS(axis))
    return jax.jit(step)


def run_spmd(u0: np.ndarray, iters: int, mesh: Mesh, axis: str = "data",
             bulk_sync: bool = False) -> np.ndarray:
    step = make_spmd_step(mesh, axis, bulk_sync)
    sharding = NamedSharding(mesh, PS(axis))
    u = jax.device_put(jnp.asarray(u0), sharding)
    for _ in range(iters):
        u = step(u)
    return np.asarray(u)
