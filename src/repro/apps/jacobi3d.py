"""Jacobi3D proxy application (paper §4.3–4.4).

Four execution modes on the same numerics:

  run_reference   — single-array jnp oracle
  run_tasked      — PREMA-style: the domain is over-decomposed into mobile
                    chunks executed as hetero_tasks with implicit
                    dependencies; halo exchange = put operations; compute and
                    halo traffic of different chunks overlap (paper Fig. 14)
  run_cluster     — distributed proxy on the message engine: slabs are
                    scattered over ranks through ``Rank.send`` (large slabs
                    ride the chunk-streamed rendezvous protocol), halo
                    planes travel as eager ``Rank.put`` operations into
                    preregistered halo objects, and the result is gathered
                    back through the same protocol — the paper's §4.3
                    distributed Jacobi on the topology-aware pipeline.
  run_spmd        — production path: shard_map over a mesh axis with
                    ppermute halo exchange — the compiled TPU analogue;
                    ``bulk_sync=True`` emulates the MPI+CUDA baseline
                    (exchange, barrier, then compute), ``False`` lets XLA
                    overlap per-slab compute with the next face transfer.

The stencil itself also exists as a Pallas kernel (repro.kernels.jacobi3d).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.core import HeteroTask, Runtime
from repro.distributed.collectives import halo_exchange_1d
from repro.distributed.handlers import handler
from repro.distributed.overdecomp import DecompPlan, plan_decomposition


def stencil_update(u: jax.Array, lo0, hi0, lo1, hi1, lo2, hi2) -> jax.Array:
    """One Jacobi sweep over the interior given face halos (each a slab of
    thickness 1; zeros at physical boundaries)."""
    up = jnp.pad(u, 1)
    up = up.at[0, 1:-1, 1:-1].set(lo0).at[-1, 1:-1, 1:-1].set(hi0)
    up = up.at[1:-1, 0, 1:-1].set(lo1).at[1:-1, -1, 1:-1].set(hi1)
    up = up.at[1:-1, 1:-1, 0].set(lo2).at[1:-1, 1:-1, -1].set(hi2)
    return ((up[:-2, 1:-1, 1:-1] + up[2:, 1:-1, 1:-1] +
             up[1:-1, :-2, 1:-1] + up[1:-1, 2:, 1:-1] +
             up[1:-1, 1:-1, :-2] + up[1:-1, 1:-1, 2:]) / 6.0).astype(u.dtype)


# ---------------------------------------------------------------------------
# reference
# ---------------------------------------------------------------------------

def run_reference(u0: np.ndarray, iters: int) -> np.ndarray:
    u = jnp.asarray(u0)

    @jax.jit
    def step(u):
        z = jnp.zeros
        return stencil_update(
            u,
            z(u.shape[1:]), z(u.shape[1:]),
            z((u.shape[0], u.shape[2])), z((u.shape[0], u.shape[2])),
            z(u.shape[:2]), z(u.shape[:2]))

    for _ in range(iters):
        u = step(u)
    return np.asarray(u)


# ---------------------------------------------------------------------------
# PREMA-tasked over-decomposed version
# ---------------------------------------------------------------------------

def run_tasked(u0: np.ndarray, iters: int, runtime: Runtime,
               over_decomposition: int = 1) -> np.ndarray:
    """Over-decomposed Jacobi on the heterogeneous tasking runtime. Chunks
    are hetero_objects; each iteration submits per-chunk face-extraction and
    update tasks whose dependencies the runtime infers — independent chunks
    overlap automatically (the paper's Fig. 14 pipeline)."""
    n_workers = len(runtime.devices)
    plan = plan_decomposition(u0.shape, n_workers, over_decomposition)
    chunks = {c.cid: runtime.hetero_object(
        np.ascontiguousarray(u0[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1],
                                c.lo[2]:c.hi[2]]), name=f"chunk{c.cid}")
        for c in plan.chunks}
    # halo buffers per (chunk, face)
    faces = {}
    for c in plan.chunks:
        s = c.shape
        face_shapes = {"lo0": (s[1], s[2]), "hi0": (s[1], s[2]),
                       "lo1": (s[0], s[2]), "hi1": (s[0], s[2]),
                       "lo2": (s[0], s[1]), "hi2": (s[0], s[1])}
        for tag, fs in face_shapes.items():
            faces[(c.cid, tag)] = runtime.hetero_object(
                np.zeros(fs, u0.dtype), name=f"halo{c.cid}:{tag}")

    # kernels created once → the runtime's jit cache hits across iterations
    def make_face_kernel(tag: str):
        d = int(tag[-1])
        hi = tag.startswith("hi")

        def extract(u, out):
            idx = [slice(None)] * 3
            idx[d] = -1 if hi else 0
            return u[tuple(idx)]
        return extract

    face_kernels = {tag: make_face_kernel(tag)
                    for tag in ("lo0", "hi0", "lo1", "hi1", "lo2", "hi2")}

    def update_kernel(u, l0, h0, l1, h1, l2, h2):
        return stencil_update(u, l0, h0, l1, h1, l2, h2)

    opposite = {"lo0": "hi0", "hi0": "lo0", "lo1": "hi1", "hi1": "lo1",
                "lo2": "hi2", "hi2": "lo2"}

    for _ in range(iters):
        # 1) extract + "send" faces into the neighbour's halo buffers (put)
        for c in plan.chunks:
            nb = plan.neighbors(c.cid)
            for tag, other in nb.items():
                if other is None:
                    continue
                runtime.run(
                    face_kernels[tag],
                    [(chunks[c.cid], "r"),
                     (faces[(other, opposite[tag])], "w")],
                    name=f"halo{c.cid}->{other}")
        # 2) update each chunk from its halo buffers
        for c in plan.chunks:
            args = [(chunks[c.cid], "rw")]
            for tag in ("lo0", "hi0", "lo1", "hi1", "lo2", "hi2"):
                args.append((faces[(c.cid, tag)], "r"))
            runtime.run(update_kernel, args, name=f"update{c.cid}")
    runtime.barrier(timeout=600)

    out = np.empty_like(u0)
    for c in plan.chunks:
        out[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1], c.lo[2]:c.hi[2]] = \
            chunks[c.cid].get()
    return out


# ---------------------------------------------------------------------------
# distributed version on the message engine (paper §4.3)
# ---------------------------------------------------------------------------
# handler-side state lives on the Rank objects themselves (one driver
# thread coordinates; handlers only deposit data and trip events)

@handler(name="jacobi_slab")
def _recv_slab(ctx, obj):
    st = ctx.rank._jacobi
    st["slab"] = obj
    st["slab_evt"].set()


@handler(name="jacobi_halo_done")
def _halo_done(ctx, obj):
    st = ctx.rank._jacobi
    with st["lock"]:
        st["halos"] += 1
        if st["halos"] >= st["halos_expected"]:
            st["halo_evt"].set()


@handler(name="jacobi_gather")
def _recv_gather(ctx, obj):
    st = ctx.rank._jacobi
    with st["lock"]:
        st["gathered"][ctx.message.user["part"]] = obj
        if len(st["gathered"]) >= st["gather_expected"]:
            st["gather_evt"].set()


def _slab_bounds(n: int, parts: int) -> List[Tuple[int, int]]:
    return [(p * n // parts, (p + 1) * n // parts) for p in range(parts)]


def run_cluster(u0: np.ndarray, iters: int, cluster) -> np.ndarray:
    """Distributed Jacobi over ``cluster``'s ranks: axis-0 slab
    decomposition, scatter/gather through ``Rank.send`` (credit-windowed
    rendezvous streams for slabs above the eager threshold — big slabs
    never head-of-line block the halo control traffic), per-iteration
    halo planes through DIRECT ``Rank.put`` into preregistered halo
    objects (the freshly-extracted face already lives on a device, so the
    plane travels device-to-device; oversized planes would chunk-stream
    through the same rendezvous path)."""
    ranks = cluster.ranks
    n = len(ranks)
    bounds = _slab_bounds(u0.shape[0], n)
    for i, r in enumerate(ranks):
        r._jacobi = {
            "lock": threading.Lock(), "slab": None,
            "slab_evt": threading.Event(), "halos": 0,
            "halos_expected": (1 if i > 0 else 0) + (1 if i < n - 1 else 0),
            "halo_evt": threading.Event(),
            "gathered": {}, "gather_expected": n - 1,
            "gather_evt": threading.Event(),
        }
    # scatter: rank 0 owns u0; remote slabs travel the message protocol
    for i, (lo, hi) in enumerate(bounds):
        part = np.ascontiguousarray(u0[lo:hi])
        if i == 0:
            ranks[0]._jacobi["slab"] = ranks[0].runtime.hetero_object(part)
        else:
            src = ranks[0].runtime.hetero_object(part)
            ranks[0].send(i, "jacobi_slab", src)
    for i in range(1, n):
        assert ranks[i]._jacobi["slab_evt"].wait(60), f"scatter to {i}"

    # per-rank halo objects + frozen zero faces for the untouched dims
    zeros = {}
    for i, r in enumerate(ranks):
        s = r._jacobi["slab"].shape
        rt = r.runtime
        r.register_object("jlo", rt.hetero_object(
            np.zeros((s[1], s[2]), u0.dtype)))
        r.register_object("jhi", rt.hetero_object(
            np.zeros((s[1], s[2]), u0.dtype)))
        zeros[i] = (rt.hetero_object(np.zeros((s[0], s[2]), u0.dtype)),
                    rt.hetero_object(np.zeros((s[0], s[1]), u0.dtype)))

    def lo_face(u, out):
        return u[0]

    def hi_face(u, out):
        return u[-1]

    def update(u, l0, h0, z1, z2):
        return stencil_update(u, l0, h0, z1, z1, z2, z2)

    for _ in range(iters):
        for r in ranks:
            r._jacobi["halos"] = 0
            r._jacobi["halo_evt"].clear()
        # extract boundary planes + put them into the neighbours' halos
        for i, r in enumerate(ranks):
            rt, slab = r.runtime, r._jacobi["slab"]
            s = slab.shape
            if i > 0:
                f = rt.hetero_object(shape=(s[1], s[2]), dtype=u0.dtype)
                rt.run(lo_face, [(slab, "r"), (f, "w")])
                r.put(i - 1, "jhi", f, on_done="jacobi_halo_done",
                      path="direct")
            if i < n - 1:
                f = rt.hetero_object(shape=(s[1], s[2]), dtype=u0.dtype)
                rt.run(hi_face, [(slab, "r"), (f, "w")])
                r.put(i + 1, "jlo", f, on_done="jacobi_halo_done",
                      path="direct")
        for r in ranks:
            if r._jacobi["halos_expected"]:
                assert r._jacobi["halo_evt"].wait(60), "halo exchange"
        # update each slab from its (now current) halo objects
        for i, r in enumerate(ranks):
            rt, slab = r.runtime, r._jacobi["slab"]
            z1, z2 = zeros[i]
            rt.run(update, [(slab, "rw"), (r.objects["jlo"], "r"),
                            (r.objects["jhi"], "r"), (z1, "r"), (z2, "r")])
        for r in ranks:
            r.runtime.barrier(timeout=120)

    # gather back to rank 0 through the protocol
    for i in range(1, n):
        ranks[i].send(0, "jacobi_gather", ranks[i]._jacobi["slab"],
                      user={"part": i})
    if n > 1:
        assert ranks[0]._jacobi["gather_evt"].wait(60), "gather"
    out = np.empty_like(u0)
    out[bounds[0][0]:bounds[0][1]] = ranks[0]._jacobi["slab"].get()
    for i in range(1, n):
        lo, hi = bounds[i]
        out[lo:hi] = ranks[0]._jacobi["gathered"][i].get()
    return out


# ---------------------------------------------------------------------------
# SPMD production version (shard_map + ppermute)
# ---------------------------------------------------------------------------

def make_spmd_step(mesh: Mesh, axis: str = "data", bulk_sync: bool = False):
    """Returns a jitted step: u sharded along dim 0 of [X,Y,Z] over ``axis``.
    bulk_sync=True forces the halo exchange to complete before any compute
    (optimization barrier) — the MPI+CUDA baseline schedule."""

    def local_step(u):
        lo0, hi0 = halo_exchange_1d(u, axis)
        if bulk_sync:
            u, lo0, hi0 = jax.lax.optimization_barrier((u, lo0, hi0))
        z = jnp.zeros
        return stencil_update(
            u, lo0[0], hi0[0],
            z((u.shape[0], u.shape[2]), u.dtype),
            z((u.shape[0], u.shape[2]), u.dtype),
            z((u.shape[0], u.shape[1]), u.dtype),
            z((u.shape[0], u.shape[1]), u.dtype))

    step = jax.shard_map(local_step, mesh=mesh,
                         in_specs=PS(axis), out_specs=PS(axis))
    return jax.jit(step)


def run_spmd(u0: np.ndarray, iters: int, mesh: Mesh, axis: str = "data",
             bulk_sync: bool = False) -> np.ndarray:
    step = make_spmd_step(mesh, axis, bulk_sync)
    sharding = NamedSharding(mesh, PS(axis))
    u = jax.device_put(jnp.asarray(u0), sharding)
    for _ in range(iters):
        u = step(u)
    return np.asarray(u)
