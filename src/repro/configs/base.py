"""Configuration dataclasses for models, shapes and runs.

Every assigned architecture gets one module in this package exporting
``CONFIG`` (the exact published configuration) and ``smoke_config()`` (a
reduced same-family configuration for CPU tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer kinds used in the per-period layer pattern.
# ---------------------------------------------------------------------------
GLOBAL_ATTN = "global_attn"   # full causal attention
LOCAL_ATTN = "local_attn"     # sliding-window attention
RGLRU = "rglru"               # RG-LRU recurrent block (recurrentgemma)
SSD = "ssd"                   # Mamba-2 state-space duality block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # llama4-style always-on shared expert (0 = none)
    d_ff_shared: int = 0
    # which layers are MoE: every `interleave`-th layer (1 = all layers)
    interleave: int = 1
    router_jitter: float = 0.0
    load_balance_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    expand: int = 2
    headdim: int = 64          # mamba2 P (head dim)
    chunk_size: int = 256      # SSD chunk length
    conv_width: int = 4
    ngroups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0          # 0 → d_model
    conv_width: int = 4
    expand: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 → d_model // n_heads
    # Layer pattern repeated across depth, e.g. 5×local:1×global for gemma3.
    # Length of the tuple is the "period"; remainder layers (n_layers % period)
    # are taken from the prefix of the pattern and unrolled.
    layer_pattern: Tuple[str, ...] = (GLOBAL_ATTN,)
    window: int = 1024          # sliding window for LOCAL_ATTN layers
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # gating MLP (SwiGLU) unless False → GELU MLP (whisper)
    gated_mlp: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # encoder-decoder (whisper): encoder layers use bidirectional attention,
    # decoder layers add cross attention.
    enc_dec: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500     # precomputed frame positions (audio stub)
    # modality frontend stub: 'none' | 'vision' | 'audio'
    frontend: str = "none"
    frontend_tokens: int = 0    # e.g. 256 patch embeddings for vlm
    max_seq: int = 131072
    # Which shapes this arch supports. long_500k only for sub-quadratic stacks.
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return all(k in (SSD, RGLRU) for k in self.layer_pattern)

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + norms)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        attn = q + kv + o
        mlp_mult = 3 if self.gated_mlp else 2
        dense_mlp = mlp_mult * d * self.d_ff
        per_layer[GLOBAL_ATTN] = attn + dense_mlp
        per_layer[LOCAL_ATTN] = attn + dense_mlp
        if self.ssm is not None:
            di = self.ssm.expand * d
            nh = di // self.ssm.headdim
            in_proj = d * (2 * di + 2 * self.ssm.ngroups * self.ssm.d_state + nh)
            per_layer[SSD] = in_proj + di * d + di * self.ssm.conv_width
        if self.rglru is not None:
            w = self.rglru.lru_width or d
            per_layer[RGLRU] = 2 * d * w + w * d + 3 * w + dense_mlp
        if self.moe is not None:
            moe_mlp = (
                self.moe.num_experts * mlp_mult * d * self.moe.d_ff_expert
                + (mlp_mult * d * self.moe.d_ff_shared if self.moe.d_ff_shared else 0)
                + d * self.moe.num_experts
            )
        total = emb
        for i in range(self.n_layers):
            kind = self.layer_pattern[i % len(self.layer_pattern)]
            blk = per_layer[kind]
            if self.moe is not None and kind in (GLOBAL_ATTN, LOCAL_ATTN):
                if (i % self.moe.interleave) == self.moe.interleave - 1:
                    blk = blk - dense_mlp + moe_mlp
            total += blk + 2 * d  # norms
        if self.enc_dec:
            enc_attn = attn + (2 if not self.gated_mlp else 3) * d * self.d_ff
            total += self.n_encoder_layers * (enc_attn + 2 * d)
            total += self.n_layers * (attn + d)  # decoder cross-attn + norm
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top_k + shared)."""
        if self.moe is None:
            return self.param_count()
        dense_like = dataclasses.replace(self, moe=None)
        base = dense_like.param_count()
        d = self.d_model
        mlp_mult = 3 if self.gated_mlp else 2
        n_moe_layers = sum(
            1 for i in range(self.n_layers) if (i % self.moe.interleave) == self.moe.interleave - 1
        )
        delta = n_moe_layers * (
            self.moe.top_k * mlp_mult * d * self.moe.d_ff_expert
            + mlp_mult * d * self.moe.d_ff_shared
            - mlp_mult * d * self.d_ff
        )
        return int(base + delta)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

ARCH_IDS = (
    "recurrentgemma_9b",
    "gemma3_27b",
    "phi4_mini_3_8b",
    "codeqwen15_7b",
    "yi_9b",
    "pixtral_12b",
    "whisper_large_v3",
    "mamba2_370m",
    "llama4_scout_17b_a16e",
    "olmoe_1b_7b",
)

# CLI ids use dashes (``--arch recurrentgemma-9b``); module names use
# underscores.
_ALIASES = {
    "phi4_mini_38b": "phi4_mini_3_8b",
    "codeqwen1_5_7b": "codeqwen15_7b",
    "llama4_scout_17b_16e": "llama4_scout_17b_a16e",
}


def canon(arch_id: str) -> str:
    s = arch_id.replace("-", "_").replace(".", "_")
    return _ALIASES.get(s, s)


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch_id)}")
    return mod.smoke_config()


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The shape cells defined for an architecture (40 total over the pool)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        shapes.append(LONG_500K)
    return tuple(shapes)


def all_cells() -> Sequence[Tuple[str, ShapeConfig]]:
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            cells.append((arch, shape))
    return cells
