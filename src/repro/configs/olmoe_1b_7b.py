"""olmoe-1b-7b [moe] — 64 experts, top-8 routing. 16L d_model=2048 16H (kv=16)
d_ff=1024 (per expert) vocab=50304 [arXiv:2409.02060]."""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    moe=MoEConfig(num_experts=64, top_k=8, d_ff_expert=1024, interleave=1),
    supports_long_context=False,  # full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab=256,
        head_dim=16,
        layer_pattern=(GLOBAL_ATTN,),
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32, interleave=1),
    )
