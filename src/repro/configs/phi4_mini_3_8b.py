"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA. 32L d_model=3072 24H (GQA kv=8)
d_ff=8192 vocab=200064 [arXiv:2412.08905]."""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    tie_embeddings=True,
    supports_long_context=False,  # pure full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-smoke",
        family="dense",
        n_layers=3,
        d_model=48,
        n_heads=6,
        n_kv_heads=2,
        d_ff=96,
        vocab=256,
        head_dim=8,
        layer_pattern=(GLOBAL_ATTN,),
        tie_embeddings=True,
    )
