"""llama4-scout-17b-16e [moe] — 16 routed experts top-1 + shared expert,
early fusion. 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.configs.base import GLOBAL_ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    rope_theta=500000.0,
    moe=MoEConfig(
        num_experts=16,
        top_k=1,
        d_ff_expert=8192,
        d_ff_shared=8192,     # llama4 always-on shared expert
        interleave=1,
    ),
    supports_long_context=False,  # full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=8,
        layer_pattern=(GLOBAL_ATTN,),
        moe=MoEConfig(num_experts=4, top_k=1, d_ff_expert=128, d_ff_shared=128, interleave=1),
    )
