"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
48L d_model=1024 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060]."""
from repro.configs.base import SSD, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=1,              # attention-free; SSD heads derive from ssm config
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    layer_pattern=(SSD,),
    ssm=SSMConfig(d_state=128, expand=2, headdim=64, chunk_size=256, conv_width=4),
    tie_embeddings=True,
    supports_long_context=True,   # constant-size recurrent state
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=4,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab=256,
        layer_pattern=(SSD,),
        ssm=SSMConfig(d_state=16, expand=2, headdim=32, chunk_size=16, conv_width=4),
        tie_embeddings=True,
        supports_long_context=True,
    )
