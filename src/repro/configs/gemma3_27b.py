"""gemma3-27b [dense] — 5 local : 1 global attention, 128k context.
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-27b-pt family]."""
from repro.configs.base import GLOBAL_ATTN, LOCAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    layer_pattern=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,),
    window=1024,
    rope_theta=10000.0,  # local layers; global layers use scaled base (see models)
    max_seq=131072,
    # 5:1 local:global — decode cost is dominated by bounded-window local
    # layers; global layers use seq-sharded KV at 500k (see serve/).
    supports_long_context=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke",
        family="dense",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        layer_pattern=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,),
        window=16,
        supports_long_context=True,
    )
