"""whisper-large-v3 [audio] — encoder-decoder, conv frontend (STUB).
32 encoder + 32 decoder layers, d_model=1280 20H (kv=20) d_ff=5120 vocab=51866
[arXiv:2212.04356].

The conv1d mel frontend is a STUB per the brief: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, 1500, d_model) feeding the
encoder directly.
"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,            # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    layer_pattern=(GLOBAL_ATTN,),
    gated_mlp=False,        # whisper uses GELU MLP
    enc_dec=True,
    encoder_seq=1500,
    frontend="audio",
    max_seq=32768,
    supports_long_context=False,  # full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2,
        n_encoder_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        head_dim=16,
        layer_pattern=(GLOBAL_ATTN,),
        gated_mlp=False,
        enc_dec=True,
        encoder_seq=24,
        frontend="audio",
    )
