"""codeqwen1.5-7b [dense] — qwen1.5 architecture, MHA. 32L d_model=4096 32H
(kv=32) d_ff=13440 vocab=92416 [hf:Qwen/CodeQwen1.5-7B]."""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    rope_theta=1000000.0,
    supports_long_context=False,  # pure full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen-smoke",
        family="dense",
        n_layers=3,
        d_model=48,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=256,
        head_dim=12,
        layer_pattern=(GLOBAL_ATTN,),
    )
