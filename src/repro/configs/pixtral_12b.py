"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo text
backbone. 40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409].

The vision frontend is a STUB per the brief: ``input_specs()`` provides
precomputed patch embeddings of shape (batch, frontend_tokens, d_model),
early-fused at the head of the sequence.
"""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=131072,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    rope_theta=1000000.0,
    frontend="vision",
    frontend_tokens=256,
    supports_long_context=False,  # pure full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke",
        family="vlm",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=16,
        layer_pattern=(GLOBAL_ATTN,),
        frontend="vision",
        frontend_tokens=8,
    )
