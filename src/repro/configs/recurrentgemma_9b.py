"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, pattern 1 local : 2
recurrent. 38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000
[arXiv:2402.19427]."""
from repro.configs.base import (
    LOCAL_ATTN,
    RGLRU,
    ModelConfig,
    RGLRUConfig,
)

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    layer_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    window=2048,
    rope_theta=10000.0,
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    supports_long_context=True,   # recurrent state + bounded window
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab=256,
        head_dim=16,
        layer_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
        window=16,
        rglru=RGLRUConfig(lru_width=64, conv_width=4),
        supports_long_context=True,
    )
