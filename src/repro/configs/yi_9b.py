"""yi-9b [dense] — llama-arch GQA. 48L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000 [arXiv:2403.04652]."""
from repro.configs.base import GLOBAL_ATTN, ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    head_dim=128,
    layer_pattern=(GLOBAL_ATTN,),
    supports_long_context=False,  # pure full attention — long_500k skipped
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-smoke",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        head_dim=8,
        layer_pattern=(GLOBAL_ATTN,),
    )
