"""repro — reproduction of "Runtime Support for Performance Portability on
Heterogeneous Distributed Platforms" on the JAX/XLA stack.

Compatibility: call sites use the modern ``jax.shard_map`` spelling; on the
older jax in this container it only exists under ``jax.experimental`` with
the same signature, so alias it once here (this package root is imported
before any ``repro.*`` submodule).
"""
import jax

#: True when this jax predates the native ``jax.shard_map`` API and the
#: aliases below are in effect. The compat layer cannot emulate the new
#: partial-manual semantics (inner sharding constraints naming manual
#: axes); tests depending on those skip when this is set.
COMPAT_SHARD_MAP = not hasattr(jax, "shard_map")

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f, *args, **kwargs):
        if "check_vma" in kwargs:        # new-API name for check_rep
            kwargs["check_rep"] = kwargs.pop("check_vma")
        if "axis_names" in kwargs:       # new API: axes to shard manually;
            manual = set(kwargs.pop("axis_names"))   # old API wants the
            mesh = kwargs.get("mesh", args[0] if args else None)  # converse
            kwargs["auto"] = frozenset(
                n for n in mesh.axis_names if n not in manual)
        return _experimental_sm(f, *args, **kwargs)

    jax.shard_map = _shard_map

if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        frame = jax.core.axis_frame(axis_name)
        return getattr(frame, "size", frame)   # older jax returns the int
    jax.lax.axis_size = _axis_size
