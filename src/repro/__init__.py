"""repro — reproduction of "Runtime Support for Performance Portability on
Heterogeneous Distributed Platforms" on the JAX/XLA stack.

Compatibility: call sites use the modern ``jax.shard_map`` spelling and are
handed the native implementation whenever this jax provides one. On the
older jax in this container it only exists under ``jax.experimental`` with
the old signature, so alias it once here (this package root is imported
before any ``repro.*`` submodule). The alias also emulates the new
partial-manual semantics (``axis_names=``): it maps the manual set onto the
old ``auto=`` complement and records the manual axes in a thread-local while
the body traces, so ``repro.models.sharding.constrain`` can filter them out
of inner sharding constraints the way native shard_map does.
"""
import functools
import threading

import jax

#: True when this jax predates the native ``jax.shard_map`` API and the
#: aliases below are in effect. Code needing partial-manual semantics the
#: old XLA cannot compile (e.g. the compressed-gradient train step)
#: branches on this to an equivalent formulation.
COMPAT_SHARD_MAP = not hasattr(jax, "shard_map")

_compat_manual = threading.local()


def compat_manual_axes() -> frozenset:
    """Mesh axes manual in the shard_map body currently tracing on this
    thread (compat shim only; empty outside a shard_map trace)."""
    return getattr(_compat_manual, "axes", frozenset())


if COMPAT_SHARD_MAP:
    from jax.experimental.shard_map import shard_map as _experimental_sm

    def _shard_map(f, *args, **kwargs):
        if "check_vma" in kwargs:        # new-API name for check_rep
            kwargs["check_rep"] = kwargs.pop("check_vma")
        manual = None
        if "axis_names" in kwargs:       # new API: axes to shard manually;
            manual = frozenset(kwargs.pop("axis_names"))  # old API wants the
            mesh = kwargs.get("mesh", args[0] if args else None)  # converse
            kwargs["auto"] = frozenset(
                n for n in mesh.axis_names if n not in manual)

        if manual is not None:
            @functools.wraps(f)
            def body(*a, **k):
                prev = compat_manual_axes()
                _compat_manual.axes = prev | manual
                try:
                    return f(*a, **k)
                finally:
                    _compat_manual.axes = prev
        else:
            body = f
        return _experimental_sm(body, *args, **kwargs)

    jax.shard_map = _shard_map

if not hasattr(jax.lax, "axis_size"):
    def _axis_size(axis_name):
        frame = jax.core.axis_frame(axis_name)
        return getattr(frame, "size", frame)   # older jax returns the int

    jax.lax.axis_size = _axis_size
