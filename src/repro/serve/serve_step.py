"""Serving steps: batched prefill and single-token decode.

``decode_step`` is the unit lowered for the ``decode_*`` / ``long_*`` dry-run
cells: one new token per request against a KV cache of the cell's seq_len.
Sampling is greedy (argmax) — the engine layer adds temperature sampling.

``tasked_decode_loop`` drives the same decode step through the task
runtime instead of calling it directly: every step is one hetero_task
over the flattened (params, cache, tokens, lengths) state, delimited by
``Runtime.step_boundary()`` — exactly the recurring one-task window the
task-graph tracer compiles, so with ``RuntimeConfig.trace_graphs`` a
steady-state decode loop replays as a single fused dispatch per step
with zero per-task scheduling overhead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model


def make_prefill_step(model: Model):
    def prefill_step(params, batch: Dict[str, jax.Array], cache):
        """Returns (next_token [B,1], cache after prefill, last hidden)."""
        x, new_cache, _ = model.apply(params, batch, mode="prefill",
                                      cache=cache)
        last = x[:, -1:]
        logits = model.unembed(params, last)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens: jax.Array, lengths: jax.Array):
        """tokens: [B,1] current token; lengths: [B] tokens so far.
        Returns (next_token [B,1], new_cache)."""
        batch = {"tokens": tokens, "lengths": lengths}
        x, new_cache, _ = model.apply(params, batch, mode="decode",
                                      cache=cache)
        logits = model.unembed(params, x)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return decode_step


def tasked_decode_loop(runtime, model: Model, params, cache,
                       tokens, lengths, n_steps: int,
                       device_type: Optional[str] = None,
                       timeout: float = 120.0):
    """Run ``n_steps`` of greedy single-token decode as hetero_tasks.

    The model state is flattened into hetero_objects (params read-only,
    cache/tokens/lengths read-write) and each step submits ONE task whose
    kernel reassembles the pytrees, applies ``make_decode_step(model)``,
    and returns the new state leaves. ``step_boundary()`` after every
    submit marks the recurring window for the task-graph tracer.

    Everything stays on device for the whole loop — reading tokens from
    the host mid-loop would flush the traced window (by design: host
    reads must observe parked writes). Returns ``(tokens_obj,
    lengths_obj, cache_objs, cache_treedef)``; read final state with
    ``.get()`` after the loop's barrier."""
    decode = make_decode_step(model)
    p_leaves, p_def = jax.tree_util.tree_flatten(params)
    c_leaves, c_def = jax.tree_util.tree_flatten(cache)
    n_p = len(p_leaves)
    p_objs = [runtime.hetero_object(np.asarray(x), name=f"dec-p{i}")
              for i, x in enumerate(p_leaves)]
    c_objs = [runtime.hetero_object(np.asarray(x), name=f"dec-kv{i}")
              for i, x in enumerate(c_leaves)]
    tok_obj = runtime.hetero_object(np.asarray(tokens), name="dec-tok")
    len_obj = runtime.hetero_object(np.asarray(lengths), name="dec-len")

    # one kernel object for the whole loop → jit cache hits every step,
    # and the tracer sees the same kernel identity window after window
    def step_kernel(tok, lens, *leaves):
        params_ = jax.tree_util.tree_unflatten(p_def, leaves[:n_p])
        cache_ = jax.tree_util.tree_unflatten(c_def, leaves[n_p:])
        new_tok, new_cache = decode(params_, cache_, tok, lens)
        new_c = jax.tree_util.tree_flatten(new_cache)[0]
        # outputs bind to the write-args in arg order: tok, lens, cache
        return (new_tok, lens + 1) + tuple(new_c)

    args = ([(tok_obj, "rw"), (len_obj, "rw")]
            + [(o, "r") for o in p_objs] + [(o, "rw") for o in c_objs])
    for _ in range(n_steps):
        runtime.run(step_kernel, args, device_type=device_type,
                    name="decode_step")
        runtime.step_boundary()
    runtime.barrier(timeout=timeout)
    return tok_obj, len_obj, c_objs, c_def


def abstract_params(model: Model):
    def go():
        from repro.models.layers import unbox
        params, _ = unbox(model.init(jax.random.PRNGKey(0)))
        return params
    return jax.eval_shape(go)


def abstract_cache(model: Model, batch: int, cache_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))
