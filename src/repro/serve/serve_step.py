"""Serving steps: batched prefill and single-token decode.

``decode_step`` is the unit lowered for the ``decode_*`` / ``long_*`` dry-run
cells: one new token per request against a KV cache of the cell's seq_len.
Sampling is greedy (argmax) — the engine layer adds temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model


def make_prefill_step(model: Model):
    def prefill_step(params, batch: Dict[str, jax.Array], cache):
        """Returns (next_token [B,1], cache after prefill, last hidden)."""
        x, new_cache, _ = model.apply(params, batch, mode="prefill",
                                      cache=cache)
        last = x[:, -1:]
        logits = model.unembed(params, last)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens: jax.Array, lengths: jax.Array):
        """tokens: [B,1] current token; lengths: [B] tokens so far.
        Returns (next_token [B,1], new_cache)."""
        batch = {"tokens": tokens, "lengths": lengths}
        x, new_cache, _ = model.apply(params, batch, mode="decode",
                                      cache=cache)
        logits = model.unembed(params, x)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return decode_step


def abstract_params(model: Model):
    def go():
        from repro.models.layers import unbox
        params, _ = unbox(model.init(jax.random.PRNGKey(0)))
        return params
    return jax.eval_shape(go)


def abstract_cache(model: Model, batch: int, cache_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, cache_len))
