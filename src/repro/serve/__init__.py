from repro.serve.serve_step import (abstract_cache, abstract_params,  # noqa: F401
                                    make_decode_step, make_prefill_step)
