"""Pallas TPU matmul (DGEMM analogue — the paper's §4.1 microbenchmark).

MXU-aligned tiling: (bm × bk) · (bk × bn) accumulated in an f32 VMEM scratch
across the k grid dimension. Block sizes default to 128/256 multiples to map
onto the 128×128 MXU; ``interpret=True`` (CPU container) executes the same
kernel body in Python for validation against ``ref.matmul_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """a: [M,K] · b: [K,N] → [M,N]. M,N,K must divide by the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
