"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in the
CPU container (kernel bodies execute in Python) and compile to Mosaic on
real hardware.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.jacobi3d import jacobi3d as _jacobi3d
from repro.kernels.matmul import matmul as _matmul
from repro.kernels.ssd import ssd_chunk as _ssd_chunk


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(a, b, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _matmul(a, b, **kw)


def jacobi3d(u_pad, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _jacobi3d(u_pad, **kw)


def ssd_chunk(x, dt, A, B, C, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _ssd_chunk(x, dt, A, B, C, **kw)


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", _default_interpret())
    return _flash(q, k, v, **kw)
