"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk quadratic form.

Per (batch, chunk) program: computes the diagonal-block output

    Y_diag[l,h,p] = sum_s (C_l · B_s) * L[l,s] * dt_s * x[s,h,p]
    states[h,p,n] = sum_s B_s[n] * decay_s * dt_s * x[s,h,p]

with L = exp(segsum(dt*A)) built in-kernel. The inter-chunk linear
recurrence (tiny) stays on the host side — the same split real SSD
implementations use. Heads are folded into the grid so a program's VMEM
working set is one (chunk × headdim) tile plus the (chunk × chunk) decay.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0]                       # [q, hp, p]  (head-group tile)
    dt = dt_ref[0]                     # [q, hp]
    A = a_ref[...]                     # [hp]
    B = b_ref[0]                       # [q, n]
    C = c_ref[0]                       # [q, n]
    q = x.shape[0]

    dA = dt * A[None, :]               # [q, hp]
    cs = jnp.cumsum(dA, axis=0)        # [q, hp]
    # L[l, s, h] = exp(cs[l] - cs[s]) for s <= l
    diff = cs[:, None, :] - cs[None, :, :]
    il = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    js = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where((js <= il)[:, :, None], jnp.exp(diff), 0.0)   # [q,q,hp]

    scores = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # [q,q]
    xdt = x * dt[:, :, None]                                      # [q,hp,p]
    w = scores[:, :, None] * L                                    # [q,q,hp]
    y = jnp.einsum("lsh,shp->lhp", w, xdt)
    y_ref[0] = y.astype(y_ref.dtype)

    decay = jnp.exp(cs[-1:, :] - cs)                              # [q,hp]
    st = jnp.einsum("sn,sh,shp->hpn", B, decay * dt, x)
    st_ref[0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
              C: jax.Array, *, interpret: bool = False):
    """x: [bc, q, h, p]; dt: [bc, q, h]; A: [h]; B, C: [bc, q, n]
    (ngroups=1, group broadcast over heads; bc = batch·chunks folded).
    Returns (y_diag [bc,q,h,p], states [bc,h,p,n])."""
    bc, q, h, p = x.shape
    n = B.shape[-1]
    grid = (bc,)
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, q, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, n), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, h, p), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda i: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((bc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, B, C)
