"""Pallas TPU Jacobi-3D stencil (the proxy application's compute kernel).

Input is the halo-padded slab [X+2, Y+2, Z+2]; output the updated interior
[X, Y, Z]. The grid tiles the x dimension; each program reads its own tile
plus both x-neighbour tiles (three BlockSpecs over the same operand — the
TPU-idiomatic way to express ±1 halo reads without dynamic HBM loads), and
the full Y/Z planes, which keeps the VMEM working set to
3·(bx+?)·(Y+2)·(Z+2)·4B — pick bx so that fits ~16 MB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_kernel(prev_ref, cur_ref, nxt_ref, o_ref, *, bx: int,
                   x_tiles: int):
    # the three operands are x-shifted views tiled identically, so row j of
    # prev/nxt IS the x∓1 neighbour of interior row j — no cross-tile reads
    up = prev_ref[...]                      # [bx, Y+2, Z+2]
    cur = cur_ref[...]
    dn = nxt_ref[...]
    out = (up[:, 1:-1, 1:-1] + dn[:, 1:-1, 1:-1] +
           cur[:, :-2, 1:-1] + cur[:, 2:, 1:-1] +
           cur[:, 1:-1, :-2] + cur[:, 1:-1, 2:]) / 6.0
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bx", "interpret"))
def jacobi3d(u_pad: jax.Array, *, bx: int = 8,
             interpret: bool = False) -> jax.Array:
    """u_pad: [X+2, Y+2, Z+2] halo-padded slab → updated interior [X,Y,Z]."""
    xp, yp, zp = u_pad.shape
    x = xp - 2
    bx = min(bx, x)
    assert x % bx == 0, (x, bx)
    x_tiles = x // bx
    # interior rows live at u_pad[1:X+1]; tile t covers rows [1+t*bx, 1+(t+1)*bx)
    # we pass u_pad[1:-1] (interior rows) as the tiled operand and the padded
    # array twice more with shifted maps for the ±1 rows.
    interior = u_pad[1:-1]                        # [X, Y+2, Z+2]
    prev = u_pad[:-2]                             # row x-1 for interior row x
    nxt = u_pad[2:]                               # row x+1
    spec = pl.BlockSpec((bx, yp, zp), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_jacobi_kernel, bx=bx, x_tiles=x_tiles),
        grid=(x_tiles,),
        in_specs=[spec, spec, spec],
        out_specs=pl.BlockSpec((bx, yp - 2, zp - 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x, yp - 2, zp - 2), u_pad.dtype),
        interpret=interpret,
    )(prev, interior, nxt)
