"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def jacobi3d_ref(u_pad: jax.Array) -> jax.Array:
    """u_pad: [X+2, Y+2, Z+2] → interior update [X, Y, Z]."""
    return ((u_pad[:-2, 1:-1, 1:-1] + u_pad[2:, 1:-1, 1:-1] +
             u_pad[1:-1, :-2, 1:-1] + u_pad[1:-1, 2:, 1:-1] +
             u_pad[1:-1, 1:-1, :-2] + u_pad[1:-1, 1:-1, 2:]) / 6.0
            ).astype(u_pad.dtype)


def ssd_chunk_ref(x, dt, A, B, C):
    """Same contract as kernels.ssd.ssd_chunk (bc-folded, ngroups=1)."""
    dA = dt * A[None, None, :]                       # [bc,q,h]
    cs = jnp.cumsum(dA, axis=1)
    q = x.shape[1]
    diff = cs[:, :, None, :] - cs[:, None, :, :]     # [bc,l,s,h]
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bln,bsn->bls", C, B)
    xdt = x * dt[..., None]
    y = jnp.einsum("bls,blsh,bshp->blhp", scores, L, xdt)
    decay = jnp.exp(cs[:, -1:, :] - cs)              # [bc,q,h]
    st = jnp.einsum("bsn,bsh,bshp->bhpn", B, decay * dt, x)
    return y.astype(jnp.float32), st.astype(jnp.float32)


def flash_ref(q, k, v, causal=True):
    """q: [BH,S,D]; k,v: [BH,T,D]."""
    sc = jnp.einsum("bsd,btd->bst", q.astype(jnp.float32),
                    k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    if causal:
        s, t = sc.shape[1], sc.shape[2]
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]
        sc = jnp.where(mask[None], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
