"""Pallas TPU kernels for the compute hot spots: matmul (the paper's DGEMM
microbenchmark), jacobi3d (the proxy app stencil), ssd_chunk (mamba2 SSD
quadratic form), flash_attention. Each has a pure-jnp oracle in ref.py and a
jit'd wrapper in ops.py (interpret=True off-TPU)."""
from repro.kernels import ops, ref  # noqa: F401
