"""Pallas TPU flash-attention forward (beyond-paper perf feature).

Grid (batch·heads, q_blocks, kv_blocks); online softmax with f32 VMEM
scratch for (acc, m, l). Causal masking by absolute positions. Matches the
scan-based ``repro.models.attention.flash_attention`` contract (its oracle
is ``ref.flash_ref``). Block sizes default MXU-aligned (128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  kv_steps: int, qb: int, kb: int, causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # [qb, d]
    k = k_ref[0]                                   # [kb, d]
    v = v_ref[0]
    sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    sc = sc * (q.shape[-1] ** -0.5)
    if causal:
        qpos = qi * qb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 0)
        kpos = ki * kb + jax.lax.broadcasted_iota(jnp.int32, (qb, kb), 1)
        sc = jnp.where(kpos <= qpos, sc, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=-1))
    p = jnp.exp(sc - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "qb", "kb", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, qb: int = 128, kb: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [BH, S, D]; k, v: [BH, T, D] (batch·heads folded; GQA pre-broadcast).
    Returns [BH, S, D]."""
    bh, s, d = q.shape
    t = k.shape[1]
    qb, kb = min(qb, s), min(kb, t)
    assert s % qb == 0 and t % kb == 0
    grid = (bh, s // qb, t // kb)
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=t // kb, qb=qb, kb=kb,
                          causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, d), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
