from repro.train.optimizer import (AdamWConfig, AdamWState, TrainState,  # noqa: F401
                                   adamw_update, init_opt_state, lr_schedule)
from repro.train.train_step import (TrainConfig, abstract_train_state,  # noqa: F401
                                    init_train_state, make_train_step)
