"""AdamW with mixed precision and ZeRO-1 optimizer-state sharding.

Parameters are stored in the compute dtype (bf16); the optimizer keeps fp32
master weights and moments. Under a mesh, moment/master arrays are
additionally sharded over the data axes ("ZeRO-1") — the launch layer
resolves that via ``zero_axes``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    m: Any
    v: Any
    master: Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: AdamWState
    # error-feedback residuals for compressed cross-pod gradient reduction
    # (None unless TrainConfig.compress_pod_grads; leading dim = pod)
    ef: Any = None

    @property
    def step(self):
        return self.opt.step


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_peak * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    # copy=True: master must never alias params (donation would double-free)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros32, params),
        v=jax.tree.map(zeros32, params),
        master=jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
    )


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, state: TrainState, grads
                 ) -> Tuple[TrainState, Dict[str, jax.Array]]:
    opt = state.opt
    step = opt.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        master = master - lr * (update + cfg.weight_decay * master)
        return m, v, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(opt.m)
    flat_v = jax.tree.leaves(opt.v)
    flat_w = jax.tree.leaves(opt.master)
    new = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree.unflatten(treedef, [n[0] for n in new])
    new_v = jax.tree.unflatten(treedef, [n[1] for n in new])
    new_w = jax.tree.unflatten(treedef, [n[2] for n in new])
    old_dtypes = jax.tree.map(lambda p: p.dtype, state.params)
    new_params = jax.tree.map(lambda w, dt: w.astype(dt), new_w, old_dtypes)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return TrainState(params=new_params,
                      opt=AdamWState(step=step, m=new_m, v=new_v, master=new_w)
                      ), metrics
