"""Train-step factory with over-decomposition (microbatch) support.

The paper's over-decomposition insight — split the domain into more chunks
than processing elements so transfers pipeline behind compute — maps to
microbatched gradient accumulation on TPU: the per-microbatch backward's
gradient reduce-scatters/all-reduces overlap with the next microbatch's
compute under XLA's latency-hiding scheduler, and activation memory drops by
the over-decomposition factor.

``over_decompose=1`` is the paper-faithful "no over-decomposition" baseline
(one monolithic batch, synchronous reduction at the end).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model
from repro.train.optimizer import (AdamWConfig, TrainState,
                                   adamw_update, init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    over_decompose: int = 1      # microbatches per step (paper: OD level)
    z_loss: float = 0.0
    # int8 + error-feedback compression of the cross-pod gradient reduction
    # (multi-pod meshes only; see train/compression.py)
    compress_pod_grads: bool = False


def make_loss_fn(model: Model):
    def loss_fn(params, batch):
        x, _, aux = model.apply(params, batch, mode="train")
        ce = model.loss(params, x, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(model: Model, tcfg: TrainConfig, param_axes=None
                    ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                  Tuple[TrainState, Dict[str, jax.Array]]]:
    """param_axes: optional logical-axes tree (from layers.unbox) — used by
    the compressed-gradient path to keep in-pod shardings across the
    partially-manual shard_map boundary."""
    loss_fn = make_loss_fn(model)
    grad_fn = jax.grad(loss_fn, has_aux=True)
    od = tcfg.over_decompose

    def _compressed_grads(state, batch):
        """Gradients with the cross-pod reduction compressed (int8 + EF).

        Native jax: shard_map manual over 'pod' only; in-pod sharding stays
        automatic and the compressed payload rides an all_gather. Old jax
        (``repro.COMPAT_SHARD_MAP``) cannot compile a full model inside a
        partially-manual region (XLA IsManualSubgroup checks), so the same
        reduction runs as an in-graph scan over the pod dimension: per-pod
        gradients are quantized independently and the dequantized payloads
        are summed — numerics and error-feedback residuals identical to the
        distributed formulation."""
        import repro
        from jax.sharding import PartitionSpec as PS
        from repro.models.sharding import active_mesh
        from repro.train.compression import (compressed_mean_stacked_tree,
                                             compressed_pmean_tree)
        assert state.ef is not None, \
            "compress_pod_grads needs EF residuals: init_train_state(..., " \
            "ef_pods=mesh.shape['pod'])"

        if repro.COMPAT_SHARD_MAP:
            npod = active_mesh().shape["pod"]

            def split(x):
                return x.reshape((npod, x.shape[0] // npod) + x.shape[1:])

            per_pod = jax.tree.map(split, batch)
            gs, ms = jax.lax.map(lambda mb: grad_fn(state.params, mb),
                                 per_pod)
            g, new_res = compressed_mean_stacked_tree(gs, state.ef)
            m = jax.tree.map(lambda v: jnp.mean(v, axis=0), ms)
            return g, m, new_res

        def body(params, batch_loc, residuals):
            from repro.models.sharding import constrain
            g, m = grad_fn(params, batch_loc)
            res_in = jax.tree.map(lambda r: r[0], residuals)
            g, new_res = compressed_pmean_tree(g, "pod", res_in)
            if param_axes is not None:
                g = jax.tree.map(lambda leaf, ax: constrain(leaf, *ax),
                                 g, param_axes)
            m = jax.tree.map(lambda v: jax.lax.pmean(v, "pod"), m)
            return g, m, jax.tree.map(lambda r: r[None], new_res)

        return jax.shard_map(
            body, mesh=active_mesh(),
            in_specs=(PS(), jax.tree.map(lambda _: PS("pod"), batch),
                      PS("pod")),
            out_specs=(PS(), PS(), PS("pod")),
            axis_names={"pod"},
            # scan carries inside the model start as pod-invariant constants;
            # vma tracking would require pcast at every scan init — the
            # gathered-mean output is replicated by construction instead
            check_vma=False,
        )(state.params, batch, state.ef)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        new_ef = state.ef
        if tcfg.compress_pod_grads and od == 1:
            grads, metrics, new_ef = _compressed_grads(state, batch)
        elif od == 1:
            grads, metrics = grad_fn(state.params, batch)
        else:
            def split(x):
                return x.reshape((od, x.shape[0] // od) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, met = carry
                g, m = grad_fn(state.params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                met = jax.tree.map(lambda a, b: a + b, met, m)
                return (acc, met), None

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            met0 = {"ce": jnp.zeros((), jnp.float32),
                    "aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = jax.lax.scan(body, (acc0, met0), micro)
            grads = jax.tree.map(lambda g: g / od, grads)
            metrics = jax.tree.map(lambda m: m / od, metrics)
        new_state, opt_metrics = adamw_update(tcfg.opt, state, grads)
        new_state = dataclasses.replace(new_state, ef=new_ef)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = metrics["ce"] + metrics["aux"]
        return new_state, metrics

    return train_step


def runtime_allreduce(group, grad_trees, average: bool = True):
    """Gradient sync over the message-driven runtime (ROADMAP item 2).

    ``grad_trees`` is one gradient pytree per group member (identical
    treedef/leaf shapes — each member's local gradients). Leaves are
    flattened and concatenated into one vector per member so a single
    collective moves the whole gradient set — large models take the
    pipelined chunked ring, small ones the eager binomial tree — then the
    summed (or averaged) vector is split back into the original pytree
    structure. Bit-deterministic: every member unflattens the *same*
    reduced vector, so replicas agree exactly.

    Returns one reduced pytree per member, in group-member order.
    """
    import numpy as np

    if len(grad_trees) != len(group.members):
        raise ValueError(
            f"expected {len(group.members)} gradient trees, "
            f"got {len(grad_trees)}")
    leaves0, treedef = jax.tree.flatten(grad_trees[0])
    shapes = [np.asarray(leaf).shape for leaf in leaves0]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    packed = []
    for tree in grad_trees:
        leaves = jax.tree.flatten(tree)[0]
        if len(leaves) != len(leaves0):
            raise ValueError("gradient trees disagree on structure")
        packed.append(np.concatenate(
            [np.asarray(leaf).reshape(-1) for leaf in leaves]))
    reduced = group.allreduce(packed, average=average)
    outs = []
    for vec in reduced:
        leaves, off = [], 0
        for shape, size in zip(shapes, sizes):
            leaves.append(vec[off:off + size].reshape(shape))
            off += size
        outs.append(jax.tree.unflatten(treedef, leaves))
    return outs


def init_train_state(model: Model, key, ef_pods: int = 0) -> TrainState:
    from repro.models.layers import unbox
    params, _ = unbox(model.init(key))
    ef = None
    if ef_pods:
        ef = jax.tree.map(
            lambda p: jnp.zeros((ef_pods,) + p.shape, jnp.float32), params)
    return TrainState(params=params, opt=init_opt_state(params), ef=ef)


def abstract_train_state(model: Model) -> TrainState:
    """TrainState of ShapeDtypeStructs — for AOT lowering (dry-run)."""
    def go():
        from repro.models.layers import unbox
        params, _ = unbox(model.init(jax.random.PRNGKey(0)))
        return TrainState(params=params, opt=init_opt_state(params))
    return jax.eval_shape(go)
