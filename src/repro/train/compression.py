"""Cross-pod gradient compression (distributed-optimization trick).

At pod scale the inter-pod links are the slowest hop, and the gradient
all-reduce across pods is the traffic that rides them. We compress exactly
that hop: int8 block-quantized payloads are all-gathered over the ``pod``
axis and averaged after dequantization, with error-feedback residuals so
the quantization error re-enters the next step's gradients (EF-style —
preserves convergence). Inter-pod gradient bytes drop ≈8× vs an f32
ring all-reduce (int8 payload + one f32 scale per 256-block vs 2× f32).

Integration: the gradient computation runs inside a ``shard_map`` that is
*manual only over the pod axis*; data/model axes stay automatic so GSPMD
still handles in-pod reductions. See ``train_step.make_train_step`` with
``TrainConfig(compress_pod_grads=True)``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array, int]:
    """Symmetric int8 quantization, blocked along the LAST axis only —
    leading dims keep their GSPMD sharding (flattening would force XLA to
    all-gather model-sharded gradients before quantizing).
    Returns (q int8 [..., n_blocks, BLOCK], scales f32 [..., n_blocks], pad).
    """
    if x.ndim == 0:
        x = x[None]
    last = x.shape[-1]
    pad = (-last) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)]).astype(jnp.float32)
    blocks = xp.reshape(x.shape[:-1] + (-1, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / safe[..., None]), -127,
                 127).astype(jnp.int8)
    return q, scale, pad


def dequantize_int8(q: jax.Array, scale: jax.Array, shape,
                    dtype) -> jax.Array:
    deq = q.astype(jnp.float32) * scale[..., None]
    lead = q.shape[:-2]
    flat_last = deq.reshape(lead + (-1,))
    last = shape[-1] if shape else 1
    out = flat_last[..., :last]
    return out.reshape(shape).astype(dtype)


def compressed_pmean(x: jax.Array, axis_name: str,
                     residual: Optional[jax.Array] = None
                     ) -> Tuple[jax.Array, jax.Array]:
    """Quantized mean-reduce over a (manual) mesh axis with error feedback.

    Returns (mean over axis of x, new local residual). The communicated
    payload is the int8 blocks + f32 block scales (all-gather), then the
    mean is reconstructed locally — the compressible formulation of an
    all-reduce."""
    import repro
    orig_shape = x.shape
    if x.ndim == 0:
        x = x[None]
    n = jax.lax.axis_size(axis_name)
    xin = x.astype(jnp.float32)
    if residual is not None:
        xin = xin + residual.reshape(x.shape)
    q, scale, _ = quantize_int8(xin)
    local_deq = dequantize_int8(q, scale, x.shape, jnp.float32)
    new_residual = (xin - local_deq).reshape(orig_shape)
    if not repro.COMPAT_SHARD_MAP:
        # native shard_map: communicate the actual compressed payload —
        # int8 blocks + f32 block scales — and reconstruct the mean locally
        qg = jax.lax.all_gather(q, axis_name)    # [n, ..., blocks, BLOCK] i8
        sg = jax.lax.all_gather(scale, axis_name)   # [n, ..., blocks]
        total = jnp.sum(qg.astype(jnp.float32) * sg[..., None], axis=0)
        deq_total = total.reshape(q.shape[:-2] + (-1,))[..., :x.shape[-1]]
        mean = (deq_total.reshape(orig_shape) / n).astype(x.dtype)
    else:
        # old jax crashes on all_gather inside a partially-manual region
        # (XLA spmd_partitioner IsManualSubgroup check); psum the locally
        # dequantized payload instead — Σ_r q_r·s_r, bit-for-bit the same
        # numerics (and the same error-feedback residual), just without the
        # wire-format compression this in-process emulation cannot measure
        # anyway
        total = jax.lax.psum(local_deq, axis_name)
        mean = (total.reshape(orig_shape) / n).astype(x.dtype)
    return mean, new_residual


def compressed_mean_stacked(x: jax.Array, residual: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """``compressed_pmean`` over a *stacked* leading axis instead of a mesh
    axis: ``x``/``residual`` are [n_pods, ...] and each pod's slice is
    quantized independently (blocked along the last axis, exactly as the
    distributed formulation does per rank). Returns (mean over pods, new
    stacked residuals). Used by the compat path of the compressed-gradient
    train step, where old jax cannot compile a pod-manual shard_map."""
    scalar = x.ndim == 1                 # per-pod scalars: [n] → [n, 1]
    if scalar:
        x = x[:, None]
        residual = residual[:, None]
    n = x.shape[0]
    xin = x.astype(jnp.float32) + residual
    q, scale, _ = quantize_int8(xin)
    local_deq = dequantize_int8(q, scale, xin.shape, jnp.float32)
    new_residual = xin - local_deq
    mean = (jnp.sum(local_deq, axis=0) / n).astype(x.dtype)
    if scalar:
        mean = mean[0]
        new_residual = new_residual[:, 0]
    return mean, new_residual


def compressed_mean_stacked_tree(grads, residuals):
    """Tree-wide ``compressed_mean_stacked``: grads/residuals are trees of
    [n_pods, ...] leaves. Returns (mean grads [...], new residuals)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = jax.tree_util.tree_leaves(residuals)
    outs, news = [], []
    for g, r in zip(leaves, res_leaves):
        m, nr = compressed_mean_stacked(g, r)
        outs.append(m)
        news.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, news))


def compressed_pmean_tree(grads, axis_name: str, residuals=None):
    """Tree-wide compressed_pmean. residuals: matching tree of f32 (or None
    on step 0). Returns (mean grads, new residual tree)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = [None] * len(leaves) if residuals is None else \
        jax.tree_util.tree_leaves(residuals)
    outs, news = [], []
    for g, r in zip(leaves, res_leaves):
        m, nr = compressed_pmean(g, axis_name, r)
        outs.append(m)
        news.append(nr)
    return (jax.tree_util.tree_unflatten(treedef, outs),
            jax.tree_util.tree_unflatten(treedef, news))
