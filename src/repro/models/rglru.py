"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Temporal mixing block: two input branches (GeLU gate branch; conv1d + RG-LRU
branch), merged multiplicatively, projected back. The RG-LRU recurrence

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t)

is a linear recurrence in h, evaluated with ``jax.lax.associative_scan``
(log-depth) for train/prefill and a single-step update for decode. The
recurrence/input gates use block-diagonal projections (n_blocks heads) as in
the paper.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RGLRUConfig
from repro.models import layers as L
from repro.models.sharding import constrain

_C = 8.0


def rglru_init(key, d_model: int, rcfg: RGLRUConfig, n_blocks: int,
               dtype=jnp.bfloat16) -> Dict:
    w = rcfg.lru_width or d_model
    bd = w // n_blocks
    ks = jax.random.split(key, 7)
    # a initialised so that a^c in [0.9, 0.999] over channels
    lam = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, w, dtype=jnp.float32)) / _C))
    return {
        "in_x": L.dense_init(ks[0], d_model, w, ("embed", "lru"), dtype),
        "in_gate": L.dense_init(ks[1], d_model, w, ("embed", "lru"), dtype),
        "conv_w": L.Boxed(
            (jax.random.normal(ks[2], (rcfg.conv_width, w), jnp.float32)
             / np.sqrt(rcfg.conv_width)).astype(dtype), ("conv", "lru")),
        "conv_b": L.Boxed(jnp.zeros((w,), dtype), ("lru",)),
        "w_r": L.Boxed(
            (jax.random.normal(ks[3], (n_blocks, bd, bd), jnp.float32)
             / np.sqrt(bd)).astype(dtype), (None, "lru", None)),
        "b_r": L.Boxed(jnp.zeros((w,), jnp.float32), ("lru",)),
        "w_i": L.Boxed(
            (jax.random.normal(ks[4], (n_blocks, bd, bd), jnp.float32)
             / np.sqrt(bd)).astype(dtype), (None, "lru", None)),
        "b_i": L.Boxed(jnp.zeros((w,), jnp.float32), ("lru",)),
        "lam": L.Boxed(lam, ("lru",)),
        "out": L.dense_init(ks[5], w, d_model, ("lru", "embed"), dtype),
    }


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [B,S,W]; w: [H, W/H, W/H] block-diagonal projection."""
    b, s, width = x.shape
    h, bd, _ = w.shape
    xr = x.reshape(b, s, h, bd)
    return jnp.einsum("bshi,hij->bshj", xr, w).reshape(b, s, width)


def _gates(params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Returns (log_a [B,S,W] fp32, gated_input [B,S,W] fp32)."""
    r = jax.nn.sigmoid(_block_diag(x, params["w_r"]).astype(jnp.float32)
                       + params["b_r"])
    i = jax.nn.sigmoid(_block_diag(x, params["w_i"]).astype(jnp.float32)
                       + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r          # <= 0
    gated = i * x.astype(jnp.float32)
    return log_a, gated


def rglru_layer(params, u: jax.Array, *, rcfg: RGLRUConfig, mode: str,
                cache: Optional[Dict[str, jax.Array]] = None
                ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """u: [B,S,D]. cache: {"conv": [B,W-1,lru], "state": [B,lru] fp32}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", u, params["in_gate"]))
    x = jnp.einsum("bsd,dw->bsw", u, params["in_x"])
    x = constrain(x, "act_batch", "act_seq", "act_mlp")

    # causal depthwise conv
    width = params["conv_w"].shape[0]
    conv_state = cache["conv"] if cache is not None else None
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)
    x = sum(xp[:, i:i + x.shape[1]] * params["conv_w"][i]
            for i in range(width)) + params["conv_b"]
    new_conv = xp[:, xp.shape[1] - (width - 1):]

    log_a, gated = _gates(params, x)
    a = jnp.exp(log_a)
    b_term = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    if mode in ("train", "prefill"):
        h0 = cache["state"].astype(jnp.float32) if cache is not None else None

        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, a2 * b1 + b2

        if h0 is not None:
            b_term = b_term.at[:, 0].add(a[:, 0] * h0)
        ah, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
        new_cache = {"conv": new_conv, "state": h[:, -1]} \
            if mode == "prefill" else None
    elif mode == "decode":
        assert cache is not None
        h_prev = cache["state"].astype(jnp.float32)               # [B,W]
        h = a[:, 0] * h_prev + b_term[:, 0]
        h = h[:, None]
        new_cache = {"conv": new_conv, "state": h[:, -1]}
    else:
        raise ValueError(mode)

    y = h.astype(u.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, params["out"])
    return constrain(out, "act_batch", "act_seq", "act_embed"), new_cache


def init_rglru_cache(batch: int, d_model: int, rcfg: RGLRUConfig,
                     dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    w = rcfg.lru_width or d_model
    return {
        "conv": jnp.zeros((batch, rcfg.conv_width - 1, w), dtype),
        "state": jnp.zeros((batch, w), jnp.float32),
    }
