"""Logical-axis sharding: MaxText-style rules mapping logical axis names to
mesh axes, with graceful no-op behaviour when no mesh is active (CPU smoke
tests) and divisibility-aware fallback (e.g. kv_heads=1 cannot shard 16-way).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default logical→mesh rules. ``data``-like axes map to all data-parallel mesh
# axes; ``model``-like axes to the tensor-parallel axis. The optimized
# configuration adds sequence parallelism by mapping ``act_seq`` → model.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # parameter axes
    "vocab": "model",
    "embed": None,
    "mlp": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",
    "expert_mlp": None,
    "lru": "model",
    # SSD inner dims stay replicated: the fused in_proj mixes z/x/B/C/dt
    # channel groups, and mamba2-370m is small enough that pure DP is the
    # realistic deployment (see DESIGN §Arch-applicability).
    "ssm_inner": None,
    "ssm_state": None,
    "conv": None,
    "layers": None,           # stacked-scan leading axis, never sharded
    # optimizer state extra sharding (ZeRO-1): applied in train/optimizer
    "zero": "data",
    # activation axes
    "act_batch": ("pod", "data"),
    "act_seq": None,          # → "model" when sequence parallelism enabled
    "act_kv_seq": None,       # KV-cache seq axis; → "data" for long-context
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    "act_vocab": "model",
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, MeshAxes] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
    """Activate a mesh + logical rules for model construction/lowering."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _CTX.rules = merged
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def resolve_spec(logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None,
                 mesh: Optional[Mesh] = None) -> PS:
    """Map logical axis names to a PartitionSpec under the active rules.

    If ``shape`` is given, drops sharding on any dim not divisible by its mesh
    axis size (e.g. kv_heads=4 over a 16-way model axis → replicated).
    """
    mesh = mesh or _CTX.mesh
    parts = []
    used: set = set()
    for i, name in enumerate(logical_axes):
        axes = _CTX.rules.get(name) if name else None
        if axes is not None and mesh is not None:
            present = tuple(a for a in ((axes,) if isinstance(axes, str) else axes)
                            if a in mesh.shape and a not in used)
            axes = present if present else None
            if axes is not None and shape is not None:
                if shape[i] % _axis_size(mesh, axes) != 0:
                    axes = None
            if axes is not None:
                used.update(axes)
        elif mesh is None:
            axes = None
        if axes is None:
            parts.append(None)
        elif isinstance(axes, tuple) and len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(axes)
    while parts and parts[-1] is None:
        parts.pop()
    return PS(*parts)


def _manual_axes() -> set:
    """Mesh axes currently in Manual (shard_map) mode — constraints must not
    mention them (e.g. the compressed-gradient pod-manual region)."""
    try:
        am = jax.sharding.get_abstract_mesh()
        return {n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)}
    except Exception:
        # old jax: no abstract mesh — the compat shard_map shim records the
        # manual axes in a thread-local while the body traces
        import repro
        return set(repro.compat_manual_axes())


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = resolve_spec(logical_axes, shape=x.shape, mesh=mesh)
    manual = _manual_axes()
    if manual:
        # old jax cannot apply constraints inside a partially-manual region
        # at all (XLA trips an IsManualSubgroup check); constraints are
        # advisory, so drop them there and let GSPMD pick layouts
        if not hasattr(jax.sharding, "get_abstract_mesh"):
            return x
        parts = []
        for p in spec:
            if p is None:
                parts.append(None)
                continue
            ax = tuple(a for a in ((p,) if isinstance(p, str) else p)
                       if a not in manual)
            parts.append(ax[0] if len(ax) == 1 else (ax or None))
        spec = PS(*parts)
        # the constraint must carry the abstract mesh, whose axis types mark
        # the manual axes
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(jax.sharding.get_abstract_mesh(), spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *logical_axes: Optional[str],
                   shape: Optional[Sequence[int]] = None) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(logical_axes, shape=shape, mesh=mesh))
