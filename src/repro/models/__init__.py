from repro.models.model_zoo import Model, build_model, build_smoke  # noqa: F401
from repro.models.transformer import DEFAULT_FLAGS, Flags, SMOKE_FLAGS  # noqa: F401
