"""Decoder-only LM assembly with scan-over-period-blocks.

The layer stack is described by ``cfg.layer_pattern`` (a repeating "period",
e.g. 5×local_attn + 1×global_attn for gemma3). Parameters for the repeated
periods are stacked along a leading ``layers`` axis and the stack is executed
with ``jax.lax.scan`` — this keeps the HLO size O(period) instead of
O(n_layers), which matters both for compile time and for remat policy
uniformity. Remainder layers (n_layers % period) are unrolled at the top of
the stack.

Caches (KV / conv / recurrent state) are threaded through the scan as
per-period xs/ys with the same stacking.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GLOBAL_ATTN, LOCAL_ATTN, RGLRU, SSD, ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.sharding import constrain


@dataclasses.dataclass(frozen=True)
class Flags:
    """Runtime/lowering flags — the optimization levers for §Perf."""
    remat: str = "dots"              # none | full | dots
    moe_mode: str = "ep"             # ep | dense
    seq_shard_kv: Optional[str] = None   # mesh axis for seq-sharded decode KV
    scan_layers: bool = True
    param_dtype: Any = jnp.bfloat16
    loss_chunk: int = 1024           # seq chunk for the CE loss
    flash_block: int = 512
    use_pallas_flash: bool = False   # Pallas kernel for global attention
                                     # (TPU; interpret=True off-TPU)


DEFAULT_FLAGS = Flags()
SMOKE_FLAGS = Flags(remat="none", moe_mode="dense", scan_layers=True,
                    param_dtype=jnp.float32, loss_chunk=64, flash_block=128)


# ---------------------------------------------------------------------------
# Per-layer block = temporal mixer + (MLP | MoE), pre-norm residual
# ---------------------------------------------------------------------------

def _is_moe_layer(cfg: ModelConfig, kind: str) -> bool:
    return cfg.moe is not None and kind in (GLOBAL_ATTN, LOCAL_ATTN) \
        and cfg.moe.interleave == 1


def block_init(key, cfg: ModelConfig, kind: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"norm1": L.scale_init(cfg.d_model)}
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        p["attn"] = A.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
    elif kind == SSD:
        p["ssd"] = S.ssd_init(ks[0], cfg.d_model, cfg.ssm, dtype)
    elif kind == RGLRU:
        p["rglru"] = R.rglru_init(ks[0], cfg.d_model, cfg.rglru,
                                  cfg.n_heads, dtype)
    else:
        raise ValueError(kind)
    if kind == SSD:
        return p  # mamba2 blocks have no separate MLP
    p["norm2"] = L.scale_init(cfg.d_model)
    if _is_moe_layer(cfg, kind):
        p["moe"] = M.moe_init(ks[1], cfg.d_model, cfg.moe, cfg.gated_mlp, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def block_apply(p, x: jax.Array, *, cfg: ModelConfig, kind: str, mode: str,
                flags: Flags, cache: Optional[Dict] = None,
                lengths: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind in (GLOBAL_ATTN, LOCAL_ATTN):
        mix, new_cache = A.attention_layer(
            p["attn"], h, kind=kind, window=cfg.window,
            rope_theta=cfg.rope_theta, n_kv_heads=cfg.n_kv_heads, mode=mode,
            lengths=lengths, cache=cache,
            seq_shard_axis=flags.seq_shard_kv,
            use_pallas=flags.use_pallas_flash)
    elif kind == SSD:
        mix, new_cache = S.ssd_layer(p["ssd"], h, scfg=cfg.ssm, mode=mode,
                                     cache=cache)
    elif kind == RGLRU:
        mix, new_cache = R.rglru_layer(p["rglru"], h, rcfg=cfg.rglru,
                                       mode=mode, cache=cache)
    else:
        raise ValueError(kind)
    x = x + mix
    if kind == SSD:
        return x, new_cache, aux
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    if "moe" in p:
        if flags.moe_mode == "ep":
            y, aux = M.moe_ep(p["moe"], h, cfg.moe, cfg.gated_mlp)
        else:
            y, aux = M.moe_dense(p["moe"], h, cfg.moe, cfg.gated_mlp)
    else:
        y = L.mlp_apply(p["mlp"], h, cfg.gated_mlp)
    return x + y, new_cache, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     dtype) -> Optional[Dict]:
    if kind == GLOBAL_ATTN:
        return A.init_attn_cache(batch, cache_len, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype)
    if kind == LOCAL_ATTN:
        return A.init_attn_cache(batch, min(cfg.window, cache_len),
                                 cfg.n_kv_heads, cfg.resolved_head_dim, dtype)
    if kind == SSD:
        return S.init_ssd_cache(batch, cfg.d_model, cfg.ssm, dtype)
    if kind == RGLRU:
        return R.init_rglru_cache(batch, cfg.d_model, cfg.rglru, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Whole-model init / apply
# ---------------------------------------------------------------------------

def _period_layout(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    period = len(cfg.layer_pattern)
    n_periods = cfg.n_layers // period
    remainder = tuple(cfg.layer_pattern[:cfg.n_layers % period])
    return n_periods, remainder


def lm_init(key, cfg: ModelConfig, flags: Flags = DEFAULT_FLAGS):
    dtype = flags.param_dtype
    n_periods, remainder = _period_layout(cfg)
    keys = jax.random.split(key, 4 + len(remainder))
    params: Dict[str, Any] = {
        "embed": L.embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": L.scale_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab,
                                         ("embed", "vocab"), dtype)

    def one_period(k):
        ks = jax.random.split(k, len(cfg.layer_pattern))
        return tuple(block_init(ki, cfg, kind, dtype)
                     for ki, kind in zip(ks, cfg.layer_pattern))

    if n_periods:
        pkeys = jax.random.split(keys[2], n_periods)
        stacked = jax.vmap(one_period)(pkeys)
        # prepend the stacking axis to every leaf's logical axes
        stacked = jax.tree.map(
            lambda b: L.Boxed(b.value, ("layers",) + tuple(b.axes)),
            stacked, is_leaf=lambda x: isinstance(x, L.Boxed))
        params["periods"] = stacked
    for i, kind in enumerate(remainder):
        params[f"rem_{i}"] = block_init(keys[4 + i], cfg, kind, dtype)
    return params


def lm_init_cache(cfg: ModelConfig, batch: int, cache_len: int,
                  flags: Flags = DEFAULT_FLAGS):
    dtype = flags.param_dtype
    n_periods, remainder = _period_layout(cfg)
    cache: Dict[str, Any] = {}
    if n_periods:
        def one_period(_):
            return tuple(init_block_cache(cfg, kind, batch, cache_len, dtype)
                         for kind in cfg.layer_pattern)
        cache["periods"] = jax.vmap(one_period)(jnp.arange(n_periods))
    for i, kind in enumerate(remainder):
        cache[f"rem_{i}"] = init_block_cache(cfg, kind, batch, cache_len, dtype)
    return cache


def _embed_inputs(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
                  flags: Flags) -> jax.Array:
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision" and "vision_embeds" in batch:
        ve = batch["vision_embeds"].astype(x.dtype)       # [B, n_tok, D]
        x = jax.lax.dynamic_update_slice(x, ve, (0, 0, 0))
    x = constrain(x, "act_batch", "act_seq", "act_embed")
    return x


def lm_apply(params, batch: Dict[str, jax.Array], *, cfg: ModelConfig,
             mode: str, flags: Flags = DEFAULT_FLAGS,
             cache: Optional[Dict] = None
             ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (final hidden [B,S,D], new_cache, aux_loss). The unembedding
    is applied by the caller (train uses a chunked fused CE; serve samples)."""
    n_periods, remainder = _period_layout(cfg)
    lengths = batch.get("lengths")
    x = _embed_inputs(params, cfg, batch, flags)
    aux_total = jnp.zeros((), jnp.float32)

    def period_body(x, period_params, period_cache):
        aux_p = jnp.zeros((), jnp.float32)
        new_caches: List[Any] = []
        for j, kind in enumerate(cfg.layer_pattern):
            c_in = period_cache[j] if period_cache is not None else None
            x, c_out, aux = block_apply(
                period_params[j], x, cfg=cfg, kind=kind, mode=mode,
                flags=flags, cache=c_in, lengths=lengths)
            new_caches.append(c_out)
            aux_p = aux_p + aux
        return x, tuple(new_caches), aux_p

    if flags.remat == "full":
        period_body = jax.checkpoint(period_body)
    elif flags.remat == "dots":
        period_body = jax.checkpoint(
            period_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    if n_periods:
        if mode == "train":
            def scan_fn(carry, pp):
                x, aux = carry
                x, _, aux_p = period_body(x, pp, None)
                return (x, aux + aux_p), None
            (x, aux_total), _ = jax.lax.scan(
                scan_fn, (x, aux_total), params["periods"])
            new_cache = None
        else:
            def scan_fn(carry, inp):
                x, aux = carry
                pp, pc = inp
                x, new_c, aux_p = period_body(x, pp, pc)
                return (x, aux + aux_p), new_c
            (x, aux_total), new_period_cache = jax.lax.scan(
                scan_fn, (x, aux_total), (params["periods"], cache["periods"]))
            new_cache = {"periods": new_period_cache}
    else:
        new_cache = {} if mode != "train" else None

    for i, kind in enumerate(remainder):
        c_in = cache.get(f"rem_{i}") if cache is not None else None
        x, c_out, aux = block_apply(params[f"rem_{i}"], x, cfg=cfg, kind=kind,
                                    mode=mode, flags=flags, cache=c_in,
                                    lengths=lengths)
        aux_total = aux_total + aux
        if new_cache is not None:
            new_cache[f"rem_{i}"] = c_out

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, aux_total


def unembed(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Logits for a (small) x — decode path. [B,S,D] -> [B,S,V]."""
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return constrain(logits, "act_batch", None, "act_vocab")


def chunked_ce_loss(params, x: jax.Array, labels: jax.Array,
                    cfg: ModelConfig, flags: Flags) -> jax.Array:
    """Cross-entropy without materializing [B,S,V]: scan over seq chunks,
    vocab-sharded logsumexp. x: [B,S,D], labels: [B,S]."""
    b, s, d = x.shape
    chunk = min(flags.loss_chunk, s)
    assert s % chunk == 0
    n = s // chunk
    xc = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]

    def body(total, inp):
        xb, lb = inp                                   # [B,chunk,D], [B,chunk]
        logits = jnp.einsum("btd,dv->btv", xb, w).astype(jnp.float32)
        logits = constrain(logits, "act_batch", None, "act_vocab")
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        ll = jnp.sum(jnp.where(iota == lb[..., None], logits, 0.0), axis=-1)
        return total + jnp.sum(logz - ll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
    return total / (b * s)
