"""Unified model facade: build any assigned architecture from its config.

``Model`` exposes:
  init(key)                -> boxed param tree (use layers.unbox)
  apply(params, batch, mode, cache) -> (hidden, new_cache, aux_loss)
  init_cache(batch, cache_len)      -> cache pytree
  input_specs(shape)       -> dict of ShapeDtypeStructs for the dry-run
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.transformer import DEFAULT_FLAGS, Flags, SMOKE_FLAGS


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    flags: Flags = DEFAULT_FLAGS

    def init(self, key):
        if self.cfg.enc_dec:
            return ED.encdec_init(key, self.cfg, self.flags)
        return T.lm_init(key, self.cfg, self.flags)

    def init_abstract(self):
        """Boxed tree of ShapeDtypeStructs — no host allocation (dry-run)."""
        from repro.models.layers import Boxed

        def go():
            return self.init(jax.random.PRNGKey(0))
        shapes = jax.eval_shape(go)
        # eval_shape maps Boxed dataclass leaves transparently? Boxed is not a
        # pytree node, so instead: run init under eval_shape via closure that
        # unboxes, and rebuild axes from a cheap tiny init. Handled in
        # launch.dryrun via lm_abstract().
        return shapes

    def apply(self, params, batch: Dict[str, jax.Array], *, mode: str,
              cache: Optional[Dict] = None):
        if self.cfg.enc_dec:
            return ED.encdec_apply(params, batch, cfg=self.cfg, mode=mode,
                                   flags=self.flags, cache=cache)
        return T.lm_apply(params, batch, cfg=self.cfg, mode=mode,
                          flags=self.flags, cache=cache)

    def init_cache(self, batch: int, cache_len: int):
        if self.cfg.enc_dec:
            return ED.encdec_init_cache(self.cfg, batch, cache_len, self.flags)
        return T.lm_init_cache(self.cfg, batch, cache_len, self.flags)

    def unembed(self, params, x):
        if self.cfg.enc_dec:
            return jnp.einsum("bsd,dv->bsv", x, params["unembed"])
        return T.unembed(params, x, self.cfg)

    def loss(self, params, x, labels):
        if self.cfg.enc_dec:
            w = params["unembed"]
            logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
            from repro.models.layers import softmax_cross_entropy
            return softmax_cross_entropy(logits, labels)
        return T.chunked_ce_loss(params, x, labels, self.cfg, self.flags)

    # ------------------------------------------------------------------
    # Input specs (ShapeDtypeStruct stand-ins — never allocate)
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if shape.kind == "train":
            specs = {
                "tokens": sds((b, s), i32),
                "labels": sds((b, s), i32),
            }
        elif shape.kind == "prefill":
            specs = {"tokens": sds((b, s), i32)}
        else:  # decode: one new token against a cache of length s
            specs = {
                "tokens": sds((b, 1), i32),
                "lengths": sds((b,), i32),
            }
        if cfg.frontend == "vision" and shape.kind != "decode":
            specs["vision_embeds"] = sds(
                (b, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.enc_dec and shape.kind != "decode":
            specs["frames"] = sds((b, cfg.encoder_seq, cfg.d_model),
                                  jnp.bfloat16)
        return specs


def build_model(cfg: ModelConfig, flags: Flags = DEFAULT_FLAGS) -> Model:
    return Model(cfg, flags)


def build_smoke(cfg: ModelConfig, **overrides) -> Model:
    flags = dataclasses.replace(SMOKE_FLAGS, **overrides)
    return Model(cfg, flags)
