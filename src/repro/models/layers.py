"""Core neural-net building blocks (pure JAX, no flax).

Parameter initializers return pytrees whose leaves are ``Boxed`` values
carrying both the array and its *logical* sharding axes. ``unbox`` splits the
tree into (params, logical_axes) so the launch layer can resolve real
``NamedSharding``s while smoke tests simply discard the axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain


@dataclasses.dataclass
class Boxed:
    value: Any
    axes: Tuple[Optional[str], ...]


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), tuple(b.axes)),
    lambda axes, children: Boxed(children[0], axes),
)


def _is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    params = jax.tree.map(lambda b: b.value, tree, is_leaf=_is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_boxed)
    return params, axes


def boxed_abstract(tree):
    """Like unbox but maps values to ShapeDtypeStructs (no allocation)."""
    params = jax.tree.map(
        lambda b: jax.ShapeDtypeStruct(b.value.shape, b.value.dtype), tree,
        is_leaf=_is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_boxed)
    return params, axes


# ---------------------------------------------------------------------------
# Initializers. For AOT dry-runs we must never materialize 27B parameters on
# the host, so inits can run in "abstract" mode producing ShapeDtypeStruct
# leaves (via jax.eval_shape at the model level).
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, axes, dtype=jnp.bfloat16,
               scale: Optional[float] = None) -> Boxed:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    w = jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32) * scale
    return Boxed(w.astype(dtype), axes)


def embed_init(key, vocab: int, dim: int, dtype=jnp.bfloat16) -> Boxed:
    w = jax.random.normal(key, (vocab, dim), dtype=jnp.float32) * 0.02
    return Boxed(w.astype(dtype), ("vocab", "embed"))


def scale_init(dim: int, axes=("embed",), dtype=jnp.float32, value=1.0) -> Boxed:
    return Boxed(jnp.full((dim,), value, dtype=dtype), axes)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                   # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs   # [..., seq, hd/2]
    sin = jnp.sin(angles)[..., None, :]                         # add heads dim
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d_model, d_ff, ("embed", "mlp"), dtype),
        "wo": dense_init(ks[1], d_ff, d_model, ("mlp", "embed"), dtype),
    }
    if gated:
        p["wg"] = dense_init(ks[2], d_model, d_ff, ("embed", "mlp"), dtype)
    return p


def mlp_apply(p, x: jax.Array, gated: bool) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if gated:
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, "act_batch", "act_seq", "act_mlp")
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jax.Array, labels: jax.Array,
                          mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy; logits [..., V] fp32-safe."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
