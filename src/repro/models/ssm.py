"""Mamba-2 SSD (state-space duality) block.

Training/prefill uses the chunked SSD algorithm (intra-chunk quadratic form +
inter-chunk linear recurrence); decode uses the O(1) recurrent state update.
A Pallas kernel for the intra-chunk quadratic form lives in
``repro.kernels.ssd``; this module is the pure-JAX implementation (and the
kernel's oracle).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.models import layers as L
from repro.models.sharding import constrain


def _segsum(x: jax.Array) -> jax.Array:
    """x: [..., q] -> [..., q, q] lower-triangular inclusive segment sums:
    out[..., i, j] = sum_{k=j+1..i} x[..., k] (NEG_INF above diagonal)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_init(key, d_model: int, scfg: SSMConfig, dtype=jnp.bfloat16) -> Dict:
    di = scfg.expand * d_model
    nh = di // scfg.headdim
    gn = scfg.ngroups * scfg.d_state
    ks = jax.random.split(key, 5)
    conv_ch = di + 2 * gn
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": L.dense_init(ks[0], d_model, 2 * di + 2 * gn + nh,
                                ("embed", "ssm_inner"), dtype),
        "conv_w": L.Boxed(
            (jax.random.normal(ks[1], (scfg.conv_width, conv_ch), jnp.float32)
             / np.sqrt(scfg.conv_width)).astype(dtype), ("conv", "ssm_inner")),
        "conv_b": L.Boxed(jnp.zeros((conv_ch,), dtype), ("ssm_inner",)),
        "A_log": L.Boxed(
            jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)), (None,)),
        "D": L.Boxed(jnp.ones((nh,), jnp.float32), (None,)),
        "dt_bias": L.Boxed(
            jnp.log(jnp.expm1(jnp.full((nh,), 0.01, jnp.float32))), (None,)),
        "norm": L.scale_init(di, ("ssm_inner",)),
        "out_proj": L.dense_init(ks[2], di, d_model, ("ssm_inner", "embed"), dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: [B,S,C]; w: [W,C]. Returns (y, new_state)
    where state is the last W-1 inputs [B,W-1,C]."""
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width)) + b
    new_state = xp[:, xp.shape[1] - (width - 1):]
    return jax.nn.silu(y), new_state


def _ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, chunk: int,
                 init_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. x: [b,s,h,p]; dt: [b,s,h]; A: [h]; B,C: [b,s,g,n] with g
    broadcastable to h. Returns (y [b,s,h,p], final_state [b,h,p,n])."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        # dt=0 on padding → decay 1, zero input: state passes through unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    c = s // q
    rep = h // g

    xr = x.reshape(b, c, q, h, p)
    dtr = dt.reshape(b, c, q, h)
    Br = jnp.repeat(B.reshape(b, c, q, g, n), rep, axis=3)
    Cr = jnp.repeat(C.reshape(b, c, q, g, n), rep, axis=3)

    dA = dtr * A[None, None, None, :]                   # [b,c,q,h] (negative)
    dA_cs = jnp.cumsum(dA, axis=2)                      # [b,c,q,h]
    # intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))   # [b,c,h,q,q]
    xdt = xr * dtr[..., None]
    scores = jnp.einsum("bclhn,bcshn->bchls", Cr, Br) * Lmat
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xdt)
    # chunk states
    decay_states = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # [b,c,q,h]
    states = jnp.einsum("bcshn,bcsh,bcshp->bchpn", Br, decay_states, xdt)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])            # [b,c,h]
    s0 = init_state if init_state is not None else \
        jnp.zeros((b, h, p, n), jnp.float32)

    def step(carry, inp):
        st, dc = inp
        new = carry * dc[:, :, None, None] + st
        return new, carry                                # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)   # [b,c,h,p,n]
    state_decay = jnp.exp(dA_cs)                          # [b,c,q,h]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cr,
                       prev_states.astype(Cr.dtype), state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :s_orig], final


def ssd_layer(params, u: jax.Array, *, scfg: SSMConfig, mode: str,
              cache: Optional[Dict[str, jax.Array]] = None
              ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Full Mamba-2 block. u: [B,S,D]. mode: train|prefill|decode.
    cache: {"conv": [B,W-1,C], "state": [B,H,P,N]} for decode."""
    b, s, d = u.shape
    di = scfg.expand * d
    nh = di // scfg.headdim
    gn = scfg.ngroups * scfg.d_state

    proj = jnp.einsum("bsd,dk->bsk", u, params["in_proj"])
    z, xbc, dt_raw = jnp.split(proj, [di, di + di + 2 * gn], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    x, B, C = jnp.split(xbc, [di, di + gn], axis=-1)
    x = x.reshape(b, s, nh, scfg.headdim)
    B = B.reshape(b, s, scfg.ngroups, scfg.d_state).astype(jnp.float32)
    C = C.reshape(b, s, scfg.ngroups, scfg.d_state).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])       # [b,s,h]
    A = -jnp.exp(params["A_log"])                                  # [h]

    if mode in ("train", "prefill"):
        y, final_state = _ssd_chunked(x.astype(jnp.float32), dt, A, B, C,
                                      scfg.chunk_size)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": new_conv, "state": final_state}
    elif mode == "decode":
        assert cache is not None
        st = cache["state"].astype(jnp.float32)                    # [b,h,p,n]
        rep = nh // scfg.ngroups
        B1 = jnp.repeat(B[:, 0], rep, axis=1)                      # [b,h,n]
        C1 = jnp.repeat(C[:, 0], rep, axis=1)
        dt1 = dt[:, 0]                                             # [b,h]
        dA = jnp.exp(dt1 * A[None, :])                             # [b,h]
        x1 = x[:, 0].astype(jnp.float32)                           # [b,h,p]
        st = st * dA[:, :, None, None] + jnp.einsum(
            "bhp,bhn,bh->bhpn", x1, B1, dt1)
        y = jnp.einsum("bhpn,bhn->bhp", st, C1)[:, None]           # [b,1,h,p]
        new_cache = {"conv": new_conv, "state": st}
        x = x1[:, None]
    else:
        raise ValueError(mode)

    y = y + params["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(u.dtype)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, params["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return constrain(out, "act_batch", "act_seq", "act_embed"), new_cache


def init_ssd_cache(batch: int, d_model: int, scfg: SSMConfig,
                   dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    di = scfg.expand * d_model
    nh = di // scfg.headdim
    gn = scfg.ngroups * scfg.d_state
    return {
        "conv": jnp.zeros((batch, scfg.conv_width - 1, di + 2 * gn), dtype),
        "state": jnp.zeros((batch, nh, scfg.headdim, scfg.d_state), jnp.float32),
    }
