"""Attention: GQA projections + three execution paths.

- ``flash_attention``: chunked online-softmax attention (pure JAX scan over KV
  blocks). Memory-bounded: never materializes the full [S, S] score matrix —
  this is the TPU-native adaptation of a fused attention kernel and is what
  the compiled dry-run exercises. A Pallas kernel with the same contract
  lives in ``repro.kernels.flash_attention``.
- ``window_attention``: exact sliding-window attention via block-banded
  computation (each query block attends to itself + previous block).
- ``decode_attention``: single-token attention against a KV cache, with an
  optional sequence-sharded variant (logsumexp partial combine over the
  ``data`` mesh axis) used for 500k-token decode where the cache cannot fit
  on one device row.

Caches for local-attention layers are ring buffers of size ``window``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.sharding import constrain

NEG_INF = -1e30


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              dtype=jnp.bfloat16) -> Dict[str, L.Boxed]:
    ks = jax.random.split(key, 4)
    return {
        "wq": L.Boxed(
            (jax.random.normal(ks[0], (d_model, n_heads, head_dim), jnp.float32)
             / jnp.sqrt(d_model)).astype(dtype),
            ("embed", "heads", "head_dim")),
        "wk": L.Boxed(
            (jax.random.normal(ks[1], (d_model, n_kv_heads, head_dim), jnp.float32)
             / jnp.sqrt(d_model)).astype(dtype),
            ("embed", "kv_heads", "head_dim")),
        "wv": L.Boxed(
            (jax.random.normal(ks[2], (d_model, n_kv_heads, head_dim), jnp.float32)
             / jnp.sqrt(d_model)).astype(dtype),
            ("embed", "kv_heads", "head_dim")),
        "wo": L.Boxed(
            (jax.random.normal(ks[3], (n_heads, head_dim, d_model), jnp.float32)
             / jnp.sqrt(n_heads * head_dim)).astype(dtype),
            ("heads", "head_dim", "embed")),
    }


def _split_gqa(q: jax.Array, n_kv: int) -> jax.Array:
    """[B,S,H,D] -> [B,S,K,G,D]."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


# ---------------------------------------------------------------------------
# Full (causal or bidirectional) chunked attention
# ---------------------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    q_positions: jax.Array, kv_positions: jax.Array,
                    causal: bool = True, q_block: int = 512,
                    kv_block: int = 512,
                    kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """q: [B,S,K,G,D]; k,v: [B,T,K,D]; positions: [S] / [T] (shared across
    batch); kv_valid: optional [T] bool (padding mask). Returns [B,S,K,G,D].

    Online-softmax attention, scanning over q blocks (outer) and kv blocks
    (inner): peak live memory is one [B,qb,K,G,kb] score tile — never the
    full [S,T] matrix. This is the structural analogue of a fused flash
    kernel; the Pallas version shares this contract."""
    b, s, kh, g, d = q.shape
    t = k.shape[1]
    qb = min(q_block, s)
    kb = min(kv_block, t)
    assert s % qb == 0 and t % kb == 0, (s, qb, t, kb)
    nq, nk = s // qb, t // kb
    scale = d ** -0.5

    qr = jnp.moveaxis(q.reshape(b, nq, qb, kh, g, d), 1, 0)     # [nq,b,qb,...]
    kr = jnp.moveaxis(k.reshape(b, nk, kb, kh, d), 1, 0)        # [nk,b,kb,...]
    vr = jnp.moveaxis(v.reshape(b, nk, kb, kh, d), 1, 0)
    qpos = q_positions.reshape(nq, qb)
    kpos = kv_positions.reshape(nk, kb)
    kval = None if kv_valid is None else kv_valid.reshape(nk, kb)

    def q_body(_, q_in):
        qblk, qp = q_in                                          # [b,qb,kh,g,d]

        def kv_body(carry, kv_in):
            acc, m, l = carry
            kblk, vblk, kp, kvld = kv_in
            sc = jnp.einsum("bqkgd,bckd->bqkgc", qblk, kblk,
                            preferred_element_type=jnp.float32) * scale
            mask = None
            if causal:
                mask = kp[None, :] <= qp[:, None]                # [qb,kb]
            if kvld is not None:
                km = jnp.broadcast_to(kvld[None, :], (qb, kb))
                mask = km if mask is None else (mask & km)
            if mask is not None:
                sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqkgc,bckd->bqkgd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            return (acc * alpha[..., None] + pv, m_new, l_new), None

        acc0 = jnp.zeros((b, qb, kh, g, d), jnp.float32)
        m0 = jnp.full((b, qb, kh, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, qb, kh, g), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0),
            (kr, vr, kpos, kval) if kval is not None else (kr, vr, kpos, None))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_body, None, (qr, qpos))             # [nq,b,qb,...]
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, kh, g, d)
    return out


# ---------------------------------------------------------------------------
# Sliding-window attention (exact, block-banded)
# ---------------------------------------------------------------------------

def window_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     positions: jax.Array, window: int) -> jax.Array:
    """Causal attention restricted to the last ``window`` positions.
    q: [B,S,K,G,D], k/v: [B,S,K,D]. Each query block of size W attends to
    (block-1, block) — exact for window size W. Ragged S is padded internally
    (padded keys get +inf positions and are never attended)."""
    b, s, kh, g, d = q.shape
    w = min(window, s)
    pad = (-s) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)) + ((0, 0),) * 3)
        k = jnp.pad(k, ((0, 0), (0, pad)) + ((0, 0),) * 2)
        v = jnp.pad(v, ((0, 0), (0, pad)) + ((0, 0),) * 2)
        positions = jnp.concatenate(
            [positions, jnp.full((pad,), 2**30, jnp.int32)])
    s_orig, s = s, s + pad
    nb = s // w
    scale = d ** -0.5

    qr = q.reshape(b, nb, w, kh, g, d)
    kr = k.reshape(b, nb, w, kh, d)
    vr = v.reshape(b, nb, w, kh, d)
    # previous block (zeros for block 0, masked out by positions)
    kprev = jnp.concatenate([jnp.zeros_like(kr[:, :1]), kr[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vr[:, :1]), vr[:, :-1]], axis=1)
    kcat = jnp.concatenate([kprev, kr], axis=2)        # [b,nb,2w,kh,d]
    vcat = jnp.concatenate([vprev, vr], axis=2)

    pos = positions.reshape(nb, w)
    pprev = jnp.concatenate([jnp.full_like(pos[:1], -10**9), pos[:-1]], axis=0)
    pcat = jnp.concatenate([pprev, pos], axis=1)       # [nb,2w]

    sc = jnp.einsum("bnqkgd,bnckd->bnqkgc", qr, kcat,
                    preferred_element_type=jnp.float32) * scale
    valid = (pcat[:, None, :] <= pos[:, :, None]) & \
            (pos[:, :, None] - pcat[:, None, :] < w)   # [nb,w,2w]
    sc = jnp.where(valid[None, :, :, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bnqkgc,bnckd->bnqkgd", p.astype(vcat.dtype), vcat,
                     preferred_element_type=jnp.float32)
    out = out.reshape(b, s, kh, g, d).astype(q.dtype)
    return out[:, :s_orig]


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     valid: jax.Array) -> jax.Array:
    """q: [B,K,G,D] (single step), cache: [B,T,K,D], valid: [B,T] bool."""
    d = q.shape[-1]
    sc = jnp.einsum("bkgd,btkd->bkgt", q, k_cache,
                    preferred_element_type=jnp.float32) * d ** -0.5
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def decode_attention_partial(q: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, *, valid: jax.Array,
                             axis_name: str) -> jax.Array:
    """Sequence-sharded decode: each shard holds a slice of the KV cache along
    T; partial attention is combined with a logsumexp reduction over
    ``axis_name``. Call inside shard_map. Collective volume: O(B·H·D) per
    shard instead of all-gathering O(B·T·K·D) of cache."""
    d = q.shape[-1]
    sc = jnp.einsum("bkgd,btkd->bkgt", q, k_cache,
                    preferred_element_type=jnp.float32) * d ** -0.5
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    m_loc = jnp.max(sc, axis=-1)                                  # [b,k,g]
    m_glob = jax.lax.pmax(m_loc, axis_name)
    p = jnp.exp(sc - m_glob[..., None])
    l_loc = jnp.sum(p, axis=-1)
    o_loc = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                       preferred_element_type=jnp.float32)
    l_glob = jax.lax.psum(l_loc, axis_name)
    o_glob = jax.lax.psum(o_loc, axis_name)
    out = o_glob / jnp.maximum(l_glob[..., None], 1e-30)
    return out.astype(q.dtype)


def _pallas_flash(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Route [B,S,K,G,D] GQA attention through the Pallas kernel
    ([BH, S, D] contract, heads folded, KV broadcast)."""
    from repro.kernels import ops
    b, s, kh, g, d = q.shape
    qf = q.transpose(0, 2, 3, 1, 4).reshape(b * kh * g, s, d)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kh, g, s, d)).reshape(b * kh * g, s, d)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (b, kh, g, s, d)).reshape(b * kh * g, s, d)
    out = ops.flash_attention(qf, kf, vf, causal=True)
    return out.reshape(b, kh, g, s, d).transpose(0, 3, 1, 2, 4)


def seq_sharded_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                       *, valid: jax.Array, axis: str = "data") -> jax.Array:
    """shard_map wrapper around ``decode_attention_partial``: KV cache seq
    dim sharded over ``axis``; result combined with logsumexp partials —
    O(B·H·D) psum instead of an O(B·T·K·D) cache all-gather.
    q: [B,K,G,D]; cache: [B,T,K,D]; valid: [B,T].

    axis='data' serves long-context decode (batch too small to shard);
    axis='model' serves kv-head-replicated GQA archs (kv % TP != 0), where
    it removes both the per-layer cache all-gather and 1/TP of the cache
    HBM footprint."""
    from jax.sharding import PartitionSpec as PS
    from repro.models.sharding import active_mesh

    mesh = active_mesh()
    if mesh is None or axis not in mesh.shape or mesh.shape[axis] == 1 \
            or k_cache.shape[1] % mesh.shape[axis] != 0:
        return decode_attention(q, k_cache, v_cache, valid=valid)
    # batch sharding (manual, no collectives over it)
    b = q.shape[0]
    baxes = tuple(a for a in ("pod", "data") if a in mesh.shape and a != axis)
    bsize = 1
    for a in baxes:
        bsize *= mesh.shape[a]
    bspec = None
    if baxes and b % bsize == 0:
        bspec = baxes if len(baxes) > 1 else baxes[0]
    # kv-head sharding only if 'model' is not the seq axis
    msize = mesh.shape.get("model", 1)
    khead = "model" if (axis != "model" and "model" in mesh.shape
                        and msize > 1 and q.shape[1] % msize == 0) else None

    def body(qs, ks, vs, vld):
        return decode_attention_partial(qs, ks, vs, valid=vld, axis_name=axis)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(PS(bspec, khead), PS(bspec, axis, khead),
                  PS(bspec, axis, khead), PS(bspec, axis)),
        out_specs=PS(bspec, khead),
    )(q, k_cache, v_cache, valid)


# ---------------------------------------------------------------------------
# Full attention layer (projection + rope + path dispatch + cache handling)
# ---------------------------------------------------------------------------

def init_attn_cache(batch: int, cache_len: int, n_kv: int, head_dim: int,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    shape = (batch, cache_len, n_kv, head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_layer(params, x: jax.Array, *, kind: str, window: int,
                    rope_theta: float, n_kv_heads: int,
                    mode: str, lengths: Optional[jax.Array] = None,
                    cache: Optional[Dict[str, jax.Array]] = None,
                    causal: bool = True,
                    seq_shard_axis: Optional[str] = None,
                    use_rope: bool = True,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    kv_valid: Optional[jax.Array] = None,
                    use_pallas: bool = False,
                    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One attention layer. mode: 'train' | 'prefill' | 'decode'.

    kind: 'global_attn' | 'local_attn'. For decode, ``lengths`` [B] gives the
    current sequence length of every request (the new token goes to position
    lengths[b]). Local layers use a ring-buffer cache of size ``window``.
    ``kv_override`` supplies externally computed k/v (cross-attention).
    """
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    q = constrain(q, "act_batch", None, "act_heads", None)

    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    else:
        k, v = kv_override

    if mode in ("train", "prefill"):
        positions = jnp.arange(s, dtype=jnp.int32)
        if use_rope:
            q = L.apply_rope(q, positions, rope_theta)
            if kv_override is None:
                k = L.apply_rope(k, positions, rope_theta)
        qg = _split_gqa(q, n_kv_heads)
        if kind == "local_attn" and kv_override is None:
            out = window_attention(qg, k, v, positions=positions, window=window)
        elif use_pallas and causal and k.shape[1] == s and s % 128 == 0:
            out = _pallas_flash(qg, k, v)
        else:
            out = flash_attention(qg, k, v, q_positions=positions,
                                  kv_positions=jnp.arange(k.shape[1], dtype=jnp.int32),
                                  causal=causal)
        new_cache = None
        if mode == "prefill" and kv_override is None:
            if kind == "local_attn":
                # ring-buffer cache: slot j must hold the position p with
                # p % w == j; roll aligns the last-window slice to slots.
                w = min(window, s)
                new_cache = {"k": jnp.roll(k[:, s - w:], s % w, axis=1),
                             "v": jnp.roll(v[:, s - w:], s % w, axis=1)}
            else:
                new_cache = {"k": k, "v": v}
    elif mode == "decode":
        assert lengths is not None and (cache is not None or
                                        kv_override is not None)
        # new token position = lengths[b]
        pos = lengths.astype(jnp.int32)                       # [B]
        if use_rope:
            q = L.apply_rope(q, pos[:, None], rope_theta)
            if kv_override is None:
                k = L.apply_rope(k, pos[:, None], rope_theta)
        qd = _split_gqa(q, n_kv_heads)[:, 0]                  # [B,K,G,D]
        if kv_override is not None:
            t = k.shape[1]
            valid = jnp.ones((b, t), bool) if kv_valid is None else \
                jnp.broadcast_to(kv_valid[None, :], (b, t))
            out = decode_attention(qd, k, v, valid=valid)[:, None]
            new_cache = None
        else:
            t = cache["k"].shape[1]
            if kind == "local_attn":
                slot = pos % t                                 # ring buffer
            else:
                slot = pos
            k_cache = jax.vmap(lambda c, kn, i: jax.lax.dynamic_update_slice(
                c, kn, (i, 0, 0)))(cache["k"], k, slot)
            v_cache = jax.vmap(lambda c, vn, i: jax.lax.dynamic_update_slice(
                c, vn, (i, 0, 0)))(cache["v"], v, slot)
            iota = jnp.arange(t, dtype=jnp.int32)[None, :]
            if kind == "local_attn":
                valid = iota < jnp.minimum(pos + 1, t)[:, None]
            else:
                valid = iota <= pos[:, None]
            if seq_shard_axis is not None and kind == "global_attn":
                out = seq_sharded_decode(qd, k_cache, v_cache, valid=valid,
                                         axis=seq_shard_axis)[:, None]
            else:
                out = decode_attention(qd, k_cache, v_cache, valid=valid)[:, None]
            new_cache = {"k": k_cache, "v": v_cache}
    else:
        raise ValueError(mode)

    wo = params["wo"]                                        # [H, D, M]
    wo4 = wo.reshape(n_kv_heads, wo.shape[0] // n_kv_heads, wo.shape[1],
                     wo.shape[2])
    y = jnp.einsum("bskgd,kgdm->bsm", out.astype(x.dtype), wo4)
    y = constrain(y, "act_batch", "act_seq", "act_embed")
    return y, new_cache
