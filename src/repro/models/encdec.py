"""Whisper-style encoder-decoder backbone (audio frontend is a STUB).

``input_specs()`` provides precomputed frame embeddings (batch, enc_seq,
d_model) — the conv1d mel frontend of the paper is out of scope per the
brief. Encoder: bidirectional attention with sinusoidal positions. Decoder:
causal self-attention (cached) + cross-attention to the encoder output
(cross K/V cached at prefill), learned positional embeddings, GELU MLPs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import layers as L
from repro.models.sharding import constrain
from repro.models.transformer import Flags, DEFAULT_FLAGS


def _sinusoids(length: int, channels: int) -> jax.Array:
    lt = np.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-lt * jnp.arange(channels // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def _enc_block_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 2)
    return {
        "norm1": L.scale_init(cfg.d_model),
        "attn": A.attn_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.resolved_head_dim, dtype),
        "norm2": L.scale_init(cfg.d_model),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def _dec_block_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    return {
        "norm1": L.scale_init(cfg.d_model),
        "self_attn": A.attn_init(ks[0], cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.resolved_head_dim, dtype),
        "norm_x": L.scale_init(cfg.d_model),
        "cross_attn": A.attn_init(ks[1], cfg.d_model, cfg.n_heads,
                                  cfg.n_kv_heads, cfg.resolved_head_dim, dtype),
        "norm2": L.scale_init(cfg.d_model),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype),
    }


def encdec_init(key, cfg: ModelConfig, flags: Flags = DEFAULT_FLAGS):
    dtype = flags.param_dtype
    ks = jax.random.split(key, 5)
    params = {
        "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dtype),
        "pos_embed": L.Boxed(
            (jax.random.normal(ks[1], (cfg.max_seq, cfg.d_model), jnp.float32)
             * 0.01).astype(dtype), (None, "embed")),
        "enc_final_norm": L.scale_init(cfg.d_model),
        "final_norm": L.scale_init(cfg.d_model),
        "unembed": L.dense_init(ks[2], cfg.d_model, cfg.vocab,
                                ("embed", "vocab"), dtype),
    }
    ek = jax.random.split(ks[3], cfg.n_encoder_layers)
    params["encoder"] = jax.tree.map(
        lambda b: L.Boxed(b.value, ("layers",) + tuple(b.axes)),
        jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(ek),
        is_leaf=lambda x: isinstance(x, L.Boxed))
    dk = jax.random.split(ks[4], cfg.n_layers)
    params["decoder"] = jax.tree.map(
        lambda b: L.Boxed(b.value, ("layers",) + tuple(b.axes)),
        jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dk),
        is_leaf=lambda x: isinstance(x, L.Boxed))
    return params


def encode(params, frames: jax.Array, cfg: ModelConfig,
           flags: Flags = DEFAULT_FLAGS) -> jax.Array:
    """frames: [B, enc_S, D] (precomputed frame embeddings — STUB frontend)."""
    x = frames + _sinusoids(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = constrain(x, "act_batch", "act_seq", "act_embed")

    def body(x, p):
        h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
        mix, _ = A.attention_layer(
            p["attn"], h, kind="global_attn", window=0, rope_theta=0.0,
            n_kv_heads=cfg.n_kv_heads, mode="train", causal=False,
            use_rope=False)
        x = x + mix
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        return x + L.mlp_apply(p["mlp"], h, cfg.gated_mlp), None

    if flags.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_final_norm"], cfg.norm_eps)


def _cross_kv(p, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["cross_attn"]["wv"])
    return k, v


def _dec_block(p, x, *, cfg: ModelConfig, mode: str, flags: Flags,
               cache: Optional[Dict], lengths, enc_out: Optional[jax.Array],
               enc_valid: Optional[jax.Array]):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    mix, new_self = A.attention_layer(
        p["self_attn"], h, kind="global_attn", window=0, rope_theta=0.0,
        n_kv_heads=cfg.n_kv_heads, mode=mode, lengths=lengths,
        cache=None if cache is None else cache["self"], use_rope=False)
    x = x + mix
    # cross attention
    h = L.rms_norm(x, p["norm_x"], cfg.norm_eps)
    if mode == "decode":
        ck, cv = cache["cross"]["k"], cache["cross"]["v"]
        enc_valid = jnp.arange(ck.shape[1]) < cfg.encoder_seq
    else:
        ck, cv = _cross_kv(p, enc_out)
    if mode in ("train", "prefill"):
        q = jnp.einsum("bsd,dhk->bshk", h, p["cross_attn"]["wq"])
        qg = q.reshape(q.shape[0], q.shape[1], cfg.n_kv_heads, -1, q.shape[-1])
        t = ck.shape[1]
        out = A.flash_attention(
            qg, ck, cv,
            q_positions=jnp.arange(h.shape[1], dtype=jnp.int32),
            kv_positions=jnp.arange(t, dtype=jnp.int32),
            causal=False, kv_valid=enc_valid)
        wo = p["cross_attn"]["wo"]
        wo4 = wo.reshape(cfg.n_kv_heads, wo.shape[0] // cfg.n_kv_heads,
                         wo.shape[1], wo.shape[2])
        mix = jnp.einsum("bskgd,kgdm->bsm", out.astype(x.dtype), wo4)
    else:
        mix, _ = A.attention_layer(
            p["cross_attn"], h, kind="global_attn", window=0, rope_theta=0.0,
            n_kv_heads=cfg.n_kv_heads, mode="decode", lengths=lengths,
            use_rope=False, kv_override=(ck, cv), kv_valid=enc_valid)
    x = x + mix
    h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], h, cfg.gated_mlp)
    new_cache = None
    if mode != "train":
        new_cache = {"self": new_self,
                     "cross": {"k": ck, "v": cv} if mode == "prefill"
                     else cache["cross"]}
    return x, new_cache


def encdec_apply(params, batch: Dict[str, jax.Array], *, cfg: ModelConfig,
                 mode: str, flags: Flags = DEFAULT_FLAGS,
                 cache: Optional[Dict] = None
                 ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (decoder hidden [B,S,D], new_cache, aux=0). For train/prefill,
    batch must contain 'frames'; decode uses the cached cross K/V."""
    tokens = batch["tokens"]
    lengths = batch.get("lengths")
    b, s = tokens.shape
    enc_out = None
    enc_valid = None
    if mode in ("train", "prefill"):
        frames = batch["frames"]
        # pad encoder seq to a flash-block multiple, mask the padding
        t = frames.shape[1]
        tpad = (-t) % 128
        enc_valid = jnp.arange(t + tpad) < t
        if tpad:
            frames = jnp.pad(frames, ((0, 0), (0, tpad), (0, 0)))
        enc_out = encode(params, frames, cfg, flags)

    if mode == "decode":
        pos = lengths.astype(jnp.int32)[:, None]          # [B,1]
        pe = jnp.take(params["pos_embed"], pos[:, 0], axis=0)[:, None]
    else:
        pe = params["pos_embed"][None, :s]
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x + pe.astype(x.dtype)
    x = constrain(x, "act_batch", "act_seq", "act_embed")

    def body(x, inp):
        p, c = inp
        x, new_c = _dec_block(p, x, cfg=cfg, mode=mode, flags=flags, cache=c,
                              lengths=lengths, enc_out=enc_out,
                              enc_valid=enc_valid)
        return x, new_c

    if flags.remat != "none" and mode == "train":
        body = jax.checkpoint(body)
    if mode == "train":
        x, _ = jax.lax.scan(lambda xx, p: body(xx, (p, None)), x,
                            params["decoder"])
        new_cache = None
    else:
        x, new_dec_cache = jax.lax.scan(body, x,
                                        (params["decoder"], cache["decoder"]))
        new_cache = {"decoder": new_dec_cache}
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_cache, jnp.zeros((), jnp.float32)


def encdec_init_cache(cfg: ModelConfig, batch: int, cache_len: int,
                      flags: Flags = DEFAULT_FLAGS):
    dtype = flags.param_dtype
    enc_t = cfg.encoder_seq + ((-cfg.encoder_seq) % 128)

    def one(_):
        return {
            "self": A.init_attn_cache(batch, cache_len, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, dtype),
            "cross": A.init_attn_cache(batch, enc_t, cfg.n_kv_heads,
                                       cfg.resolved_head_dim, dtype),
        }
    return {"decoder": jax.vmap(one)(jnp.arange(cfg.n_layers))}
