"""Mixture-of-Experts FFN with top-k routing.

Two execution paths:

- ``moe_dense``: oracle path — computes every expert on every token and
  combines with routing weights. Exact, used for smoke tests and as the
  reference for the EP path's correctness tests.
- ``moe_ep``: production path — fixed-capacity GShard-style expert
  parallelism inside ``shard_map``: tokens are slotted into per-expert
  capacity buffers, exchanged with ``all_to_all`` over the ``model`` mesh
  axis, processed as dense batched matmuls on the expert owner, and combined
  back. FLOPs scale with top_k·capacity_factor, not num_experts.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import layers as L
from repro.models.sharding import active_mesh, constrain, resolve_spec

from jax.sharding import PartitionSpec as PS


def moe_init(key, d_model: int, mcfg: MoEConfig, gated: bool,
             dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 6)
    e, ff = mcfg.num_experts, mcfg.d_ff_expert
    sc = 1.0 / np.sqrt(d_model)
    p = {
        "router": L.Boxed(
            (jax.random.normal(ks[0], (d_model, e), jnp.float32) * sc
             ).astype(jnp.float32), ("embed", "experts")),
        "wi": L.Boxed(
            (jax.random.normal(ks[1], (e, d_model, ff), jnp.float32) * sc
             ).astype(dtype), ("experts", "embed", "expert_mlp")),
        "wo": L.Boxed(
            (jax.random.normal(ks[2], (e, ff, d_model), jnp.float32)
             / np.sqrt(ff)).astype(dtype), ("experts", "expert_mlp", "embed")),
    }
    if gated:
        p["wg"] = L.Boxed(
            (jax.random.normal(ks[3], (e, d_model, ff), jnp.float32) * sc
             ).astype(dtype), ("experts", "embed", "expert_mlp"))
    if mcfg.d_ff_shared:
        p["shared"] = L.mlp_init(ks[4], d_model, mcfg.d_ff_shared, gated, dtype)
    return p


def _route(router_w: jax.Array, x: jax.Array, mcfg: MoEConfig
           ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (weights [T,k], expert_idx [T,k], aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, mcfg.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balance loss: E * sum_e f_e * p_e
    e = mcfg.num_experts
    me = jnp.mean(probs, axis=0)                                  # [E]
    fe = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * fe) * mcfg.load_balance_loss_weight
    return weights, idx, aux


def _expert_ffn(p, h: jax.Array, gated: bool) -> jax.Array:
    """h: [E, C, D] -> [E, C, D] (batched per-expert dense MLP)."""
    up = jnp.einsum("ecd,edf->ecf", h, p["wi"])
    if gated:
        g = jnp.einsum("ecd,edf->ecf", h, p["wg"])
        up = jax.nn.silu(g) * up
    else:
        up = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", up, p["wo"])


def moe_dense(p, x: jax.Array, mcfg: MoEConfig, gated: bool
              ) -> Tuple[jax.Array, jax.Array]:
    """Oracle: every expert on every token. x: [B,S,D]."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    weights, idx, aux = _route(p["router"], xf, mcfg)
    hs = jnp.einsum("td,edf->etf", xf, p["wi"])
    if gated:
        gs = jnp.einsum("td,edf->etf", xf, p["wg"])
        hs = jax.nn.silu(gs) * hs
    else:
        hs = jax.nn.gelu(hs)
    ys = jnp.einsum("etf,efd->etd", hs, p["wo"])                  # [E,T,D]
    comb = jnp.zeros((xf.shape[0], mcfg.num_experts), x.dtype)
    comb = comb.at[jnp.arange(xf.shape[0])[:, None], idx].add(
        weights.astype(x.dtype))
    out = jnp.einsum("te,etd->td", comb, ys)
    if mcfg.d_ff_shared:
        out = out + L.mlp_apply(p["shared"], xf, gated)
    return out.reshape(b, s, d), aux


def _ep_local(p, xf: jax.Array, mcfg: MoEConfig, gated: bool, axis: str,
              capacity_factor: float) -> Tuple[jax.Array, jax.Array]:
    """Body run per (data, model) shard inside shard_map.
    xf: [T_loc, D] local tokens. Experts are sharded over ``axis``."""
    tp = jax.lax.axis_size(axis)
    t_loc, d = xf.shape
    e = mcfg.num_experts
    e_loc = e // tp
    k = mcfg.top_k
    # capacity per (this shard -> each expert)
    cap = int(np.ceil(t_loc * k / e * capacity_factor))
    cap = max(4, ((cap + 3) // 4) * 4)

    weights, idx, aux = _route(p["router"], xf, mcfg)              # [T,k]
    flat_e = idx.reshape(-1)                                       # [T*k]
    token_of = jnp.repeat(jnp.arange(t_loc), k)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    tok_sorted = token_of[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t_loc * k) - starts[e_sorted]                # slot in expert
    keep = rank < cap
    # dispatch buffers [E, cap, D]
    buf = jnp.zeros((e, cap, d), xf.dtype)
    buf = buf.at[e_sorted, jnp.where(keep, rank, 0)].add(
        jnp.where(keep[:, None], xf[tok_sorted], 0))
    # exchange: [tp, E_loc, cap, D] -> owner gets [tp, E_loc, cap, D]
    buf = buf.reshape(tp, e_loc, cap, d)
    buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0, tiled=True)
    buf = buf.reshape(tp, e_loc, cap, d).transpose(1, 0, 2, 3)     # [E_loc,tp,cap,D]
    h = buf.reshape(e_loc, tp * cap, d)
    y = _expert_ffn(p, h, gated)                                   # local experts
    y = y.reshape(e_loc, tp, cap, d).transpose(1, 0, 2, 3)
    y = y.reshape(tp * e_loc, cap, d)
    y = jax.lax.all_to_all(y, axis, split_axis=0, concat_axis=0, tiled=True)
    y = y.reshape(e, cap, d)
    # combine back to tokens
    gathered = y[e_sorted, jnp.where(keep, rank, 0)]               # [T*k, D]
    gathered = jnp.where(keep[:, None], gathered, 0)
    w_sorted = weights.reshape(-1)[order].astype(xf.dtype)
    out = jnp.zeros_like(xf)
    out = out.at[tok_sorted].add(gathered * w_sorted[:, None])
    return out, aux


def moe_ep(p, x: jax.Array, mcfg: MoEConfig, gated: bool, *,
           axis: str = "model", capacity_factor: float = 1.25,
           data_axes: Tuple[str, ...] = ("pod", "data"),
           ) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE. x: [B,S,D] sharded over data axes. Must run under
    an active mesh; falls back to the dense oracle otherwise."""
    mesh = active_mesh()
    if mesh is None or axis not in mesh.shape or mesh.shape[axis] == 1 \
            or mcfg.num_experts % mesh.shape[axis] != 0:
        return moe_dense(p, x, mcfg, gated)
    b, s, d = x.shape
    batch_axes = tuple(a for a in data_axes if a in mesh.shape)
    tp = mesh.shape[axis]
    # sequence-parallel dispatch: each model shard routes its own token slice
    # (no redundant router compute, no replication to verify). Decode (S=1)
    # falls back to model-replicated tokens.
    seq_shard = s % tp == 0 and s >= tp

    def body(experts, xloc):
        bl, sl, dl = xloc.shape
        out, aux = _ep_local(experts, xloc.reshape(bl * sl, dl), mcfg, gated,
                             axis, capacity_factor)
        # aux differs per shard; mean over all axes for a global scalar
        aux = jax.lax.pmean(aux, axis)
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)
        return out.reshape(bl, sl, dl), aux

    bax = batch_axes if len(batch_axes) != 1 else batch_axes[0]
    xs = PS(bax if batch_axes else None, axis if seq_shard else None)
    espec = PS(axis)
    experts = {k: p[k] for k in ("wi", "wo", "wg") if k in p}
    experts["router"] = p["router"]
    especs = {k: espec for k in experts}
    especs["router"] = PS()
    out, aux = jax.shard_map(
        body, mesh=mesh,
        in_specs=(especs, xs),
        out_specs=(xs, PS()),
        check_vma=seq_shard,
    )(experts, x)
    if mcfg.d_ff_shared:
        out = out + L.mlp_apply(p["shared"], x, gated)
    return out, aux
