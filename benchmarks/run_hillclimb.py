"""§Perf hillclimb driver: for each of the three selected cells, lower the
variant ladder (plus 0-layer/1-period probes for corrected accounting) and
store JSONs under benchmarks/results/hillclimb/.

Cells (selection criteria per the brief):
  gemma3_27b   train_4k   — most representative of the paper's technique
                            (over-decomposition/microbatch + overlap)
  pixtral_12b  decode_32k — most collective-bound baseline (cache all-gather)
  mamba2_370m  train_4k   — worst roofline fraction (no TP mapping)
"""
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT = os.path.join(REPO, "benchmarks", "results", "hillclimb")

PLAN = [
    ("gemma3-27b", "train_4k",
     ["od2", "od4", "dots", "dots_sp", "dots_sp_od4", "sp", "sp_od4",
      "sp_od8"]),
    ("pixtral-12b", "decode_32k", ["kvseq_model"]),
    ("mamba2-370m", "train_4k",
     ["dots", "ssd_chunk128", "ssd_chunk128_dots_sp"]),
    # breadth: the seq-sharded-KV decode fix applied to every
    # kv-head-replicated architecture (beyond-paper optimized column)
    ("yi-9b", "decode_32k", ["kvseq_model"]),
    ("phi4-mini-3.8b", "decode_32k", ["kvseq_model"]),
    ("llama4-scout-17b-a16e", "decode_32k", ["kvseq_model"]),
    ("whisper-large-v3", "decode_32k", ["kvseq_model"]),
]


def run(arch, shape, variant, probe=None, timeout=3600):
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.configs import canon
    tag = f"{canon(arch)}__{shape}__{variant}"
    if probe is not None:
        tag += f"__probe{probe}"
    out_path = os.path.join(OUT, tag + ".json")
    if os.path.exists(out_path):
        try:
            if "error" not in json.load(open(out_path)):
                print(f"SKIP {tag}", flush=True)
                return
        except Exception:
            pass
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--variant", variant, "--out", out_path]
    if probe is not None:
        cmd += ["--probe", str(probe)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    t0 = time.time()
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=REPO)
    if proc.returncode != 0:
        with open(out_path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "variant": variant,
                       "probe": probe, "error": proc.stderr[-3000:]}, f)
        print(f"FAIL {tag} ({time.time()-t0:.0f}s)", flush=True)
    else:
        print(f"OK   {tag} ({time.time()-t0:.0f}s)", flush=True)


def main():
    os.makedirs(OUT, exist_ok=True)
    for arch, shape, variants in PLAN:
        for v in variants:
            run(arch, shape, v)
            run(arch, shape, v, probe=0)
            run(arch, shape, v, probe=1)
    print("hillclimb sweep done", flush=True)


if __name__ == "__main__":
    main()
