"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout), mirroring:
  Fig. 8   tasking-framework optimization ladder (tasking_overhead)
  Fig. 9   multi-device scaling (multidevice_scaling)
  Fig. 10–12  ping-pong latency/bandwidth (pingpong)
  Fig. 13/15  Jacobi3D scaling + over-decomposition (jacobi_scaling)
plus a summary of the multi-pod dry-run + roofline table (reads the JSONs
produced by benchmarks/run_dryrun_sweep.py — run that first for fresh data).
"""
import json
import glob
import os
import sys
import traceback

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(HERE), "src"))
sys.path.insert(0, os.path.dirname(HERE))


def _section(title):
    print(f"# --- {title} ---", flush=True)


def main() -> None:
    from benchmarks import (jacobi_scaling, multidevice_scaling, pingpong,
                            tasking_overhead)

    sections = [
        ("fig8 tasking overhead ladder", tasking_overhead.main),
        ("fig9 multi-device scaling", multidevice_scaling.main),
        ("fig10-12 pingpong", pingpong.main),
        ("fig13/15 jacobi scaling + over-decomposition", jacobi_scaling.main),
    ]
    failures = []
    for title, fn in sections:
        _section(title)
        try:
            fn()
        except Exception as e:   # keep the harness running
            failures.append(title)
            print(f"SECTION_FAILED {title}: {e}", flush=True)
            traceback.print_exc()

    _section("dry-run / roofline summary")
    for f in sorted(glob.glob(os.path.join(HERE, "results", "dryrun",
                                           "*.json"))):
        with open(f) as fh:
            d = json.load(fh)
        if d.get("probe") is not None or d.get("skipped"):
            continue
        if "error" in d:
            print(f"dryrun_{os.path.basename(f)},,ERROR")
            continue
        pods = "pod2" if "pod" in d.get("mesh", {}) else "pod1"
        print(f"dryrun_{d['arch']}__{d['shape']}__{pods},"
              f"{d.get('compile_s', '')},"
              f"bottleneck={d.get('bottleneck')};chips={d.get('chips')}")
    if failures:
        print(f"# failed sections: {failures}", flush=True)
        sys.exit(1)
    print("# all benchmark sections completed", flush=True)


if __name__ == '__main__':
    main()
