"""Benchmark harness entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (stdout), mirroring:
  Fig. 8   tasking-framework optimization ladder (tasking_overhead)
  Fig. 9   multi-device scaling (multidevice_scaling)
  Fig. 10–12  ping-pong latency/bandwidth (pingpong)
  Fig. 13/15  Jacobi3D scaling + over-decomposition (jacobi_scaling)
plus a summary of the multi-pod dry-run + roofline table (reads the JSONs
produced by benchmarks/run_dryrun_sweep.py — run that first for fresh data).
"""
import json
import glob
import os
import sys
import traceback

HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(HERE), "src"))
sys.path.insert(0, os.path.dirname(HERE))


def _section(title):
    print(f"# --- {title} ---", flush=True)


def main() -> None:
    from benchmarks import (jacobi_scaling, multidevice_scaling, pingpong,
                            tasking_overhead)

    sections = [
        ("fig8 tasking overhead ladder", tasking_overhead.main),
        ("fig9 multi-device scaling", multidevice_scaling.main),
        ("fig10-12 pingpong", pingpong.main),
        ("fig13/15 jacobi scaling + over-decomposition", jacobi_scaling.main),
    ]
    failures = []
    for title, fn in sections:
        _section(title)
        try:
            fn()
        except Exception as e:   # keep the harness running
            failures.append(title)
            print(f"SECTION_FAILED {title}: {e}", flush=True)
            traceback.print_exc()

    _section("dry-run / roofline summary")
    result_files = sorted(glob.glob(os.path.join(HERE, "results", "dryrun",
                                                 "*.json")))
    for f in result_files:
        if os.path.basename(f).startswith("rt_ladder__"):
            continue           # runtime-ladder payloads summarized below
        with open(f) as fh:
            d = json.load(fh)
        if not isinstance(d, dict):
            continue
        if d.get("probe") is not None or d.get("skipped"):
            continue
        if "error" in d:
            print(f"dryrun_{os.path.basename(f)},,ERROR")
            continue
        pods = "pod2" if "pod" in d.get("mesh", {}) else "pod1"
        print(f"dryrun_{d['arch']}__{d['shape']}__{pods},"
              f"{d.get('compile_s', '')},"
              f"bottleneck={d.get('bottleneck')};chips={d.get('chips')}")

    _section("runtime ladder / residency report")
    for f in result_files:
        base = os.path.basename(f)
        if not base.startswith("rt_ladder__"):
            continue
        with open(f) as fh:
            d = json.load(fh)
        tag = base[len("rt_ladder__"):-len(".json")]
        if isinstance(d, dict) and "error" in d:
            print(f"rt_{tag},,ERROR")
        elif isinstance(d, dict) and "bytes_moved_ratio" in d:
            # SCHED-Locality: gravity-vs-baseline byte accounting
            print(f"rt_{tag},,"
                  f"baseline_moved={d['baseline']['bytes_moved']};"
                  f"gravity_moved={d['gravity']['bytes_moved']};"
                  f"ratio={d['bytes_moved_ratio']}")
        elif isinstance(d, list):
            for row in d:
                for key, val in row.items():
                    if not key.endswith("_stats") or not isinstance(val,
                                                                    dict):
                        continue
                    rung = key[:-len("_stats")]
                    pools = (f"stage={val.get('staging_hits')}/"
                             f"{val.get('staging_misses')};"
                             f"req={val.get('request_pool_hits')}/"
                             f"{val.get('request_pool_misses')}")
                    moved = sum(val.get(k) or 0 for k in
                                ("bytes_h2d", "bytes_d2h", "bytes_d2d"))
                    print(f"rt_{tag}_{rung}_{row['size']},,"
                          f"moved={moved};{pools};"
                          f"evict={val.get('evictions')}")
    if failures:
        print(f"# failed sections: {failures}", flush=True)
        sys.exit(1)
    print("# all benchmark sections completed", flush=True)


if __name__ == '__main__':
    main()
