"""Paper §4.2 — message-protocol crossover sweep (Fig. 10–12 analogue).

Per message size, measures the wall-clock from ``Rank.send`` on rank 0 to
*device-resident delivery* on rank 1 (the paper's definition of a useful
message: the payload is where the consumer task runs), for two protocol
configurations on the same simulated network:

  mono   eager_threshold = ∞ — every payload travels as one monolithic
         blob through a single staging hop (the pre-protocol-split path);
         the receiver then uploads the whole payload to its device.
  pipe   the protocol split: payloads ≤ eager_threshold travel eagerly
         (identical to mono), larger ones chunk-stream through the
         rendezvous protocol with each chunk uploaded to the landing
         device while the next is still on the network.

The expected curve is the paper's crossover: small messages identical
(within noise — the eager path IS the monolithic path), large messages
faster under pipe because device upload hides behind network receive.

Chunk size defaults to the bandwidth-delay product measured by the
cluster's InterconnectModel (refined by the warmup sends); pass
``--chunk-kb`` to pin it.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import RuntimeConfig
from repro.distributed import Cluster, handler

_delivered = threading.Event()
_count_lock = threading.Lock()
_count = 0
_target = 1


@handler(name="msgrate_sink")
def _sink(ctx, obj):
    # force device residency: rendezvous payloads already live there,
    # monolithic host payloads pay their upload here — the fair endpoint
    global _count
    rt = ctx.rank.runtime
    rt._ensure_on_device(obj, rt.pick_landing_device(), will_write=False)
    with _count_lock:
        _count += 1
        if _count >= _target:
            _delivered.set()


def _one_batch(cluster: Cluster, nbytes: int, count: int) -> float:
    """Time ``count`` back-to-back deliveries; returns seconds per
    message. Small messages are batched so per-call scheduler jitter
    (±0.5 ms on a busy box) amortizes below the effect being measured."""
    global _count, _target
    n = max(nbytes // 4, 1)
    objs = [cluster.ranks[0].runtime.hetero_object(
        np.ones((n,), np.float32)) for _ in range(count)]
    with _count_lock:
        _count, _target = 0, count
    _delivered.clear()
    t0 = time.perf_counter()
    for obj in objs:
        cluster.ranks[0].send(1, "msgrate_sink", obj)
    if not _delivered.wait(120):
        raise TimeoutError(f"delivery timeout at {nbytes}B")
    return (time.perf_counter() - t0) / count


def _batch_count(nbytes: int) -> int:
    return max(1, min(64, (256 << 10) // max(nbytes, 1)))


SIZES = (1 << 10, 8 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20, 8 << 20)


def run(sizes=SIZES, iters: int = 10, latency_s: float = 30e-6,
        bw_bytes_per_s: float = 1e9, eager_threshold: int = 64 << 10,
        chunk_bytes: Optional[int] = None) -> List[Dict]:
    rows: List[Dict] = []
    # ONE cluster serves both modes: the protocol decision reads
    # cfg.eager_threshold at flush time, so flipping it between sends
    # A/B-tests mono vs pipe on identical threads, identical topology
    # state, identical caches — the only variable is the protocol
    cfg = RuntimeConfig(memory_capacity=1 << 30,
                        eager_threshold=eager_threshold,
                        chunk_bytes=chunk_bytes)
    with Cluster(2, cfg, latency_s=latency_s,
                 bw_bytes_per_s=bw_bytes_per_s) as cluster:
        r1 = cluster.ranks[1]

        def timed(nb: int, mono: bool) -> float:
            cfg.eager_threshold = (1 << 62) if mono else eager_threshold
            return _one_batch(cluster, nb, _batch_count(nb))

        for _ in range(2):               # compile + seed the bw estimate
            timed(1 << 20, mono=True)
            timed(1 << 20, mono=False)
        for nb in sizes:
            timed(nb, mono=True)         # per-size shape warmup
            timed(nb, mono=False)
            chunks0 = r1.stats["chunks_in"]
            overlap0 = r1.stats["overlap_bytes"]
            mono_lat, pipe_lat = [], []
            for i in range(iters):
                # alternate which mode goes first so any first-of-pair
                # effect (cache state, thread wakeup) cancels out
                if i % 2 == 0:
                    mono_lat.append(timed(nb, mono=True))
                    pipe_lat.append(timed(nb, mono=False))
                else:
                    pipe_lat.append(timed(nb, mono=False))
                    mono_lat.append(timed(nb, mono=True))
            mono_us = float(np.median(mono_lat)) * 1e6
            pipe_us = float(np.median(pipe_lat)) * 1e6
            rows.append({
                "bytes": nb,
                "protocol": "eager" if nb <= eager_threshold else "rdzv",
                "mono_us": round(mono_us, 1),
                "pipe_us": round(pipe_us, 1),
                "speedup": round(mono_us / pipe_us, 4),
                "chunks": (r1.stats["chunks_in"] - chunks0)
                / (iters * _batch_count(nb)),
                "overlap_bytes": (r1.stats["overlap_bytes"] - overlap0)
                / (iters * _batch_count(nb)),
            })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default=None,
                    help="comma-separated payload bytes")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--latency-us", type=float, default=30.0)
    ap.add_argument("--bw-gbps", type=float, default=1.0,
                    help="simulated network bandwidth, GB/s")
    ap.add_argument("--eager-kb", type=int, default=64)
    ap.add_argument("--chunk-kb", type=int, default=None,
                    help="pin the rendezvous chunk size (default: "
                         "bandwidth-delay product from the measured link)")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(",")) if args.sizes \
        else SIZES
    rows = run(sizes=sizes, iters=args.iters,
               latency_s=args.latency_us * 1e-6,
               bw_bytes_per_s=args.bw_gbps * 1e9,
               eager_threshold=args.eager_kb << 10,
               chunk_bytes=(args.chunk_kb << 10) if args.chunk_kb else None)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"msgrate_mono_{r['bytes']},{r['mono_us']:.1f},")
        print(f"msgrate_pipe_{r['bytes']},{r['pipe_us']:.1f},"
              f"{r['protocol']}_x{r['speedup']:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
