"""Paper §4.2 — message-protocol crossover sweep (Fig. 10–12 analogue).

Per message size, measures the wall-clock from ``Rank.send`` on rank 0 to
*device-resident delivery* on rank 1 (the paper's definition of a useful
message: the payload is where the consumer task runs), for two protocol
configurations on the same simulated network:

  mono   eager_threshold = ∞ — every payload travels as one monolithic
         blob through a single staging hop (the pre-protocol-split path);
         the receiver then uploads the whole payload to its device.
  pipe   the protocol split: payloads ≤ eager_threshold travel eagerly
         (identical to mono), larger ones chunk-stream through the
         rendezvous protocol with each chunk uploaded to the landing
         device while the next is still on the network.

The expected curve is the paper's crossover: small messages identical
(within noise — the eager path IS the monolithic path), large messages
faster under pipe because device upload hides behind network receive.

Chunk size defaults to the bandwidth-delay product measured by the
cluster's InterconnectModel (refined by the warmup sends); pass
``--chunk-kb`` to pin it.
"""
from __future__ import annotations

import argparse
import json
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import RuntimeConfig
from repro.distributed import Cluster, handler

_delivered = threading.Event()
_count_lock = threading.Lock()
_count = 0
_target = 1


@handler(name="msgrate_sink")
def _sink(ctx, obj):
    # force device residency: rendezvous payloads already live there,
    # monolithic host payloads pay their upload here — the fair endpoint
    global _count
    rt = ctx.rank.runtime
    rt._ensure_on_device(obj, rt.pick_landing_device(), will_write=False)
    with _count_lock:
        _count += 1
        if _count >= _target:
            _delivered.set()


_stream_done = threading.Event()


@handler(name="msgrate_stream_sink")
def _stream_sink(ctx, obj):
    rt = ctx.rank.runtime
    rt._ensure_on_device(obj, rt.pick_landing_device(), will_write=False)
    _stream_done.set()


_hol_t1 = [0.0]


@handler(name="msgrate_hol_sink")
def _hol_sink(ctx, obj):
    # HOL smalls measure the message engine's control-plane latency: the
    # endpoint is handler delivery, timestamped HERE (ranks share a
    # clock in-process, so one-way latency is directly measurable and
    # the caller's own wake-up cost stays out of the number). Forcing a
    # jax upload here would fold multi-ms XLA dispatch jitter into a
    # sub-ms quantity and drown the head-of-line signal being measured;
    # the concurrent stream still pays full device-resident landing —
    # that IS the load.
    global _count
    _hol_t1[0] = time.perf_counter()
    with _count_lock:
        _count += 1
        if _count >= _target:
            _delivered.set()


def _one_batch(cluster: Cluster, nbytes: int, count: int) -> float:
    """Time ``count`` back-to-back deliveries; returns seconds per
    message. Small messages are batched so per-call scheduler jitter
    (±0.5 ms on a busy box) amortizes below the effect being measured."""
    global _count, _target
    n = max(nbytes // 4, 1)
    objs = [cluster.ranks[0].runtime.hetero_object(
        np.ones((n,), np.float32)) for _ in range(count)]
    with _count_lock:
        _count, _target = 0, count
    _delivered.clear()
    t0 = time.perf_counter()
    for obj in objs:
        cluster.ranks[0].send(1, "msgrate_sink", obj)
    if not _delivered.wait(120):
        raise TimeoutError(f"delivery timeout at {nbytes}B")
    return (time.perf_counter() - t0) / count


def _batch_count(nbytes: int) -> int:
    return max(1, min(64, (256 << 10) // max(nbytes, 1)))


SIZES = (1 << 10, 8 << 10, 64 << 10, 256 << 10, 1 << 20, 4 << 20, 8 << 20)


def run(sizes=SIZES, iters: int = 10, latency_s: float = 30e-6,
        bw_bytes_per_s: float = 1e9, eager_threshold: int = 64 << 10,
        chunk_bytes: Optional[int] = None) -> List[Dict]:
    rows: List[Dict] = []
    # ONE cluster serves both modes: the protocol decision reads
    # cfg.eager_threshold at flush time, so flipping it between sends
    # A/B-tests mono vs pipe on identical threads, identical topology
    # state, identical caches — the only variable is the protocol
    cfg = RuntimeConfig(memory_capacity=1 << 30,
                        eager_threshold=eager_threshold,
                        chunk_bytes=chunk_bytes)
    with Cluster(2, cfg, latency_s=latency_s,
                 bw_bytes_per_s=bw_bytes_per_s) as cluster:
        r1 = cluster.ranks[1]

        def timed(nb: int, mono: bool) -> float:
            cfg.eager_threshold = (1 << 62) if mono else eager_threshold
            return _one_batch(cluster, nb, _batch_count(nb))

        for _ in range(2):               # compile + seed the bw estimate
            timed(1 << 20, mono=True)
            timed(1 << 20, mono=False)
        for nb in sizes:
            timed(nb, mono=True)         # per-size shape warmup
            timed(nb, mono=False)
            chunks0 = r1.stats["chunks_in"]
            overlap0 = r1.stats["overlap_bytes"]
            mono_lat, pipe_lat = [], []
            for i in range(iters):
                # alternate which mode goes first so any first-of-pair
                # effect (cache state, thread wakeup) cancels out
                if i % 2 == 0:
                    mono_lat.append(timed(nb, mono=True))
                    pipe_lat.append(timed(nb, mono=False))
                else:
                    pipe_lat.append(timed(nb, mono=False))
                    mono_lat.append(timed(nb, mono=True))
            mono_us = float(np.median(mono_lat)) * 1e6
            pipe_us = float(np.median(pipe_lat)) * 1e6
            rows.append({
                "bytes": nb,
                "protocol": "eager" if nb <= eager_threshold else "rdzv",
                "mono_us": round(mono_us, 1),
                "pipe_us": round(pipe_us, 1),
                "speedup": round(mono_us / pipe_us, 4),
                "chunks": (r1.stats["chunks_in"] - chunks0)
                / (iters * _batch_count(nb)),
                "overlap_bytes": (r1.stats["overlap_bytes"] - overlap0)
                / (iters * _batch_count(nb)),
            })
    return rows


def run_verify_overhead(sizes=(8 << 10, 1 << 20, 4 << 20), iters: int = 10,
                        latency_s: float = 30e-6,
                        bw_bytes_per_s: float = 4e9,
                        eager_threshold: int = 64 << 10,
                        chunk_bytes: Optional[int] = None) -> List[Dict]:
    """INTEG-Recover overhead arm: end-to-end delivery latency with the
    fold64 payload checksum ON vs OFF, A/B'd on one cluster the same way
    ``run`` A/Bs protocols — ``cfg.verify_payloads`` is consulted at
    send-digest and receive-verify time, so flipping it between batches
    isolates the digest cost on identical threads/topology/caches. The
    claim: the vectorized fold runs far above simulated wire bandwidth,
    so the clean-path cost stays within a few percent even at 4 MiB."""
    rows: List[Dict] = []
    cfg = RuntimeConfig(memory_capacity=1 << 30,
                        eager_threshold=eager_threshold,
                        chunk_bytes=chunk_bytes)
    with Cluster(2, cfg, latency_s=latency_s,
                 bw_bytes_per_s=bw_bytes_per_s) as cluster:

        def timed(nb: int, verify: bool) -> float:
            cfg.verify_payloads = verify
            return _one_batch(cluster, nb, _batch_count(nb))

        for _ in range(2):               # compile + seed the bw estimate
            timed(1 << 20, verify=True)
            timed(1 << 20, verify=False)
        for nb in sizes:
            timed(nb, verify=True)       # per-size shape warmup
            timed(nb, verify=False)
            on_lat, off_lat = [], []
            for i in range(iters):
                if i % 2 == 0:
                    on_lat.append(timed(nb, verify=True))
                    off_lat.append(timed(nb, verify=False))
                else:
                    off_lat.append(timed(nb, verify=False))
                    on_lat.append(timed(nb, verify=True))
            on_us = float(np.median(on_lat)) * 1e6
            off_us = float(np.median(off_lat)) * 1e6
            rows.append({
                "bytes": nb,
                "protocol": "eager" if nb <= eager_threshold else "rdzv",
                "verify_us": round(on_us, 1),
                "noverify_us": round(off_us, 1),
                "overhead_pct": round((on_us / off_us - 1.0) * 100, 2),
            })
        cfg.verify_payloads = True
    return rows


def _one_small(cluster: Cluster, nbytes: int) -> float:
    """One timed small-message ONE-WAY delivery (send call → handler
    invocation on the peer, receiver-timestamped)."""
    global _count, _target
    obj = cluster.ranks[0].runtime.hetero_object(
        np.ones(max(nbytes // 4, 1), np.float32))
    with _count_lock:
        _count, _target = 0, 1
    _delivered.clear()
    t0 = time.perf_counter()
    cluster.ranks[0].send(1, "msgrate_hol_sink", obj)
    if not _delivered.wait(60):
        raise TimeoutError(f"small-message delivery timeout at {nbytes}B")
    return _hol_t1[0] - t0


def run_hol(small_bytes: int = 4 << 10, stream_bytes: int = 8 << 20,
            samples: int = 80, repeats: int = 3, latency_s: float = 20e-6,
            bw_bytes_per_s: float = 512e6, eager_threshold: int = 64 << 10,
            chunk_bytes: int = 128 << 10, net_window: int = 4) -> Dict:
    """MSG-HOL rung: head-of-line latency under load. Measures the p50
    small-message one-way delivery latency on an idle rank pair, then
    again while a ``stream_bytes`` rendezvous stream is in flight on the
    SAME pair. With the progress engine the stream runs on the sender's
    net-send lane and the cut-through link gives control/eager traffic a
    higher-priority virtual channel, so the loaded p50 stays within a
    small factor of unloaded — the pre-engine pump streamed the whole
    payload inline and every small message waited out the stream
    (loaded latency ≈ the stream's remaining wire time, tens of ms).

    Robustness choices, all aimed at measuring the protocol and not the
    host: the credit window is pinned (``net_window``) so the BDP
    autosizer's run-to-run drift stays out of the numbers; phases are
    interleaved ``repeats`` times and each phase reports the MINIMUM of
    its per-round medians (timeit's rationale: scheduler interference on
    a small shared host is strictly additive noise); latency is one-way,
    receiver-timestamped, so the measuring thread's own wake-up cost is
    excluded."""
    cfg = RuntimeConfig(memory_capacity=1 << 30,
                        eager_threshold=eager_threshold,
                        chunk_bytes=chunk_bytes, net_window=net_window)
    with Cluster(2, cfg, latency_s=latency_s,
                 bw_bytes_per_s=bw_bytes_per_s) as cluster:
        r0, r1 = cluster.ranks

        def one_stream(measure: bool) -> List[float]:
            _stream_done.clear()
            big = r0.runtime.hetero_object(
                np.ones(stream_bytes // 4, np.float32))
            r0.send(1, "msgrate_stream_sink", big)
            got: List[float] = []
            while not _stream_done.is_set() and len(got) < samples * 4:
                lat = _one_small(cluster, small_bytes)
                if measure:
                    got.append(lat)
            if not _stream_done.wait(120):
                raise TimeoutError("stream timeout")
            cluster.barrier()
            return got

        for _ in range(10):                   # compile + thread warmup
            _one_small(cluster, small_bytes)
        one_stream(measure=False)             # warm the rendezvous path
        chunks0 = r1.stats["chunks_in"]
        overlap0 = r1.stats["overlap_bytes"]
        un_meds, ld_meds, n_loaded = [], [], 0
        for _ in range(repeats):
            un = [_one_small(cluster, small_bytes) for _ in range(samples)]
            un_meds.append(float(np.median(un)))
            ld = one_stream(measure=True)
            n_loaded += len(ld)
            if ld:
                ld_meds.append(float(np.median(ld)))
        p50_un = min(un_meds) * 1e6
        p50_ld = min(ld_meds) * 1e6 if ld_meds else 0.0
        return {
            "small_bytes": small_bytes,
            "stream_bytes": stream_bytes,
            "repeats": repeats,
            "p50_unloaded_us": round(p50_un, 1),
            "p50_loaded_us": round(p50_ld, 1),
            "ratio": round(p50_ld / p50_un, 4) if p50_un else None,
            "loaded_samples": n_loaded,
            "stream_chunks": r1.stats["chunks_in"] - chunks0,
            "max_window": r0.stats["max_window"],
            "overlap_bytes": r1.stats["overlap_bytes"] - overlap0,
        }


_congest_done = threading.Event()
_congest_t1 = [0.0]


@handler(name="msgrate_congest_sink")
def _congest_sink(ctx, obj):
    # device residency is already paid chunk-by-chunk on the (throttled)
    # transfer lane; timestamp stream completion for the goodput number
    _congest_t1[0] = time.perf_counter()
    _congest_done.set()


def _slow_receiver_transfers(runtime, slow_on: threading.Event,
                             slow_s: float):
    """Artificially slow the receiver's transfer lane: while ``slow_on``
    is set, every job submitted to a transfer lane pays a fixed extra
    ``slow_s`` — a constant per-chunk service cost, so the drain rate is
    the same no matter how many chunks a window piles into the queue
    (a fair A/B between window policies; a queue-depth-coupled throttle
    would throttle the wider window less)."""
    orig = runtime._async_transfer

    def slowed_submit(device_id, fn, priority=0):
        if not slow_on.is_set():
            return orig(device_id, fn, priority)

        def slowed():
            time.sleep(slow_s)
            return fn()
        return orig(device_id, slowed, priority)

    runtime._async_transfer = slowed_submit


def run_congestion(small_bytes: int = 4 << 10, stream_bytes: int = 8 << 20,
                   samples: int = 40, repeats: int = 3,
                   latency_s: float = 2e-3, bw_bytes_per_s: float = 512e6,
                   eager_threshold: int = 64 << 10,
                   chunk_bytes: int = 128 << 10, pinned_window: int = 8,
                   slow_s: float = 8e-3,
                   ctrl_drain_per_s: float = 100e3) -> Dict:
    """MSG-Congestion rung: adaptive vs pinned credit windows against a
    backed-up receiver. The receiver's landing-device transfer lane is
    artificially slowed (bounded sleeper backlog), a large stream runs
    through it, and small messages are timed one-way on the same rank
    pair throughout. Both arms pay the SAME billed control channel
    (finite ``ctrl_drain_per_s`` drain rate per link — credit chatter
    costs simulated time) and the same throttle.

    The paper's claim, measurably: the adaptive window shrinks to the
    receiver's real drain rate (``credits_deferred`` > 0, ``window_min``
    → 1–2) so the transfer-lane queue and landing-slab occupancy stay
    bounded — while small-message HOL p50 stays within ~10% of the
    uncontended baseline and large-stream goodput stays within ~5% of
    the pinned window (the drain rate, not the window, is the
    bottleneck). Pinned keeps the full window queued at the receiver and
    pays one control message per chunk; adaptive coalesces re-grants, so
    it also sends FEWER billed credit messages."""
    global _count, _target
    cfg = RuntimeConfig(memory_capacity=1 << 30,
                        eager_threshold=eager_threshold,
                        chunk_bytes=chunk_bytes)
    with Cluster(2, cfg, latency_s=latency_s,
                 bw_bytes_per_s=bw_bytes_per_s,
                 ctrl_drain_per_s=ctrl_drain_per_s) as cluster:
        r0, r1 = cluster.ranks
        r1.route_to("msgrate_congest_sink", 0)
        slow_on = threading.Event()
        _slow_receiver_transfers(r1.runtime, slow_on, slow_s)

        def one_stream(throttled: bool, measure: bool):
            _congest_done.clear()
            if throttled:
                slow_on.set()
            big = r0.runtime.hetero_object(
                np.ones(stream_bytes // 4, np.float32))
            t0 = time.perf_counter()
            r0.send(1, "msgrate_congest_sink", big)
            lat: List[float] = []
            while not _congest_done.is_set() and len(lat) < samples * 4:
                got = _one_small(cluster, small_bytes)
                if measure:
                    lat.append(got)
                # paced sampling: a back-to-back send loop saturates a
                # core on small hosts and perturbs the very stream (and
                # latencies) being measured; the baseline paces the same
                time.sleep(0.004)
            if not _congest_done.wait(120):
                raise TimeoutError("congestion stream timeout")
            t_stream = _congest_t1[0] - t0
            slow_on.clear()
            cluster.barrier()
            return lat, t_stream

        def arm(pinned: bool) -> Dict:
            cfg.net_window = pinned_window if pinned else None
            # clean A/B: forget the controller's sticky window from the
            # warm phase / previous arm, and reset the high-water marks
            cluster.topology.reset_window(0, 1)
            r0.stats["max_window"] = 0
            r1.stats["window_min"] = 0
            r1.stats["rx_queue_peak"] = 0
            base_rx = dict(r1.stats)
            base_ctrl = dict(cluster.ctrl_stats)
            meds, best_t, n = [], None, 0
            for _ in range(repeats):
                lat, t_stream = one_stream(throttled=True, measure=True)
                n += len(lat)
                if lat:
                    meds.append(float(np.median(lat)))
                if best_t is None or t_stream < best_t:
                    best_t = t_stream
            return {
                "p50_us": round(min(meds) * 1e6, 1) if meds else 0.0,
                "samples": n,
                "stream_s": round(best_t, 4),
                "goodput_MBps": round(stream_bytes / best_t / 1e6, 1),
                "window_adjusts": r1.stats["window_adjusts"]
                - base_rx["window_adjusts"],
                "credits_deferred": r1.stats["credits_deferred"]
                - base_rx["credits_deferred"],
                "window_min": r1.stats["window_min"],
                "rx_queue_peak": r1.stats["rx_queue_peak"],
                # high-water mark, not a counter: report as-is
                "max_window": r0.stats["max_window"],
                "ctrl_msgs": cluster.ctrl_stats["msgs"]
                - base_ctrl["msgs"],
                "ctrl_queued_ms": round(
                    (cluster.ctrl_stats["queued_s"]
                     - base_ctrl["queued_s"]) * 1e3, 3),
            }

        def measure_uncontended() -> float:
            # small p50 with no stream, no throttle (min of medians),
            # paced exactly like the loaded sampling loop
            meds = []
            for _ in range(repeats):
                un = []
                for _ in range(samples):
                    un.append(_one_small(cluster, small_bytes))
                    time.sleep(0.004)
                meds.append(float(np.median(un)))
            return min(meds)

        for _ in range(10):                   # compile + thread warmup
            _one_small(cluster, small_bytes)
        one_stream(throttled=False, measure=False)   # warm rendezvous
        # uncontended baseline, sampled BOTH before and after the arms
        # (min of the two): the host keeps warming up across the run, so
        # a single early baseline reads systematically slow and skews
        # the HOL ratios
        un_before = measure_uncontended()
        adaptive = arm(pinned=False)
        pinned = arm(pinned=True)
        p50_un = min(un_before, measure_uncontended()) * 1e6
        return {
            "small_bytes": small_bytes,
            "stream_bytes": stream_bytes,
            "chunk_bytes": chunk_bytes,
            "pinned_window": pinned_window,
            "slow_ms": slow_s * 1e3,
            "ctrl_drain_per_s": ctrl_drain_per_s,
            "ctrl_billed": ctrl_drain_per_s > 0,
            "repeats": repeats,
            "p50_uncontended_us": round(p50_un, 1),
            "adaptive": adaptive,
            "pinned": pinned,
            "hol_ratio_adaptive": round(adaptive["p50_us"] / p50_un, 4)
            if p50_un else None,
            "hol_ratio_pinned": round(pinned["p50_us"] / p50_un, 4)
            if p50_un else None,
            "goodput_ratio": round(adaptive["goodput_MBps"]
                                   / pinned["goodput_MBps"], 4)
            if pinned["goodput_MBps"] else None,
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default=None,
                    help="comma-separated payload bytes")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--latency-us", type=float, default=30.0)
    ap.add_argument("--bw-gbps", type=float, default=1.0,
                    help="simulated network bandwidth, GB/s")
    ap.add_argument("--eager-kb", type=int, default=64)
    ap.add_argument("--chunk-kb", type=int, default=None,
                    help="pin the rendezvous chunk size (default: "
                         "bandwidth-delay product from the measured link)")
    ap.add_argument("--hol", action="store_true",
                    help="run the MSG-HOL ladder: small-message p50 with "
                         "and without a concurrent large stream")
    ap.add_argument("--hol-samples", type=int, default=60)
    ap.add_argument("--congestion", action="store_true",
                    help="run the MSG-Congestion ladder: adaptive vs "
                         "pinned credit windows against a slowed "
                         "receiver transfer lane, billed control VC")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    if args.congestion:
        row = run_congestion(samples=args.hol_samples)
        print("name,us_per_call,derived")
        print(f"msgcongest_uncontended_{row['small_bytes']},"
              f"{row['p50_uncontended_us']:.1f},")
        for label in ("adaptive", "pinned"):
            a = row[label]
            print(f"msgcongest_{label}_{row['small_bytes']},"
                  f"{a['p50_us']:.1f},goodput{a['goodput_MBps']}MBps_"
                  f"ctrl{a['ctrl_msgs']}")
        print(f"msgcongest_summary,,hol_x{row['hol_ratio_adaptive']}_"
              f"goodput_x{row['goodput_ratio']}_"
              f"wmin{row['adaptive']['window_min']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.hol:
        row = run_hol(samples=args.hol_samples)
        print("name,us_per_call,derived")
        print(f"msghol_unloaded_{row['small_bytes']},"
              f"{row['p50_unloaded_us']:.1f},")
        print(f"msghol_loaded_{row['small_bytes']},"
              f"{row['p50_loaded_us']:.1f},x{row['ratio']:.3f}_"
              f"window{row['max_window']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    sizes = tuple(int(s) for s in args.sizes.split(",")) if args.sizes \
        else SIZES
    rows = run(sizes=sizes, iters=args.iters,
               latency_s=args.latency_us * 1e-6,
               bw_bytes_per_s=args.bw_gbps * 1e9,
               eager_threshold=args.eager_kb << 10,
               chunk_bytes=(args.chunk_kb << 10) if args.chunk_kb else None)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"msgrate_mono_{r['bytes']},{r['mono_us']:.1f},")
        print(f"msgrate_pipe_{r['bytes']},{r['pipe_us']:.1f},"
              f"{r['protocol']}_x{r['speedup']:.3f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
