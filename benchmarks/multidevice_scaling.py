"""Paper Fig. 9 — multi-device scaling with/without dedicated device threads.

Throughput of independent matmul tasks over 1/2/4 virtual devices, dedicated
threads on vs off. NOTE: this container exposes ONE physical core, so
speedups cannot exceed 1 for compute-bound work; what this benchmark
demonstrates on CPU is (a) work actually spreads across devices, (b) the
dedicated-thread dispatch path's overhead behaviour. On a real multi-chip
host the same harness exhibits the paper's near-linear scaling.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import Dict, List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _throughput(devices: int, dedicated: bool, n: int = 64,
                tasks: int = 120) -> Dict:
    code = f"""
        import numpy as np, time, json, collections
        from repro.core import Runtime, RuntimeConfig
        cfg = RuntimeConfig(scheduler='least_loaded',
                            dedicated_threads={dedicated},
                            memory_capacity=1 << 30)
        with Runtime(cfg) as rt:
            objs = [rt.hetero_object(np.random.rand({n}, {n}).astype(
                np.float32)) for _ in range(16)]
            outs = [rt.hetero_object(shape=({n}, {n}), dtype=np.float32)
                    for _ in range(16)]
            k = lambda a, o: (a @ a.T).astype(a.dtype)
            for i in range(16):
                rt.run(k, [(objs[i], 'r'), (outs[i], 'w')])
            rt.barrier()
            t0 = time.perf_counter()
            ts = []
            for i in range({tasks}):
                ts.append(rt.run(k, [(objs[i % 16], 'r'),
                                     (outs[i % 16], 'w')]))
            rt.barrier(timeout=600)
            dt = time.perf_counter() - t0
            used = collections.Counter(t.chosen_device for t in ts)
            print(json.dumps({{'tps': {tasks} / dt,
                               'devices_used': len(used)}}))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def run() -> List[Dict]:
    rows = []
    base = None
    for devices in (1, 2, 4):
        for dedicated in (False, True):
            r = _throughput(devices, dedicated)
            row = {"devices": devices, "dedicated_threads": dedicated,
                   "tasks_per_s": round(r["tps"], 1),
                   "devices_used": r["devices_used"]}
            if devices == 1 and dedicated:
                base = r["tps"]
            rows.append(row)
    for row in rows:
        row["speedup_vs_1dev"] = round(row["tasks_per_s"] / base, 2) \
            if base else None
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        tag = f"d{r['devices']}_{'ded' if r['dedicated_threads'] else 'nod'}"
        print(f"fig9_{tag},{1e6 / r['tasks_per_s']:.0f},"
              f"x{r['speedup_vs_1dev']};used{r['devices_used']}")


if __name__ == "__main__":
    main()
