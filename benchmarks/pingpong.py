"""Paper Fig. 10–12 — ping-pong latency/bandwidth between two ranks.

Paths measured per message size (8B – 8MB):
  raw          hand-written copy loop (the MPI+CUDA analogue)
  prema_send   hetero_object handler send (two-phase metadata+payload,
               host-staged; small messages inline — §4.2.3)
  prema_put    remote put into preallocated memory (§4.2.4)
The 'direct' variant models a device-aware interconnect by skipping the
host-staging copy (paper Fig. 11/12).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List

import numpy as np

from repro.core import Runtime, RuntimeConfig
from repro.distributed import Cluster, handler

_pong_evt = threading.Event()


@handler(name="bench_pong")
def _pong(ctx, obj):
    ctx.send(ctx.message.src, "bench_done", obj)


@handler(name="bench_done")
def _done(ctx, obj):
    _pong_evt.set()


@handler(name="bench_put_ack")
def _put_ack(ctx, obj):
    _pong_evt.set()


def bench_prema_send(cluster: Cluster, nbytes: int, iters: int,
                     path: str = "host") -> float:
    n = max(nbytes // 4, 1)
    rt0 = cluster.ranks[0].runtime
    lat = []
    for _ in range(iters):
        obj = rt0.hetero_object(np.zeros((n,), np.float32))
        _pong_evt.clear()
        t0 = time.perf_counter()
        cluster.ranks[0].send(1, "bench_pong", obj, path=path)
        _pong_evt.wait(30)
        lat.append((time.perf_counter() - t0) / 2)   # one-way
    return float(np.median(lat))


def bench_prema_put(cluster: Cluster, nbytes: int, iters: int) -> float:
    n = max(nbytes // 4, 1)
    rt0, rt1 = cluster.ranks[0].runtime, cluster.ranks[1].runtime
    target = rt1.hetero_object(np.zeros((n,), np.float32))
    cluster.ranks[1].register_object("bench_tgt", target)
    src = rt0.hetero_object(np.ones((n,), np.float32))
    lat = []
    for _ in range(iters):
        _pong_evt.clear()
        t0 = time.perf_counter()
        cluster.ranks[0].put(1, "bench_tgt", src, on_done="bench_put_ack")
        _pong_evt.wait(30)
        lat.append(time.perf_counter() - t0)
    return float(np.median(lat))


def bench_raw(nbytes: int, iters: int) -> float:
    """Hand-written transfer round trip (MPI+CUDA analogue). On this CPU
    container device==host, so jax.device_put can alias; the explicit
    .copy() calls stand in for the D2H / NIC / H2D byte movement a real
    MPI+CUDA ping-pong performs."""
    import jax
    n = max(nbytes // 4, 1)
    buf = np.zeros((n,), np.float32)
    lat = []
    for _ in range(iters):
        t0 = time.perf_counter()
        dev = jax.device_put(buf)
        back = np.array(dev)              # D2H copy
        dev2 = jax.device_put(back.copy())  # network + H2D copy
        dev2.block_until_ready()
        lat.append((time.perf_counter() - t0) / 2)
    return float(np.median(lat))


SIZES = (8, 64, 256, 1024, 8192, 65536, 1 << 20, 8 << 20)


def run(iters: int = 20) -> List[Dict]:
    rows = []
    with Cluster(2, RuntimeConfig(memory_capacity=1 << 30)) as cluster:
        for nbytes in SIZES:
            it = iters if nbytes < (1 << 20) else max(iters // 4, 3)
            r = {"bytes": nbytes,
                 "raw_us": bench_raw(nbytes, it) * 1e6,
                 "send_us": bench_prema_send(cluster, nbytes, it) * 1e6,
                 "direct_us": bench_prema_send(cluster, nbytes, it,
                                               path="direct") * 1e6,
                 "put_us": bench_prema_put(cluster, nbytes, it) * 1e6}
            r["send_vs_raw"] = r["send_us"] / r["raw_us"]
            r["direct_vs_send"] = r["send_us"] / r["direct_us"]
            r["put_vs_raw"] = r["put_us"] / r["raw_us"]
            r["put_bw_MBs"] = nbytes / r["put_us"] * 1e6 / 1e6
            rows.append(r)
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run():
        print(f"fig10_raw_{r['bytes']},{r['raw_us']:.1f},")
        print(f"fig10_send_{r['bytes']},{r['send_us']:.1f},"
              f"x{r['send_vs_raw']:.2f}")
        print(f"fig11_direct_{r['bytes']},{r['direct_us']:.1f},"
              f"hostvsdirect_x{r['direct_vs_send']:.2f}")
        print(f"fig10_put_{r['bytes']},{r['put_us']:.1f},"
              f"x{r['put_vs_raw']:.2f};{r['put_bw_MBs']:.0f}MB/s")


if __name__ == "__main__":
    main()
