"""INTEG-Recover rung: end-to-end data integrity on the distributed
Jacobi proxy (ISSUE tentpole — checksummed transfers, corruption
injection, lineage/replica recovery).

Four arms, all on a simulated network with a billed control VC:

  clean — 4 ranks, per-iteration slab replication to a ring buddy, no
      faults. The oracle baseline every other arm is compared against.

  corrupt — the SAME run under seeded wire corruption (every directed
      link bit-flips host-staged payloads with p=0.05), two injected
      kernel faults (absorbed by ``task_retries``), a rank killed after
      an iteration commits AND that iteration's checkpoint leaf for one
      of the dead rank's slabs bit-flipped on disk. Recovery prefers the
      live replica, the checksum layer rejects every flipped payload and
      the reliability layer retransmits — the run must finish with ZERO
      hangs and an answer bit-identical to the clean arm, with
      checksum_fail/chunks_rejected/retries all nonzero as evidence the
      corruption actually happened.

  ckpt_fallback — no replication: the killed rank's slab can only come
      from the checkpoint, whose newest copy of that leaf is corrupted.
      The digest-validated restore DETECTS the corruption
      (ckpt_verify_fail ≥ 1) and falls back to the next-older committed
      step instead of feeding garbage back in. The run completes (answer
      rolls back one committed iteration for that slab — correctness
      here is "detected + degraded gracefully", not bit-identity).

  verify_overhead — msgrate's A/B with ``cfg.verify_payloads`` flipped
      per batch on one cluster: the clean-path cost of the fold64
      digest at eager and rendezvous sizes (claim: within ~5% at the
      MSG-Pipeline large size).

Run via ``tasking_overhead.py --only INTEG-Recover`` (the dry-run sweep
does this) or directly: ``python benchmarks/integ_recover.py``.
"""
import argparse
import json
import tempfile
import time
from typing import Dict

import numpy as np

from repro.core import RuntimeConfig
from repro.distributed import Cluster
from repro.apps.jacobi3d import run_cluster_elastic, run_reference

_NET = dict(latency_s=100e-6, bw_bytes_per_s=4e9, ctrl_drain_per_s=2e5)


def _cfg() -> RuntimeConfig:
    # task_retries: the corrupt arm plants kernel faults that must be
    # absorbed by retry, not surfaced. chunk_bytes pinned small so each
    # slab streams as several chunks — more corruptible wire crossings
    # per run, so the seeded flips reliably hit the chunk path too.
    return RuntimeConfig(memory_capacity=1 << 26, task_retries=2,
                         chunk_bytes=64 << 10,
                         retry_backoff_s=0.02, retry_tick_s=0.002)


def run_integ(n: int = 64, iters: int = 6, ranks: int = 4,
              corrupt_p: float = 0.1, seed: int = 7) -> Dict:
    rng = np.random.default_rng(0)
    # slab size must clear the eager threshold so replication/scatter
    # travel as host-staged rendezvous streams — the corruptible path
    u0 = rng.standard_normal((n, n // 2, n // 2)).astype(np.float32)
    row: Dict = {"n": n, "iters": iters, "ranks": ranks,
                 "corrupt_p": corrupt_p, "ctrl_billed": True}

    kill_rank, kill_it = ranks - 2, 2
    revive_it = max(kill_it + 1, min(iters - 2, kill_it + 2))
    bad_leaf = f"slab{kill_rank}"        # owned by the rank about to die

    # -- clean arm: replication on, no faults ---------------------------
    t0 = time.perf_counter()
    with Cluster(ranks, _cfg(), **_NET) as c:
        clean, rep_clean = run_cluster_elastic(u0, iters, c, replicate=True)
    row["clean"] = {
        "wall_s": round(time.perf_counter() - t0, 4),
        "integrity": rep_clean["integrity"],
    }
    ref = run_reference(u0, iters)
    row["oracle_ok"] = bool(np.allclose(clean, ref, rtol=1e-5, atol=1e-6))

    # -- corrupt arm: wire flips + kernel faults + kill + bad leaf ------
    with tempfile.TemporaryDirectory() as ckpt_dir:
        t0 = time.perf_counter()
        with Cluster(ranks, _cfg(), **_NET) as c:
            fi = c.fault_injector(seed=seed)
            fi.fail_task(1, times=2)
            out, rep = run_cluster_elastic(
                u0, iters, c, ckpt_dir=ckpt_dir, replicate=True,
                corrupt_links=corrupt_p,
                kill=(kill_rank, kill_it),
                revive_at=(kill_rank, revive_it),
                corrupt_leaf_at=(kill_it, bad_leaf),
                heartbeat_interval_s=0.02, heartbeat_timeout_s=0.4)
            wall = time.perf_counter() - t0
            fi_stats = dict(fi.stats)
    e = rep["elastic"]
    row["corrupt"] = {
        "wall_s": round(wall, 4),
        "killed_rank": kill_rank, "kill_iter": kill_it,
        "corrupted_leaf": bad_leaf,
        "recoveries": e["recoveries"], "grows": e["grows"],
        "dead_detected": e["dead"],
        "recovery_stall_s": round(e["recovery_stall_s"], 6),
        "bytes_migrated": e["bytes_migrated"],
        "epochs": rep["epochs"],
        "faults": fi_stats,
        "integrity": rep["integrity"],
        "bitwise_identical": bool(np.array_equal(out, clean)),
    }

    # -- ckpt_fallback arm: corrupted leaf with NO replica --------------
    with tempfile.TemporaryDirectory() as ckpt_dir:
        t0 = time.perf_counter()
        with Cluster(ranks, _cfg(), **_NET) as c:
            fi = c.fault_injector(seed=seed + 1)
            out, rep = run_cluster_elastic(
                u0, iters, c, ckpt_dir=ckpt_dir, replicate=False,
                kill=(kill_rank, kill_it),
                corrupt_leaf_at=(kill_it, bad_leaf),
                heartbeat_interval_s=0.02, heartbeat_timeout_s=0.4)
        wall = time.perf_counter() - t0
    row["ckpt_fallback"] = {
        "wall_s": round(wall, 4),
        "recoveries": rep["elastic"]["recoveries"],
        "integrity": rep["integrity"],
        "corruption_detected":
            rep["integrity"]["ckpt_verify_fail"] >= 1,
        "completed": bool(np.isfinite(out).all()),
    }

    # -- verify_overhead arm: fold64 digest cost A/B --------------------
    import msgrate   # benchmarks/ is on sys.path as a script
    overhead = msgrate.run_verify_overhead(
        sizes=(8 << 10, 4 << 20), iters=8,
        latency_s=30e-6, bw_bytes_per_s=4e9)
    row["verify_overhead"] = overhead
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--corrupt-p", type=float, default=0.05)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    row = run_integ(n=args.n, iters=args.iters, ranks=args.ranks,
                    corrupt_p=args.corrupt_p)
    print(json.dumps(row, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(row, f, indent=2)


if __name__ == "__main__":
    main()
