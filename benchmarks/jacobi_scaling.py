"""Paper Fig. 13/15 — Jacobi3D strong/weak scaling and over-decomposition.

Strong/weak scaling run the SPMD production path on 1/2/4 virtual devices in
subprocesses (bulk_sync=True is the MPI+CUDA-style schedule; False lets XLA
overlap halo transfers with interior compute). Over-decomposition levels run
the PREMA-tasked path on the in-process runtime (Fig. 15).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap
import time
from typing import Dict, List

import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spmd_time(devices: int, domain, iters: int, bulk_sync: bool) -> float:
    code = f"""
        import numpy as np, time, jax
        from repro.apps.jacobi3d import make_spmd_step
        from jax.sharding import NamedSharding, PartitionSpec as PS
        import jax.numpy as jnp
        mesh = jax.make_mesh(({devices},), ('data',))
        step = make_spmd_step(mesh, 'data', bulk_sync={bulk_sync})
        rng = np.random.default_rng(0)
        u = jax.device_put(jnp.asarray(rng.random({tuple(domain)},
                           dtype=np.float32)), NamedSharding(mesh, PS('data')))
        u = step(u); u.block_until_ready()          # compile
        t0 = time.perf_counter()
        for _ in range({iters}):
            u = step(u)
        u.block_until_ready()
        print((time.perf_counter() - t0) / {iters})
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    return float(out.stdout.strip().splitlines()[-1])


def run_scaling(domain=(64, 64, 64), iters=10) -> List[Dict]:
    rows = []
    for devices in (1, 2, 4):
        t_sync = _spmd_time(devices, domain, iters, True)
        t_ovl = _spmd_time(devices, domain, iters, False)
        rows.append({"mode": "strong", "devices": devices,
                     "domain": list(domain),
                     "bulk_sync_ms": t_sync * 1e3,
                     "overlap_ms": t_ovl * 1e3,
                     "overlap_gain": t_sync / t_ovl})
        wdomain = (domain[0] * devices, domain[1], domain[2])
        t_sync = _spmd_time(devices, wdomain, iters, True)
        t_ovl = _spmd_time(devices, wdomain, iters, False)
        rows.append({"mode": "weak", "devices": devices,
                     "domain": list(wdomain),
                     "bulk_sync_ms": t_sync * 1e3,
                     "overlap_ms": t_ovl * 1e3,
                     "overlap_gain": t_sync / t_ovl})
    return rows


def run_overdecomposition(domain=(32, 32, 32), iters=4) -> List[Dict]:
    from repro.core import Runtime, RuntimeConfig
    from repro.apps.jacobi3d import run_tasked
    rng = np.random.default_rng(0)
    u0 = rng.random(domain).astype(np.float32)
    rows = []
    for od in (1, 2, 4):
        with Runtime(RuntimeConfig(memory_capacity=1 << 30)) as rt:
            run_tasked(u0, 1, rt, over_decomposition=od)   # warm compile
            t0 = time.perf_counter()
            run_tasked(u0, iters, rt, over_decomposition=od)
            dt = (time.perf_counter() - t0) / iters
        rows.append({"od": od, "ms_per_iter": dt * 1e3})
    return rows


def run_transfer_engine(domain=(32, 32, 32), iters=4, od=4) -> List[Dict]:
    """Transfer-engine ablation on the tasked Jacobi pipeline: the paper's
    §4.1.3 overlap (argument prefetch) and §3.2.3 direct D2D path, on vs
    off, on the over-decomposed PREMA schedule."""
    from repro.core import Runtime, RuntimeConfig
    from repro.apps.jacobi3d import run_tasked
    rng = np.random.default_rng(0)
    u0 = rng.random(domain).astype(np.float32)
    rows = []
    for label, kw in (("off", dict(d2d=False, prefetch=False)),
                      ("prefetch", dict(d2d=False, prefetch=True)),
                      ("prefetch_d2d", dict(d2d=True, prefetch=True))):
        with Runtime(RuntimeConfig(memory_capacity=1 << 30, **kw)) as rt:
            run_tasked(u0, 1, rt, over_decomposition=od)   # warm compile
            t0 = time.perf_counter()
            run_tasked(u0, iters, rt, over_decomposition=od)
            dt = (time.perf_counter() - t0) / iters
            stats = rt.stats()
        rows.append({"cfg": label, "ms_per_iter": dt * 1e3,
                     # staged = claimed-early copies, hit or stalled
                     "prefetch_staged": stats["prefetch_hits"]
                     + stats["prefetch_stalls"],
                     "transfers_d2d": stats["transfers_d2d"]})
    return rows


def main():
    print("name,us_per_call,derived")
    for r in run_scaling():
        print(f"fig13_{r['mode']}_d{r['devices']}_sync,"
              f"{r['bulk_sync_ms'] * 1e3:.0f},")
        print(f"fig13_{r['mode']}_d{r['devices']}_overlap,"
              f"{r['overlap_ms'] * 1e3:.0f},gain_x{r['overlap_gain']:.2f}")
    for r in run_overdecomposition():
        print(f"fig15_od{r['od']},{r['ms_per_iter'] * 1e3:.0f},")
    for r in run_transfer_engine():
        print(f"xfer_{r['cfg']},{r['ms_per_iter'] * 1e3:.0f},"
              f"pf{r['prefetch_staged']}_d2d{r['transfers_d2d']}")


if __name__ == "__main__":
    main()
