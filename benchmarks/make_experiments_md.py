"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep
JSONs (the §Perf narrative lives in the template below, with numbers pulled
from perf_report.json). Re-run after refreshing the sweeps:

    python benchmarks/run_dryrun_sweep.py --multi-pod --probes
    python benchmarks/run_hillclimb.py
    python benchmarks/perf_report.py
    python benchmarks/make_experiments_md.py
"""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.configs import (ARCH_IDS, ALL_SHAPES, get_config,  # noqa: E402
                           shapes_for)
from repro.launch import roofline as R  # noqa: E402

DRY = os.path.join(REPO, "benchmarks", "results", "dryrun")


def _load(tag):
    p = os.path.join(DRY, tag + ".json")
    if not os.path.exists(p):
        return None
    d = json.load(open(p))
    return d


def dryrun_table():
    lines = [
        "| arch | shape | mesh 16×16 (256) | mesh 2×16×16 (512) | "
        "compile s (1-pod) | args GB/dev | temp GB/dev | collectives/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    n_pass = n_skip = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        supported = {s.name for s in shapes_for(cfg)}
        for shape in ALL_SHAPES:
            if shape.name not in supported:
                lines.append(
                    f"| {arch} | {shape.name} | SKIP | SKIP | — | — | — | "
                    f"full-attention arch: long_500k inapplicable "
                    f"(DESIGN §4) |")
                n_skip += 1
                continue
            d1 = _load(f"{arch}__{shape.name}__pod1__baseline")
            d2 = _load(f"{arch}__{shape.name}__pod2__baseline")
            ok1 = d1 is not None and "error" not in d1
            ok2 = d2 is not None and "error" not in d2
            n_pass += 1 if (ok1 and ok2) else 0
            coll = d1.get("collective_bytes_per_device", {}) if ok1 else {}
            coll_s = ", ".join(f"{k.split('-')[-1][:4]}:{v/1e9:.2f}G"
                               for k, v in coll.items() if v > 0) or "none"
            lines.append(
                f"| {arch} | {shape.name} | "
                f"{'PASS' if ok1 else 'FAIL'} | {'PASS' if ok2 else 'FAIL'} | "
                f"{d1.get('compile_s', '—') if ok1 else '—'} | "
                f"{(d1.get('argument_size_in_bytes', 0)/1e9):.2f} | "
                f"{(d1.get('temp_size_in_bytes', 0)/1e9):.1f} | {coll_s} |")
    return "\n".join(lines), n_pass, n_skip


def roofline_table():
    rows = R.build_table(DRY, "baseline")
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | "
        "MODEL/analytic FLOPs | roofline frac | what would move the "
        "dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.1f} ms | "
            f"{r['t_memory_s']*1e3:.1f} ms | {r['t_collective_s']*1e3:.1f} ms"
            f" | **{r['bottleneck']}** | {r['model_vs_analytic']:.2f} | "
            f"{(r['roofline_fraction'] or 0)*100:.1f}% | {r['hint']} |")
    return "\n".join(lines), rows


def opt_comparison_table():
    """Baseline vs opt-level step bound for every cell with both results."""
    base = {(r["arch"], r["shape"]): r for r in R.build_table(DRY, "baseline")}
    opt = {(r["arch"], r["shape"]): r for r in R.build_table(DRY, "opt")}
    if not opt:
        return "(opt-level sweep not yet run)"
    lines = [
        "| arch | shape | baseline bound | opt bound | speedup | baseline "
        "roofline | opt roofline | opt bottleneck |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in base:
        if key not in opt:
            continue
        b, o = base[key], opt[key]
        sp = b["step_time_bound_s"] / o["step_time_bound_s"] \
            if o["step_time_bound_s"] else float("nan")
        lines.append(
            f"| {key[0]} | {key[1]} | {b['step_time_bound_s']*1e3:.1f} ms | "
            f"{o['step_time_bound_s']*1e3:.1f} ms | **{sp:.2f}×** | "
            f"{(b['roofline_fraction'] or 0)*100:.1f}% | "
            f"{(o['roofline_fraction'] or 0)*100:.1f}% | "
            f"{o['bottleneck']} |")
    return "\n".join(lines)


def perf_tables():
    p = os.path.join(REPO, "benchmarks", "results", "perf_report.json")
    if not os.path.exists(p):
        return {}
    return json.load(open(p))


def fmt_perf(rows):
    lines = [
        "| variant | t_compute | t_memory | t_collective | bottleneck | "
        "step bound | roofline frac | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        bound = max(r["t_compute_ms"], r["t_memory_ms"],
                    r["t_collective_ms"])
        lines.append(
            f"| {r['variant']} | {r['t_compute_ms']:.1f} ms | "
            f"{r['t_memory_ms']:.1f} ms | {r['t_collective_ms']:.1f} ms | "
            f"{r['bottleneck']} | {bound:.1f} ms | {r['roofline_pct']:.1f}% |"
            f" {r['temp_GB']:.1f} |")
    return "\n".join(lines)


def main():
    dry, n_pass, n_skip = dryrun_table()
    roof, roof_rows = roofline_table()
    perf = perf_tables()

    def cell(name):
        return fmt_perf(perf.get(name, []))

    md = TEMPLATE.format(dryrun_table=dry, n_pass=n_pass, n_skip=n_skip,
                         roofline_table=roof,
                         opt_table=opt_comparison_table(),
                         gemma=cell("gemma3_27b__train_4k"),
                         pixtral=cell("pixtral_12b__decode_32k"),
                         mamba=cell("mamba2_370m__train_4k"),
                         breadth="\n\n".join(
                             f"**{k}**\n\n{fmt_perf(v)}"
                             for k, v in perf.items()
                             if k.endswith("decode_32k")
                             and not k.startswith("pixtral")))
    with open(os.path.join(REPO, "EXPERIMENTS.md"), "w") as f:
        f.write(md)
    print("wrote EXPERIMENTS.md")


TEMPLATE = """# EXPERIMENTS

Reproduction of *"Runtime Support for Performance Portability on
Heterogeneous Distributed Platforms"* (Thomadakis & Chrisochoides, 2023) as a
TPU-pod-scale JAX framework, plus the assigned 10-architecture × 4-shape
grid. Hardware target: TPU v5e pods — 197 TFLOP/s bf16, 819 GB/s HBM,
50 GB/s/link ICI per chip (constants from the brief). This container is
CPU-only: all performance numbers below are derived from compiled AOT
artifacts (the dry-run), not wall clocks, except the paper-behaviour
benchmarks (Fig. 8/9/10/13/15 analogues) which run natively on CPU — see
`bench_output.txt`.

## Method note — corrected cost accounting

`compiled.cost_analysis()` counts every while-loop body ONCE regardless of
trip count (verified with a controlled probe: a scan of 1/2/8 matmuls reports
identical FLOPs). Everything under `lax.scan` — the layer stack, flash
attention's q/kv block loops, the chunked-CE loss, microbatch accumulation —
is undercounted. Corrections applied (implemented in
`src/repro/launch/roofline.py`, probe lowerings produced by
`launch/dryrun.py --probe {{0,1}}`):

1. **Layer-scan probe correction**: lower the model with 0 layers (M0) and
   with exactly 1 period (M1); per-period body cost = M1 − M0; corrected =
   M_full + (n_periods − 1)·(M1 − M0). Applied to FLOPs, HBM bytes and
   per-type collective bytes.
2. **Flash/loss scans**: trip counts and block shapes are static, so the
   uncounted work is added analytically ((trips−1) × body cost).
3. **Compute term** uses an exact analytic FLOP model of the executed math
   (einsum-by-einsum, incl. capacity-based MoE and chunked SSD;
   ×4 for training with full remat, ×3.3 with dots-saveable remat);
   probe-corrected HLO FLOPs are kept as a cross-check column.
4. **Memory-term caveat**: "bytes accessed" comes from the **CPU** backend,
   which fuses far less than TPU; the memory terms are therefore upper
   bounds, and relative deltas between variants are the meaningful signal.
   Similarly, dynamic-update-slice on CPU is counted as a whole-buffer copy,
   inflating decode-cache traffic that is in-place on TPU.

## §Dry-run — 40 cells × 2 meshes

Meshes per the brief: single-pod `(data=16, model=16)` = 256 chips and
multi-pod `(pod=2, data=16, model=16)` = 512 chips;
`jax.jit(step).lower(...).compile()` with
`--xla_force_host_platform_device_count=512`. PASS = lower+compile succeeded
and memory/cost analyses extracted. {n_pass} cells pass on both meshes;
{n_skip} long_500k cells are skipped by design for pure full-attention
architectures (noted in DESIGN.md §4) — 40 cells accounted for.
`train_4k` lowers `train_step` (AdamW + ZeRO-1, donated state);
`prefill_32k` lowers `prefill_step`; `decode_*`/`long_*` lower `serve_step`
(one token against a seq_len-sized KV cache, donated).

{dryrun_table}

Full per-cell JSON (incl. collective-schedule breakdown, memory analysis,
HLO line counts): `benchmarks/results/dryrun/`.

## §Roofline — single-pod mesh, paper-faithful baseline

Baseline lowering = paper-faithful schedule: full activation remat,
synchronous gradient reduction, no sequence parallelism, od=1
(no over-decomposition). MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D
(decode & prefill forward).

{roofline_table}

Reading: the paper-faithful baseline is **memory-bound nearly everywhere**
(full remat re-streams every activation; CPU-backend fusion pessimism
inflates absolutes but not the ordering), and **collective-bound** exactly
where GQA KV heads do not divide the 16-way model axis (yi kv=4,
pixtral/llama4 kv=8 decode: GSPMD inserts a per-layer KV-cache all-gather)
and where MoE dispatch dominates (olmoe train).

### Optimized level (beyond-paper) — whole-grid comparison

`--opt-level opt` applies the hillclimb winners grid-wide: sequence-parallel
activations (`act_seq → model`), full remat, and seq-sharded KV decode for
kv-head-replicated architectures. Step bound = max of the three terms.

{opt_table}

The optimized lowering is **not uniformly better** — SP regresses
recurrentgemma training 0.75× (the RG-LRU associative scan needs the whole
sequence per shard, so GSPMD round-trips the activations) and several
prefill cells 0.6–0.9× (their baselines are activation-light, so SP's
all-gathers outweigh its bandwidth savings). The production answer is
per-cell configuration selection from this table —
`repro.launch.autotune` materializes it: **17/33 cells pick `opt`,
16 keep `baseline`, geomean step-bound speedup 1.97× over the
always-paper-faithful lowering** (`benchmarks/results/tuned_configs.json`,
consumed by the launchers).

## §Perf — hillclimbing (hypothesis → change → measure → validate)

Three cells selected per the brief: worst roofline fraction
(mamba2_370m×train_4k, 0.5%), most collective-bound
(pixtral_12b×decode_32k), most representative of the paper's technique
(gemma3_27b×train_4k — over-decomposition applies to the training pipeline
directly). The paper-faithful baseline row is the reproduction; subsequent
rows are the beyond-paper optimization ladder. "step bound" =
max(compute, memory, collective) — the roofline lower bound on step time.

### Cell 1 — gemma3_27b × train_4k (paper's technique + beyond)

{gemma}

Iteration log:
1. **od2/od4 (paper-faithful over-decomposition).** Hypothesis: identical
   math ⇒ flat roofline terms, but peak live memory drops ≈ od× because only
   one microbatch's activations are alive; collectives overlap behind the
   next microbatch's compute (the paper's Fig. 14 pipeline, in XLA's
   latency-hiding scheduler). **Confirmed**: terms flat (memory +0.3%/+1.1%
   from od× weight re-reads), temp 88.6 → 49.2 → 28.0 GB/device. This is the
   paper's claim transposed exactly: over-decomposition is a
   capacity/latency-hiding lever, not a bandwidth lever.
2. **dots remat.** Hypothesis: dropping the recompute forward cuts compute
   ×4→×3.3 and HBM traffic ~20%. **Half-refuted**: compute −17.5% as
   predicted, but HBM traffic barely moved (−2%, recompute reads are a small
   slice of the CPU-counted traffic) and live temp exploded 88.6 → 294 GB
   (saved dot outputs) — the wrong direction for a capacity-limited cell.
   Lesson recorded: with 16 GB/chip, full remat + SP beats dots remat.
3. **dots_sp (sequence parallelism).** Hypothesis: sharding layer-boundary
   activations 16× over the model axis cuts the dominant memory term ≈3×
   (residual-stream traffic dominates). **Confirmed**: memory 36.4 → 14.2 s
   (−61%); cost: +8.1 s collectives (per-layer all-gather/reduce-scatter) —
   the cell flips to collective-bound. Step bound 37.1 → 15.2 s (2.4×),
   roofline fraction 12.6% → 25.5%.
4. **dots_sp_od4.** Hypothesis: od should not change totals. **Refuted**:
   collective volume ≈ doubled — with microbatches 4× smaller, per-layer
   activations drop below the weight-gather crossover and GSPMD re-gathers
   weights every microbatch. Genuine scale lesson: over-decomposition must
   keep microbatch × seq above the weight/activation crossover, or switch to
   weight-stationary scheduling.
5. **sp / sp_od4 / sp_od8 (full remat + SP).** Hypothesis: combine SP's
   bandwidth win with full remat's low live memory; over-decomposition then
   walks temp toward the 16 GB budget. **Confirmed for the bound, partially
   for capacity**: `sp` is the best step bound (15.2 s, 2.4× over baseline,
   30.9% of roofline) at 61.8 GB temp; od4/od8 shrink temp 61.8 → 39.0 →
   34.4 GB but with diminishing returns — each halving of the microbatch
   adds a full round of per-microbatch weight gathers (the crossover effect
   from iteration 4), so od8's bound regresses to 44.3 s. Deployable
   configuration: `sp` + od4 at batch-per-device 4 (or a 32-wide data axis),
   trading DP width for capacity; the remaining distance to 16 GB is an
   optimizer-state-offload / fused-loss follow-up, napkin-mathed at −14 GB.

### Cell 2 — pixtral_12b × decode_32k (most collective-bound)

{pixtral}

Iteration log:
1. **Diagnosis.** Per-layer probe deltas isolate a 2.15 GB/layer all-gather:
   kv=8 heads cannot shard over the 16-way model axis, so the cache is
   replicated per-shard; with q heads sharded, GSPMD aligns shardings by
   all-gathering the KV cache every layer (85.9 GB/device/step). phi4
   (q also unshardable) instead reads the full cache locally — same root
   cause, different symptom.
2. **kvseq_model (beyond-paper: sequence-sharded KV decode).** Hypothesis:
   shard the cache on the *sequence* dim over the model axis and combine
   partial attention with a logsumexp psum (O(B·H·D) per layer ≪ O(B·T·K·D)
   gather); cache HBM footprint also ÷16. **Confirmed**: collective term
   3393.6 → 1.4 ms (≈2400×), memory term 812 → 97 ms, step bound 3394 → 97 ms
   (**35×**), temp 86 → 10.6 GB/device (now fits a v5e chip).
3. **Residual memory analysis.** The remaining 97 ms is dominated by the
   CPU-backend DUS-as-full-copy artifact (§Method 4); on TPU the update is
   in-place and the true bound approaches cache-read time
   (2·B·T·K·D / 16 ≈ 2.1 GB ⇒ ~2.6 ms/step/chip). Three further variants
   (int8 cache, fused rope+DUS, paged cache) were napkin-mathed at <5%
   each on top of the TPU-corrected bound — stopping per the <5%×3 rule.

### Cell 3 — mamba2_370m × train_4k (worst roofline fraction)

{mamba}

Iteration log:
1. **Diagnosis.** 370M params ⇒ no tensor-parallel mapping (DESIGN §4):
   model axis idle, every shard re-streams f32 SSD intermediates; the decay
   matrix L [b,c,h,q,q] (q=256) dominates traffic.
2. **dots remat.** Same half-refutation as gemma3: compute −17%, memory flat,
   temp ×2.5. Recorded, reverted.
3. **ssd_chunk128.** Hypothesis: decay-matrix traffic scales ∝ q
   (c·q² with c=S/q), so chunk 256→128 halves that component at equal FLOPs.
   **Confirmed in direction, small in magnitude**: memory 14.81 → 13.78 s
   (−7%) — L is a smaller slice of the CPU-counted traffic than estimated;
   the f32 x/B/C/state streams dominate. Lesson: the decay matrix was the
   wrong first target.
4. **ssd_chunk128_dots_sp.** Hypothesis: with SP (`act_seq → model`), the
   4096-token sequence splits into 16 × 256-token shards — exactly one SSD
   chunk per shard, so the *entire intra-chunk computation parallelizes over
   the model axis* (context parallelism for SSMs; only the tiny inter-chunk
   state recurrence crosses shards). **Strongly confirmed**: memory term
   14.81 → 1.72 s (−88%), step bound 14.81 → 1.72 s (**8.6×**), roofline
   fraction 0.5% → 3.3%, now balanced memory/collective. The arch-
   applicability note in DESIGN §4 is thereby refined: mamba2 has no
   *tensor*-parallel mapping, but an excellent *sequence*-parallel one —
   a finding the dry-run methodology surfaced.

### Breadth: the kvseq_model fix across every kv-replicated architecture

{breadth}

## Paper-claims validation (CPU-native benchmarks)

See `bench_output.txt` (generated by `python -m benchmarks.run`):

- **Fig. 8 ladder** (`tasking_overhead`): each optimization stage
  (page-locked staging pool → jit-cache/donation → request pools → transfer
  thread → multi-queue) improves matmul task throughput; on this CPU
  container the full ladder reaches 1.2–1.9× over the unoptimized runtime
  (64×64: 592 → 387 µs/task, 1.53×; larger sizes compute-dominated).
  The paper reports up to 4× on V100s, where transfer overheads are far
  larger — same ladder shape, different hardware constants.
- **Fig. 9** (`multidevice_scaling`): work spreads across all virtual
  devices with dedicated per-device threads; wall-clock speedup is
  impossible on 1 physical core (documented in-module).
- **Fig. 10–12** (`pingpong`): small-message handler sends land at
  0.8–1.4× the hand-written transfer loop (paper: within 10–15% of
  MPI+CUDA), and the put path beats it at every size (0.5–0.7×; paper: put
  wins by up to 20% for large messages). The device-aware "direct" path
  beats host-staging by 1.7–2.3× for ≥1 MB messages (paper Fig. 12: up to
  2–3× for large messages) — the same ordering, reproduced.
- **Fig. 13/15** (`jacobi_scaling`): bulk-synchronous (MPI-like) vs
  overlapped SPMD halo exchange, strong/weak scaling over 1/2/4 virtual
  devices; over-decomposition levels 1/2/4 on the tasked runtime.

## Reproduction status vs the paper's claims

| Paper claim | Status |
|---|---|
| Implicit dependency + coherence correctness | ✓ property-tested (random DAGs ≡ sequential) |
| Optimization ladder improves single-device throughput | ✓ ladder reproduced on CPU (magnitudes hardware-scaled) |
| Dedicated threads per device enable multi-device scaling | ✓ semantics (spread + linear task placement); wall-clock N/A on 1 core |
| Messaging within ~10–15% of hand-written; put wins large | ✓ small ≤1.2×, put ≤1× for most sizes |
| Over-decomposition improves end-to-end Jacobi | ✓ pipeline semantics + capacity effect measured in both the tasked app and the LM trainer (temp −68% at od4) |
| Scales to distributed heterogeneous nodes | ✓ dry-run: 33/33 runnable cells compile on 256- and 512-chip meshes |
| Fault tolerance at scale (beyond paper) | ✓ end-to-end elastic training: lose half the mesh mid-run → shrink → restore → continue, loss-identical to an uninterrupted run (tests/test_elastic_train.py); bit-exact checkpoint restart; straggler drain plans |
| Distributed-optimization tricks (brief) | ✓ microbatch compute/collective overlap, ZeRO-1, int8+EF cross-pod gradient compression (convergence-validated; 512-chip lowering limitation documented in DESIGN §5) |
"""


if __name__ == "__main__":
    main()
