"""COLL-Allreduce rung: topology-aware runtime collectives (ISSUE 9).

Four arms on one simulated network (per-link latency/bandwidth, billed
control VC) — the same 4-rank cluster end to end so link EWMAs warm up:

  large — ~4 MiB float32 allreduce: pipelined chunked ring
      (reduce-scatter + allgather on rendezvous streams, per-hop adds
      fused on the consumer's transfer lane) vs the naive baseline every
      MPI tutorial starts from — sequentially send every vector to the
      root, add, sequentially scatter the sum back. The ring moves
      2·(R-1)/R of the payload per member over R concurrent links where
      the naive path moves 2·(R-1) payloads over the root's single NIC,
      so the claim is ≥ 1.5× (paper §headline: beating point-to-point
      staging by pipelining).

  small — ~1 KiB allreduce, median of many iterations: the eager
      binomial-tree arm vs the same naive baseline. Claim: small-message
      overhead within 10% (the tree costs ~log₂R latencies vs the
      naive path's 2·(R-1), so it is usually *faster*; the bound guards
      the protocol's fixed cost).

  bitwise — engine result vs ``oracle_allreduce`` (the single-threaded
      numpy replay of the exact reduction schedule): must be equal bit
      for bit, large and small.

  kill — a rank black-holed then killed mid-collective; the elastic
      epoch bump aborts the collective cleanly (CollectiveAborted, no
      hang, no restart), and after revive + peer-state sweep the SAME
      group re-runs to a bit-exact result.

Run via ``tasking_overhead.py --only COLL-Allreduce`` (the dry-run sweep
does this) or directly: ``python benchmarks/coll_allreduce.py``.
"""
import argparse
import json
import threading
import time
from typing import Dict

import numpy as np

from repro.core import RuntimeConfig
from repro.distributed import Cluster, CollectiveAborted, CollectiveGroup
from repro.distributed.handlers import handler

# 100 MB/s links: wire serialization dominates host-side protocol cost,
# which is the regime the ring-vs-root claim is about — the naive path
# pushes 2·(R-1) full payloads through the root's single link while the
# ring keeps every link busy with 1/R-sized segments concurrently.
_NET = dict(latency_s=100e-6, bw_bytes_per_s=1e8, ctrl_drain_per_s=2e5)

_naive: Dict[str, Dict] = {}
_naive_lock = threading.Lock()


@handler(name="coll_naive_part")
def _naive_part(ctx, obj):
    st = _naive[ctx.message.user["run"]]
    with st["lock"]:
        st["parts"][ctx.message.user["src"]] = np.asarray(obj.get())
        st["part_evt"].set()


@handler(name="coll_naive_out")
def _naive_out(ctx, obj):
    st = _naive[ctx.message.user["run"]]
    with st["lock"]:
        st["outs"][ctx.rank.rank] = np.asarray(obj.get())
        st["out_evt"][ctx.rank.rank].set()


def naive_allreduce(cluster, arrs, run_id: str):
    """The sequential send-to-root-and-scatter strawman, built from the
    SAME messaging primitives the engine uses: each member's vector
    travels to rank members[0] one at a time (each waited for before the
    next starts), the root adds in member order, then the sum travels
    back out one member at a time."""
    ranks = cluster.ranks
    root = 0
    st = {"lock": threading.Lock(), "parts": {},
          "part_evt": threading.Event(),
          "outs": {}, "out_evt": {r.rank: threading.Event()
                                  for r in ranks}}
    with _naive_lock:
        _naive[run_id] = st
    try:
        for i in range(1, len(ranks)):
            st["part_evt"].clear()
            obj = ranks[i].runtime.hetero_object(np.asarray(arrs[i]))
            ranks[i].send(root, "coll_naive_part", obj,
                          user={"run": run_id, "src": i})
            assert st["part_evt"].wait(120), "naive gather hung"
        acc = np.asarray(arrs[0]).copy()
        for i in range(1, len(ranks)):
            acc = acc + st["parts"][i]
        for i in range(1, len(ranks)):
            obj = ranks[root].runtime.hetero_object(acc)
            ranks[root].send(i, "coll_naive_out", obj,
                             user={"run": run_id})
            assert st["out_evt"][i].wait(120), "naive scatter hung"
        return [acc] + [st["outs"][i] for i in range(1, len(ranks))]
    finally:
        with _naive_lock:
            _naive.pop(run_id, None)


def _cfg() -> RuntimeConfig:
    return RuntimeConfig(memory_capacity=1 << 27,
                         chunk_bytes=256 << 10,
                         retry_backoff_s=0.02, retry_tick_s=0.002)


def run_coll(large_elems: int = 1 << 20, small_elems: int = 256,
             ranks: int = 4, iters_small: int = 25,
             reps_large: int = 3) -> Dict:
    rng = np.random.default_rng(0)
    row: Dict = {"ranks": ranks, "large_bytes": large_elems * 4,
                 "small_bytes": small_elems * 4, "ctrl_billed": True}

    with Cluster(ranks, _cfg(), **_NET) as c:
        g = CollectiveGroup(c)
        row["shape"] = g.describe()

        # -- large arm: pipelined ring vs sequential root staging -------
        big = [rng.standard_normal(large_elems).astype(np.float32)
               for _ in range(ranks)]
        g.allreduce(big)                        # warm compile/lanes
        naive_allreduce(c, big, "warm")
        t0 = time.perf_counter()
        for _ in range(reps_large):
            ring_out = g.allreduce(big)
        ring_s = (time.perf_counter() - t0) / reps_large
        t0 = time.perf_counter()
        for r in range(reps_large):
            naive_out = naive_allreduce(c, big, f"l{r}")
        naive_s = (time.perf_counter() - t0) / reps_large
        oracle = g.oracle_allreduce(big)
        row["large"] = {
            "ring_ms": round(ring_s * 1e3, 3),
            "naive_ms": round(naive_s * 1e3, 3),
            "speedup": round(naive_s / ring_s, 3),
            "bitwise_identical": bool(all(
                np.array_equal(o, e) for o, e in zip(ring_out, oracle))),
        }
        row["large"]["naive_allclose"] = bool(np.allclose(
            naive_out[0], oracle[0], rtol=1e-4, atol=1e-5))

        # -- small arm: eager binomial tree vs the same baseline --------
        small = [rng.standard_normal(small_elems).astype(np.float32)
                 for _ in range(ranks)]
        g.allreduce(small)
        tree_t, naive_t = [], []
        for i in range(iters_small):
            t0 = time.perf_counter()
            tree_out = g.allreduce(small)
            tree_t.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            naive_allreduce(c, small, f"s{i}")
            naive_t.append(time.perf_counter() - t0)
        tree_us = float(np.median(tree_t) * 1e6)
        naive_us = float(np.median(naive_t) * 1e6)
        s_oracle = g.oracle_allreduce(small)
        row["small"] = {
            "tree_us": round(tree_us, 1),
            "naive_us": round(naive_us, 1),
            "overhead_pct": round((tree_us - naive_us) / naive_us * 100,
                                  2),
            "bitwise_identical": bool(all(
                np.array_equal(o, e)
                for o, e in zip(tree_out, s_oracle))),
        }
        row["bitwise_identical"] = (row["large"]["bitwise_identical"]
                                    and row["small"]["bitwise_identical"])

        # -- kill arm: rank dies mid-collective, epoch bump aborts ------
        fi = c.fault_injector(seed=17)
        epoch = [0]
        gk = CollectiveGroup(c, epoch_fn=lambda: epoch[0])
        victim = ranks - 1
        for other in range(ranks - 1):
            fi.set_link(other, victim, drop=1.0)
            fi.set_link(victim, other, drop=1.0)
        err = {}

        def go():
            try:
                gk.allreduce(big)
            except BaseException as e:          # noqa: BLE001
                err["e"] = e

        t = threading.Thread(target=go)
        t.start()
        time.sleep(0.05)
        fi.kill_rank(victim)                    # now actually gone
        time.sleep(0.05)
        epoch[0] += 1                           # elastic recovery signal
        t.join(60)
        aborted = (not t.is_alive()
                   and isinstance(err.get("e"), CollectiveAborted))
        fi.revive_rank(victim)
        for other in range(ranks - 1):
            fi.clear_link(other, victim)
            fi.clear_link(victim, other)
        for r in c.ranks:
            r.reset_peer_state()
        out2 = gk.allreduce(big)
        oracle_k = gk.oracle_allreduce(big)   # gk's own frozen schedule
        row["kill"] = {
            "victim": victim,
            "kills": fi.stats["kills"],
            "aborts": sum(r.stats["coll_aborts"] for r in c.ranks),
            "aborted_cleanly": bool(aborted),
            "recovered": bool(aborted and all(
                np.array_equal(o, e) for o, e in zip(out2, oracle_k))),
        }
        row["gauges"] = {
            "coll_bytes_reduced": sum(
                r.stats["coll_bytes_reduced"] for r in c.ranks),
            "coll_chunks_in_flight_peak": max(
                r.stats["coll_chunks_in_flight_peak"] for r in c.ranks),
        }
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--large-elems", type=int, default=1 << 20)
    ap.add_argument("--small-elems", type=int, default=256)
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    row = run_coll(large_elems=args.large_elems,
                   small_elems=args.small_elems, ranks=args.ranks,
                   iters_small=args.iters)
    print(json.dumps(row, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(row, f, indent=2)


if __name__ == "__main__":
    main()
