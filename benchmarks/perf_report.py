"""§Perf report: corrected roofline terms for every hillclimb variant.

Correction recap (see launch/roofline.py): layer-scan probe correction plus
an over-decomposition factor — with od microbatches the whole fwd+bwd lives
inside a scan body XLA counts once, so

    corrected_od = od · (corrected_layers − probe0) + probe0
"""
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.configs import SHAPES_BY_NAME, get_config  # noqa: E402
from repro.launch import roofline as R  # noqa: E402

HILL = os.path.join(REPO, "benchmarks", "results", "hillclimb")
DRY = os.path.join(REPO, "benchmarks", "results", "dryrun")

CELLS = {
    ("gemma3_27b", "train_4k"):
        ["baseline", "od2", "od4", "dots", "dots_sp", "dots_sp_od4",
         "sp", "sp_od4", "sp_od8"],
    ("pixtral_12b", "decode_32k"): ["baseline", "kvseq_model"],
    ("mamba2_370m", "train_4k"):
        ["baseline", "dots", "ssd_chunk128", "ssd_chunk128_dots_sp"],
    ("yi_9b", "decode_32k"): ["baseline", "kvseq_model"],
    ("phi4_mini_3_8b", "decode_32k"): ["baseline", "kvseq_model"],
    ("llama4_scout_17b_a16e", "decode_32k"): ["baseline", "kvseq_model"],
    ("whisper_large_v3", "decode_32k"): ["baseline", "kvseq_model"],
}

OD = {"od2": 2, "od4": 4, "od8": 8, "dots_sp_od4": 4, "dots_sp_od8": 8,
      "sp_od4": 4, "sp_od8": 8}
REMAT = {"dots": "dots", "dots_sp": "dots", "dots_sp_od4": "dots",
         "dots_sp_od8": "dots", "ssd_chunk128_dots_sp": "dots"}


def load(arch, shape, variant, probe=None):
    if variant == "baseline":
        tag = f"{arch}__{shape}__pod1__baseline"
        if probe is not None:
            tag += f"__probe{probe}"
        path = os.path.join(DRY, tag + ".json")
    else:
        tag = f"{arch}__{shape}__{variant}"
        if probe is not None:
            tag += f"__probe{probe}"
        path = os.path.join(HILL, tag + ".json")
    if not os.path.exists(path):
        return None
    d = json.load(open(path))
    return None if "error" in d else d


def analyze(arch, shape, variant):
    full = load(arch, shape, variant)
    if full is None:
        return None
    p0 = load(arch, shape, variant, 0)
    p1 = load(arch, shape, variant, 1)
    cfg = get_config(arch)
    sh = SHAPES_BY_NAME[shape]
    chips = full["chips"]
    hlo = R.corrected_hlo(full, p0, p1, cfg)
    od = OD.get(variant, 1)
    if od > 1 and p0 is not None:
        for key in ("flops_per_device", "bytes_per_device",
                    "collective_total_bytes"):
            base = p0.get(key, 0.0) or 0.0
            hlo[key] = od * (hlo[key] - base) + base
    remat = REMAT.get(variant, "full")
    ana = R.analytic_total_flops(cfg, sh, remat) / chips
    hbm = hlo["bytes_per_device"] + R.flash_scan_bytes_correction(
        cfg, sh, chips)
    coll = hlo["collective_total_bytes"]
    terms = {"compute": ana / R.PEAK_FLOPS, "memory": hbm / R.HBM_BW,
             "collective": coll / R.ICI_BW}
    bound = max(terms.values())
    return {
        "variant": variant, "t_compute_ms": terms["compute"] * 1e3,
        "t_memory_ms": terms["memory"] * 1e3,
        "t_collective_ms": terms["collective"] * 1e3,
        "bottleneck": max(terms, key=terms.get),
        "roofline_pct": 100 * terms["compute"] / bound,
        "temp_GB": (full.get("temp_size_in_bytes") or 0) / 1e9,
        "hbm_GB": hbm / 1e9, "coll_GB": coll / 1e9,
    }


def main():
    out = {}
    for (arch, shape), variants in CELLS.items():
        print(f"\n== {arch} × {shape} ==")
        print(f"{'variant':22s} {'compute':>9s} {'memory':>10s} "
              f"{'coll':>9s} {'bneck':>10s} {'roofl%':>7s} {'temp':>7s}")
        rows = []
        for v in variants:
            r = analyze(arch, shape, v)
            if r is None:
                print(f"{v:22s}  (missing)")
                continue
            rows.append(r)
            print(f"{r['variant']:22s} {r['t_compute_ms']:7.1f}ms "
                  f"{r['t_memory_ms']:8.1f}ms {r['t_collective_ms']:7.1f}ms "
                  f"{r['bottleneck']:>10s} {r['roofline_pct']:6.1f}% "
                  f"{r['temp_GB']:5.1f}GB")
        out[f"{arch}__{shape}"] = rows
    path = os.path.join(REPO, "benchmarks", "results", "perf_report.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
