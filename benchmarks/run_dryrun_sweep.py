"""Sequential dry-run sweep: every (arch × shape) cell on the single-pod mesh
(+ optionally multi-pod), each in an isolated subprocess. Failures are
recorded and the sweep continues. Results land in benchmarks/results/dryrun/.

``--rt-ladder`` additionally sweeps the tasking-runtime optimization ladder
(benchmarks/tasking_overhead.py, paper Fig. 8) rung by rung — including the
transfer-engine rungs TF-Prefetch (RuntimeConfig.prefetch) and TF-D2D
(RuntimeConfig.d2d) — each rung in its own subprocess with a multi-device
CPU view so the D2D path is actually exercised.
"""
import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(REPO, "benchmarks", "results", "dryrun")


def cells():
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.configs import ARCH_IDS, get_config, shapes_for
    out = []
    for arch in ARCH_IDS:
        for shape in shapes_for(get_config(arch)):
            out.append((arch, shape.name))
    return out


def _run_subprocess_cell(tag, cmd, env, meta, timeout):
    """One sweep cell in an isolated subprocess: cached-JSON skip, error
    recording (``meta`` + the failure), and OK/FAIL/TIME reporting."""
    out_path = os.path.join(OUT_DIR, tag + ".json")
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
        # success payloads are dicts without an "error" key or row lists;
        # failures are always dicts carrying "error"
        if "error" not in data:
            print(f"SKIP (cached) {tag}", flush=True)
            return
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env, cwd=REPO)
        ok = proc.returncode == 0
        if not ok:
            with open(out_path, "w") as f:
                json.dump(dict(meta, error=(proc.stderr or "")[-3000:]),
                          f, indent=2)
        print(f"{'OK  ' if ok else 'FAIL'} {tag}  ({time.time()-t0:.0f}s)",
              flush=True)
    except subprocess.TimeoutExpired:
        with open(out_path, "w") as f:
            json.dump(dict(meta, error="timeout"), f)
        print(f"TIME {tag}", flush=True)


def run_cell(arch, shape, multi_pod, opt_level, timeout=3600, probe=None):
    tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}__{opt_level}"
    if probe is not None:
        tag += f"__probe{probe}"
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--opt-level", opt_level, "--out",
           os.path.join(OUT_DIR, tag + ".json")]
    if probe is not None:
        cmd += ["--probe", str(probe)]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    _run_subprocess_cell(tag, cmd, env,
                         {"arch": arch, "shape": shape,
                          "multi_pod": multi_pod, "opt_level": opt_level},
                         timeout)


def rt_ladder_rungs():
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    sys.path.insert(0, os.path.join(REPO, "src"))
    from tasking_overhead import EXTRA_RUNGS, LADDER
    return [name for name, _ in LADDER] + list(EXTRA_RUNGS)


def run_rt_rung(rung, devices=2, sizes="64,128", iters=30, timeout=1800):
    """One tasking-ladder rung in an isolated subprocess with ``devices``
    virtual CPU devices (so TF-D2D has a second device to transfer to)."""
    tag = f"rt_ladder__{rung}__dev{devices}"
    cmd = [sys.executable, os.path.join(REPO, "benchmarks",
                                        "tasking_overhead.py"),
           "--only", rung, "--sizes", sizes, "--iters", str(iters),
           "--json", os.path.join(OUT_DIR, tag + ".json")]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    _run_subprocess_cell(tag, cmd, env, {"rung": rung, "devices": devices},
                         timeout)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2-pod mesh")
    ap.add_argument("--only-multi-pod", action="store_true")
    ap.add_argument("--opt-level", default="baseline")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--probes", action="store_true",
                    help="also run 0-layer/1-period probe lowerings")
    ap.add_argument("--rt-ladder", action="store_true",
                    help="also sweep the tasking-runtime ladder "
                         "(TF-Baseline … TF-Prefetch, TF-D2D)")
    ap.add_argument("--rt-devices", type=int, default=2,
                    help="virtual devices for the runtime ladder")
    ap.add_argument("--rt-sizes", default="64,128",
                    help="matrix sizes for the runtime ladder (SCHED-"
                         "Locality uses the largest)")
    ap.add_argument("--rt-iters", type=int, default=30)
    ap.add_argument("--only-rt-ladder", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    if args.rt_ladder or args.only_rt_ladder:
        for rung in rt_ladder_rungs():
            run_rt_rung(rung, devices=args.rt_devices,
                        sizes=args.rt_sizes, iters=args.rt_iters)
        if args.only_rt_ladder:
            print("sweep done", flush=True)
            return
    todo = cells()
    if args.arch:
        todo = [(a, s) for a, s in todo if a == args.arch]
    for arch, shape in todo:
        if not args.only_multi_pod:
            run_cell(arch, shape, False, args.opt_level)
            if args.probes:
                run_cell(arch, shape, False, args.opt_level, probe=0)
                run_cell(arch, shape, False, args.opt_level, probe=1)
        if args.multi_pod or args.only_multi_pod:
            run_cell(arch, shape, True, args.opt_level)
    print("sweep done", flush=True)


if __name__ == "__main__":
    main()
