"""Sequential dry-run sweep: every (arch × shape) cell on the single-pod mesh
(+ optionally multi-pod), each in an isolated subprocess. Failures are
recorded and the sweep continues. Results land in benchmarks/results/dryrun/.
"""
import argparse
import json
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_DIR = os.path.join(REPO, "benchmarks", "results", "dryrun")


def cells():
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.configs import ARCH_IDS, get_config, shapes_for
    out = []
    for arch in ARCH_IDS:
        for shape in shapes_for(get_config(arch)):
            out.append((arch, shape.name))
    return out


def run_cell(arch, shape, multi_pod, opt_level, timeout=3600, probe=None):
    tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}__{opt_level}"
    if probe is not None:
        tag += f"__probe{probe}"
    out_path = os.path.join(OUT_DIR, tag + ".json")
    if os.path.exists(out_path):
        with open(out_path) as f:
            data = json.load(f)
        if "error" not in data:
            print(f"SKIP (cached) {tag}", flush=True)
            return
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--opt-level", opt_level, "--out", out_path]
    if probe is not None:
        cmd += ["--probe", str(probe)]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              timeout=timeout, env=env, cwd=REPO)
        ok = proc.returncode == 0
        if not ok:
            err = (proc.stderr or "")[-3000:]
            with open(out_path, "w") as f:
                json.dump({"arch": arch, "shape": shape,
                           "multi_pod": multi_pod, "opt_level": opt_level,
                           "error": err}, f, indent=2)
        print(f"{'OK  ' if ok else 'FAIL'} {tag}  ({time.time()-t0:.0f}s)",
              flush=True)
    except subprocess.TimeoutExpired:
        with open(out_path, "w") as f:
            json.dump({"arch": arch, "shape": shape, "multi_pod": multi_pod,
                       "opt_level": opt_level, "error": "timeout"}, f)
        print(f"TIME {tag}", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true",
                    help="also run the 2-pod mesh")
    ap.add_argument("--only-multi-pod", action="store_true")
    ap.add_argument("--opt-level", default="baseline")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--probes", action="store_true",
                    help="also run 0-layer/1-period probe lowerings")
    args = ap.parse_args()
    os.makedirs(OUT_DIR, exist_ok=True)
    todo = cells()
    if args.arch:
        todo = [(a, s) for a, s in todo if a == args.arch]
    for arch, shape in todo:
        if not args.only_multi_pod:
            run_cell(arch, shape, False, args.opt_level)
            if args.probes:
                run_cell(arch, shape, False, args.opt_level, probe=0)
                run_cell(arch, shape, False, args.opt_level, probe=1)
        if args.multi_pod or args.only_multi_pod:
            run_cell(arch, shape, True, args.opt_level)
    print("sweep done", flush=True)


if __name__ == "__main__":
    main()
