"""Paper Fig. 8 — heterogeneous tasking framework optimization ladder.

Matrix-multiply benchmark over the runtime with optimizations applied
incrementally, normalized against a direct jit call (the "CUDA baseline"
analogue — no runtime, hand-managed buffers). Reports throughput
(iterations/s) per matrix size and the ratio to the baseline.

Ladder (paper §4.1 + transfer engine):
  TF-Baseline    fresh jit per launch, sync dispatch, no pools
  TF-PageLocked  + staging-buffer pool (page-locked analogue)
  TF-CustomAlloc + jit cache & buffer donation (custom allocator analogue)
  TF-TPools      + request/future pools
  TF-TferQueue   + per-device dedicated transfer queues
  TF-MultQueue   + multiple in-flight launches (multi-stream analogue)
  TF-Prefetch    + argument prefetch pipeline (transfers overlap compute)
  TF-D2D         + direct device→device transfers (no host bounce)
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core import Runtime, RuntimeConfig

# every rung below TF-Prefetch runs with the transfer engine's new paths
# off, so the ladder isolates each optimization's contribution
_OFF = dict(d2d=False, prefetch=False)

LADDER = [
    ("TF-Baseline", dict(staging_pool=False, cache_jit=False,
                         request_pool=False, transfer_thread=False,
                         inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-PageLocked", dict(staging_pool=True, cache_jit=False,
                           request_pool=False, transfer_thread=False,
                           inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-CustomAlloc", dict(staging_pool=True, cache_jit=True,
                            request_pool=False, transfer_thread=False,
                            inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-TPools", dict(staging_pool=True, cache_jit=True, request_pool=True,
                       transfer_thread=False, inflight=1,
                       sync_dispatch=True, **_OFF)),
    ("TF-TferQueue", dict(staging_pool=True, cache_jit=True,
                          request_pool=True, transfer_thread=True,
                          inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-MultQueue", dict(staging_pool=True, cache_jit=True,
                          request_pool=True, transfer_thread=True,
                          inflight=4, sync_dispatch=False, **_OFF)),
    ("TF-Prefetch", dict(staging_pool=True, cache_jit=True,
                         request_pool=True, transfer_thread=True,
                         inflight=4, sync_dispatch=False,
                         d2d=False, prefetch=True)),
    ("TF-D2D", dict(staging_pool=True, cache_jit=True,
                    request_pool=True, transfer_thread=True,
                    inflight=4, sync_dispatch=False,
                    d2d=True, prefetch=True)),
]

LADDER_BY_NAME = dict(LADDER)


def dgemm(a, b, c):
    return (a @ b).astype(c.dtype)


def bench_config(name: str, overrides: Dict, n: int, iters: int,
                 collect_stats: Dict = None) -> float:
    """Each iteration re-creates inputs (allocate, transfer, compute) like the
    paper's benchmark. Returns iterations/s."""
    import jax
    with Runtime(RuntimeConfig(memory_capacity=1 << 30, **overrides)) as rt:
        host_a = np.random.rand(n, n).astype(np.float32)
        host_b = np.random.rand(n, n).astype(np.float32)
        # warmup (compile)
        A = rt.hetero_object(host_a)
        B = rt.hetero_object(host_b)
        C = rt.hetero_object(shape=(n, n), dtype=np.float32)
        rt.run(dgemm, [(A, "r"), (B, "r"), (C, "w")])
        rt.barrier()
        t0 = time.perf_counter()
        for _ in range(iters):
            A = rt.hetero_object(host_a)
            B = rt.hetero_object(host_b)
            C = rt.hetero_object(shape=(n, n), dtype=np.float32)
            rt.run(dgemm, [(A, "r"), (B, "r"), (C, "w")])
        rt.barrier(timeout=600)
        dt = time.perf_counter() - t0
        if collect_stats is not None:
            collect_stats.update(rt.stats())
    return iters / dt


def bench_direct(n: int, iters: int) -> float:
    """Direct jit + device_put: the MPI+CUDA-style hand-written baseline."""
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda a, b: a @ b)
    host_a = np.random.rand(n, n).astype(np.float32)
    host_b = np.random.rand(n, n).astype(np.float32)
    f(jnp.asarray(host_a), jnp.asarray(host_b)).block_until_ready()
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        a = jax.device_put(host_a)
        b = jax.device_put(host_b)
        out = f(a, b)
    out.block_until_ready()
    return iters / (time.perf_counter() - t0)


def run(sizes=(64, 128, 256, 512), iters=60, only=None) -> List[Dict]:
    ladder = [(k, v) for k, v in LADDER if only is None or k == only]
    rows = []
    for n in sizes:
        base = bench_direct(n, iters)
        row = {"size": n, "direct_its": round(base, 1)}
        for name, overrides in ladder:
            stats: Dict = {}
            its = bench_config(name, overrides, n, iters,
                              collect_stats=stats)
            row[name] = round(its, 1)
            row[name + "_vs_direct"] = round(its / base, 3)
            if overrides.get("prefetch"):
                row[name + "_prefetch_hits"] = stats.get("prefetch_hits", 0)
            if overrides.get("d2d"):
                row[name + "_transfers_d2d"] = stats.get("transfers_d2d", 0)
        rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=[k for k, _ in LADDER],
                    help="run a single ladder rung (used by the sweep)")
    ap.add_argument("--sizes", default="64,128,256,512")
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON to this path")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    rows = run(sizes=sizes, iters=args.iters, only=args.only)
    print("name,us_per_call,derived")
    for row in rows:
        n = row["size"]
        for name, _ in LADDER:
            if name not in row:
                continue
            us = 1e6 / row[name]
            print(f"fig8_{name}_{n},{us:.1f},x{row[name + '_vs_direct']:.3f}")
        print(f"fig8_direct_{n},{1e6 / row['direct_its']:.1f},x1.000")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
