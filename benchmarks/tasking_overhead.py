"""Paper Fig. 8 — heterogeneous tasking framework optimization ladder.

Matrix-multiply benchmark over the runtime with optimizations applied
incrementally, normalized against a direct jit call (the "CUDA baseline"
analogue — no runtime, hand-managed buffers). Reports throughput
(iterations/s) per matrix size and the ratio to the baseline.

Ladder (paper §4.1 + transfer engine):
  TF-Baseline    fresh jit per launch, sync dispatch, no pools
  TF-PageLocked  + staging-buffer pool (page-locked analogue)
  TF-CustomAlloc + jit cache & buffer donation (custom allocator analogue)
  TF-TPools      + request/future pools
  TF-TferQueue   + per-device dedicated transfer queues
  TF-MultQueue   + multiple in-flight launches (multi-stream analogue)
  TF-Prefetch    + argument prefetch pipeline (transfers overlap compute)
  TF-D2D         + direct device→device transfers (no host bounce)
  SCHED-Locality + data-gravity placement (residency-ledger cost model)
  TASK-Replay    + compiled task-graph fast path (trace recurring windows,
                   fuse same-device runs, replay without per-task
                   scheduling) and the shared-lane-pool wake A/B

The SCHED-Locality rung is measured on a chunk-update workload (rw task
chains over persistent chunks, the over-decomposition pattern) under both
the PR 1 baseline scheduler and the gravity scheduler, reporting bytes
moved (h2d + d2h + d2d) and throughput for each — the paper's "place
tasks where their data lives" claim as a measurable byte delta.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from repro.core import Runtime, RuntimeConfig

# every rung below TF-Prefetch runs with the transfer engine's new paths
# off, so the ladder isolates each optimization's contribution
_OFF = dict(d2d=False, prefetch=False)

LADDER = [
    ("TF-Baseline", dict(staging_pool=False, cache_jit=False,
                         request_pool=False, transfer_thread=False,
                         inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-PageLocked", dict(staging_pool=True, cache_jit=False,
                           request_pool=False, transfer_thread=False,
                           inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-CustomAlloc", dict(staging_pool=True, cache_jit=True,
                            request_pool=False, transfer_thread=False,
                            inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-TPools", dict(staging_pool=True, cache_jit=True, request_pool=True,
                       transfer_thread=False, inflight=1,
                       sync_dispatch=True, **_OFF)),
    ("TF-TferQueue", dict(staging_pool=True, cache_jit=True,
                          request_pool=True, transfer_thread=True,
                          inflight=1, sync_dispatch=True, **_OFF)),
    ("TF-MultQueue", dict(staging_pool=True, cache_jit=True,
                          request_pool=True, transfer_thread=True,
                          inflight=4, sync_dispatch=False, **_OFF)),
    ("TF-Prefetch", dict(staging_pool=True, cache_jit=True,
                         request_pool=True, transfer_thread=True,
                         inflight=4, sync_dispatch=False,
                         d2d=False, prefetch=True)),
    ("TF-D2D", dict(staging_pool=True, cache_jit=True,
                    request_pool=True, transfer_thread=True,
                    inflight=4, sync_dispatch=False,
                    d2d=True, prefetch=True)),
]

LADDER_BY_NAME = dict(LADDER)

# rungs with their own workload/measurement, appended after the ladder
EXTRA_RUNGS = ["SCHED-Locality", "MSG-Pipeline", "MSG-HOL",
               "MSG-Congestion", "ELASTIC-Recover", "INTEG-Recover",
               "TASK-Replay", "COLL-Allreduce"]

# subset of Runtime.stats() recorded per rung in the JSON report
_REPORT_KEYS = ("staging_hits", "staging_misses", "request_pool_hits",
                "request_pool_misses", "bytes_h2d", "bytes_d2h",
                "bytes_d2d", "evictions", "prefetch_hits",
                "prefetch_stalls", "prefetch_misses", "bytes_resident")


def dgemm(a, b, c):
    return (a @ b).astype(c.dtype)


def locality_kernel(w):
    return (w * 1.000001).astype(w.dtype)


def bench_sched_locality(n: int = 384, iters: int = 120,
                         weights: int = 8) -> Dict:
    """Chunk-update workload (the over-decomposition pattern): ``iters``
    rw tasks round-robin over ``weights`` persistent n×n chunks, each
    updating its chunk in place. Every placement hop moves the whole chunk
    (the write invalidates the old replica), so bytes moved scale with how
    often the scheduler bounces a chunk off its home. The PR 1 locality
    scheduler's flat 1MiB pressure penalty overwhelms sub-megabyte
    residency, so transient queue imbalance hops chunks between devices;
    data-gravity placement keeps each chain on its chunk's device.
    Reports bytes moved + throughput for both."""
    row: Dict = {"size": n, "iters": iters, "weights": weights}
    for label, sched in (("baseline", "locality"), ("gravity", "gravity")):
        with Runtime(RuntimeConfig(memory_capacity=1 << 30,
                                   scheduler=sched)) as rt:
            warm = rt.hetero_object(np.zeros((n, n), np.float32))
            rt.run(locality_kernel, [(warm, "rw")])   # compile
            rt.barrier()
            ws = [rt.hetero_object(
                np.random.rand(n, n).astype(np.float32))
                for _ in range(weights)]
            base_stats = rt.stats()
            t0 = time.perf_counter()
            for i in range(iters):
                rt.run(locality_kernel, [(ws[i % weights], "rw")])
            rt.barrier(timeout=600)
            dt = time.perf_counter() - t0
            s = rt.stats()
        moved = {k: s[k] - base_stats[k]
                 for k in ("bytes_h2d", "bytes_d2h", "bytes_d2d")}
        row[label] = {
            "its": round(iters / dt, 1),
            **moved,
            "bytes_moved": sum(moved.values()),
        }
    base, grav = row["baseline"]["bytes_moved"], row["gravity"]["bytes_moved"]
    row["bytes_moved_ratio"] = round(grav / base, 4) if base else None
    return row


def bench_msg_pipeline(iters: int = 10) -> Dict:
    """MSG-Pipeline rung: the distributed message-protocol split (paper
    §4.2), measured as device-resident delivery time on a simulated
    0.5 GB/s network. Small messages ride the eager path (must stay
    within ~10% of the monolithic protocol — it IS the same code path,
    so the delta is measurement noise); large messages chunk-stream
    through the rendezvous protocol, overlapping network receive with
    device upload (the paper's up-to-20%-over-MPI+CUDA claim)."""
    import msgrate   # benchmarks/ is on sys.path when run as a script
    net = dict(latency_s=30e-6, bw_bytes_per_s=5e8)
    # the small (eager) size is cheap — triple the samples to tighten the
    # overhead estimate; the large (rendezvous) size dominates wall time
    (small_row,) = msgrate.run(sizes=(8 << 10,), iters=iters * 3, **net)
    (large_row,) = msgrate.run(sizes=(8 << 20,), iters=iters * 2, **net)
    return {
        "small": small_row,
        "large": large_row,
        "small_overhead": round(small_row["pipe_us"]
                                / small_row["mono_us"] - 1.0, 4),
        "large_speedup": large_row["speedup"],
    }


def bench_msg_hol(samples: int = 40) -> Dict:
    """MSG-HOL rung: small-message p50 delivery latency with and without
    a concurrent 8 MiB rendezvous stream on the same rank pair (paper
    §5–6: control messages stay within a small overhead factor while
    payloads stream). The progress engine keeps the ratio near 1; the
    pre-engine pump serialized every small message behind the stream."""
    import msgrate   # benchmarks/ is on sys.path when run as a script
    return msgrate.run_hol(samples=samples)


def bench_msg_congestion(samples: int = 30) -> Dict:
    """MSG-Congestion rung: adaptive vs pinned credit windows against an
    artificially slowed receiver transfer lane, with the control VC
    billed in both arms (finite drain rate — credit chatter costs
    simulated time). Reports small-message HOL p50 vs the uncontended
    baseline, large-stream goodput for both windows, and the adaptation
    evidence (window_adjusts / credits_deferred / window_min)."""
    import msgrate   # benchmarks/ is on sys.path when run as a script
    return msgrate.run_congestion(samples=samples)


def bench_elastic_recover(iters: int = 6) -> Dict:
    """ELASTIC-Recover rung: distributed Jacobi losing (and regaining) a
    rank mid-run with checkpoint-backed live recovery, plus a frozen-but-
    alive straggler whose chunks drain off it. The faulted run must match
    the unfaulted elastic run bit-for-bit — no restart, bounded stall."""
    import elastic_recover   # benchmarks/ is on sys.path as a script
    return elastic_recover.run_recover(iters=max(iters, 4))


def bench_coll_allreduce(iters: int = 25) -> Dict:
    """COLL-Allreduce rung: topology-aware runtime collectives — the
    pipelined chunked-ring allreduce vs the naive sequential send-to-
    root-and-scatter baseline on large payloads, the eager binomial-tree
    arm on small ones, bit-determinism against the numpy oracle, and a
    kill-rank-mid-collective abort/retry."""
    import coll_allreduce   # benchmarks/ is on sys.path as a script
    return coll_allreduce.run_coll(iters_small=max(iters, 10))


def bench_integ_recover(iters: int = 6) -> Dict:
    """INTEG-Recover rung: the same distributed Jacobi under seeded wire
    bit-flips, injected kernel faults, a mid-run kill AND a corrupted
    checkpoint leaf — checksums reject every flipped payload, retries/
    NACKs retransmit, recovery takes the live replica, and the answer
    stays bit-identical to the clean run. Plus the fold64 digest's
    clean-path cost A/B'd on the MSG-Pipeline path."""
    import integ_recover   # benchmarks/ is on sys.path as a script
    # ≥6 iterations: the kill/revive schedule needs iterations after the
    # revive, and the corruption probability needs enough wire crossings
    # to fire deterministically under the fixed seed
    return integ_recover.run_integ(iters=max(iters, 6))


# power-of-two scales: replay fuses both kernels under ONE jit, and XLA
# may contract mul+add chains into FMAs — exact multiplies keep the
# contracted result bit-identical to the interpreted two-dispatch run
def replay_f(x, y):
    return (x * 0.5).astype(x.dtype)


def replay_g(y, x):
    return ((x + y) * 0.5).astype(x.dtype)


def _replay_arm(trace: bool, objects: int, steps: int,
                warmup: int) -> tuple:
    """One arm of the TASK-Replay A/B: ``2 * objects`` small tasks per
    step (producer + in-place consumer per object pair), windows
    delimited by ``step_boundary``. Returns (tasks/s, final arrays,
    runtime stats)."""
    cfg = RuntimeConfig(memory_capacity=1 << 30, trace_graphs=trace,
                        replay_after=3)
    with Runtime(cfg) as rt:
        xs = [rt.hetero_object(np.full((64, 64), 1.0 + 0.01 * i,
                                       np.float32))
              for i in range(objects)]
        ys = [rt.hetero_object(np.zeros((64, 64), np.float32))
              for _ in range(objects)]

        def step():
            for x, y in zip(xs, ys):
                rt.run(replay_f, [(x, "r"), (y, "w")])
                rt.run(replay_g, [(y, "r"), (x, "rw")])
            rt.step_boundary()

        for _ in range(warmup):      # compile + first replay
            step()
        rt.barrier(timeout=600)
        t0 = time.perf_counter()
        for _ in range(steps):
            step()
        rt.barrier(timeout=600)
        dt = time.perf_counter() - t0
        finals = [np.asarray(o.get()).copy() for o in xs + ys]
        st = rt.stats()
    return 2 * objects * steps / dt, finals, st


def _wake_latency_p50_us(pool_workers: int, samples: int = 200) -> float:
    """submit→job-start latency p50 for one lane, pooled vs legacy."""
    from repro.core.futures import HFuture
    from repro.core.progress import ProgressEngine
    eng = ProgressEngine(name="bench", pool_workers=pool_workers)
    lats = []
    try:
        lane = eng.lane("transfer", 0)
        for _ in range(20):          # warm the worker / thread
            lane.submit(lambda: None, HFuture()).get(timeout=30)
        for _ in range(samples):
            t0 = time.perf_counter()
            started = lane.submit(time.perf_counter, HFuture()).get(
                timeout=30)
            lats.append(started - t0)
    finally:
        eng.shutdown()
    lats.sort()
    return lats[len(lats) // 2] * 1e6


def bench_task_replay(objects: int = 8, steps: int = 60) -> Dict:
    """TASK-Replay rung (ROADMAP 4): tasks/s for a recurring 2·objects-task
    window, interpreted vs compiled-replay, bitwise-compared; plus the
    shared-lane-pool wake-latency A/B (pool_workers=4 vs legacy
    thread-per-lane)."""
    warmup = 4                      # replay_after=3 compiles on window 3
    interp_tps, interp_finals, _ = _replay_arm(False, objects, steps,
                                               warmup)
    replay_tps, replay_finals, st = _replay_arm(True, objects, steps,
                                                warmup)
    bitwise = all(np.array_equal(a, b)
                  for a, b in zip(interp_finals, replay_finals))
    return {
        "objects": objects,
        "steps": steps,
        "tasks_per_step": 2 * objects,
        "interpreted_tasks_per_s": round(interp_tps, 1),
        "replay_tasks_per_s": round(replay_tps, 1),
        "speedup": round(replay_tps / interp_tps, 3),
        "graphs_traced": st["graphs_traced"],
        "replays": st["graph_replays"],
        "replayed_tasks": st["replayed_tasks"],
        "graph_invalidations": st["graph_invalidations"],
        "bitwise_identical": bool(bitwise),
        "pool_workers": RuntimeConfig().pool_workers,
        "wake_pool_p50_us": round(_wake_latency_p50_us(4), 1),
        "wake_thread_p50_us": round(_wake_latency_p50_us(0), 1),
    }


def bench_config(name: str, overrides: Dict, n: int, iters: int,
                 collect_stats: Dict = None) -> float:
    """Each iteration re-creates inputs (allocate, transfer, compute) like the
    paper's benchmark. Returns iterations/s."""
    import jax
    with Runtime(RuntimeConfig(memory_capacity=1 << 30, **overrides)) as rt:
        host_a = np.random.rand(n, n).astype(np.float32)
        host_b = np.random.rand(n, n).astype(np.float32)
        # warmup (compile)
        A = rt.hetero_object(host_a)
        B = rt.hetero_object(host_b)
        C = rt.hetero_object(shape=(n, n), dtype=np.float32)
        rt.run(dgemm, [(A, "r"), (B, "r"), (C, "w")])
        rt.barrier()
        t0 = time.perf_counter()
        for _ in range(iters):
            A = rt.hetero_object(host_a)
            B = rt.hetero_object(host_b)
            C = rt.hetero_object(shape=(n, n), dtype=np.float32)
            rt.run(dgemm, [(A, "r"), (B, "r"), (C, "w")])
        rt.barrier(timeout=600)
        dt = time.perf_counter() - t0
        if collect_stats is not None:
            collect_stats.update(rt.stats())
    return iters / dt


def bench_direct(n: int, iters: int) -> float:
    """Direct jit + device_put: the MPI+CUDA-style hand-written baseline."""
    import jax
    import jax.numpy as jnp
    f = jax.jit(lambda a, b: a @ b)
    host_a = np.random.rand(n, n).astype(np.float32)
    host_b = np.random.rand(n, n).astype(np.float32)
    f(jnp.asarray(host_a), jnp.asarray(host_b)).block_until_ready()
    t0 = time.perf_counter()
    out = None
    for _ in range(iters):
        a = jax.device_put(host_a)
        b = jax.device_put(host_b)
        out = f(a, b)
    out.block_until_ready()
    return iters / (time.perf_counter() - t0)


def run(sizes=(64, 128, 256, 512), iters=60, only=None) -> List[Dict]:
    ladder = [(k, v) for k, v in LADDER if only is None or k == only]
    rows = []
    for n in sizes:
        base = bench_direct(n, iters)
        row = {"size": n, "direct_its": round(base, 1)}
        for name, overrides in ladder:
            stats: Dict = {}
            its = bench_config(name, overrides, n, iters,
                              collect_stats=stats)
            row[name] = round(its, 1)
            row[name + "_vs_direct"] = round(its / base, 3)
            row[name + "_stats"] = {k: stats.get(k) for k in _REPORT_KEYS}
        rows.append(row)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[k for k, _ in LADDER] + EXTRA_RUNGS,
                    help="run a single ladder rung (used by the sweep)")
    ap.add_argument("--sizes", default="64,128,256,512")
    ap.add_argument("--iters", type=int, default=60)
    ap.add_argument("--json", default=None,
                    help="also write rows as JSON to this path")
    args = ap.parse_args(argv)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    print("name,us_per_call,derived")
    if args.only == "MSG-Pipeline":
        row = bench_msg_pipeline(iters=max(args.iters // 2, 8))
        for label in ("small", "large"):
            r = row[label]
            print(f"fig12_MSG-Pipeline_{label}_mono_{r['bytes']},"
                  f"{r['mono_us']:.1f},")
            print(f"fig12_MSG-Pipeline_{label}_pipe_{r['bytes']},"
                  f"{r['pipe_us']:.1f},{r['protocol']}_x{r['speedup']:.3f}")
        print(f"fig12_MSG-Pipeline_summary,,"
              f"overhead{row['small_overhead']:+.3f}_"
              f"x{row['large_speedup']:.3f}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "MSG-HOL":
        row = bench_msg_hol(samples=max(args.iters * 2, 20))
        print(f"figHOL_MSG-HOL_unloaded_{row['small_bytes']},"
              f"{row['p50_unloaded_us']:.1f},")
        print(f"figHOL_MSG-HOL_loaded_{row['small_bytes']},"
              f"{row['p50_loaded_us']:.1f},x{row['ratio']:.3f}")
        print(f"figHOL_MSG-HOL_summary,,window{row['max_window']}_"
              f"chunks{row['stream_chunks']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "MSG-Congestion":
        row = bench_msg_congestion(samples=max(args.iters, 20))
        print(f"figCONG_MSG-Congestion_uncontended_{row['small_bytes']},"
              f"{row['p50_uncontended_us']:.1f},")
        for label in ("adaptive", "pinned"):
            a = row[label]
            print(f"figCONG_MSG-Congestion_{label}_{row['small_bytes']},"
                  f"{a['p50_us']:.1f},goodput{a['goodput_MBps']}MBps_"
                  f"ctrl{a['ctrl_msgs']}")
        print(f"figCONG_MSG-Congestion_summary,,"
              f"hol_x{row['hol_ratio_adaptive']}_"
              f"goodput_x{row['goodput_ratio']}_"
              f"wmin{row['adaptive']['window_min']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "ELASTIC-Recover":
        row = bench_elastic_recover(iters=max(args.iters // 5, 4))
        fr, st = row["fail_recover"], row["straggler"]
        print(f"figELA_ELASTIC-Recover_fail,"
              f"{fr['recovery_stall_s'] * 1e6:.1f},"
              f"bytes{fr['bytes_migrated']}_"
              f"bitwise{int(fr['bitwise_identical'])}")
        print(f"figELA_ELASTIC-Recover_straggler,,"
              f"drains{st['drains']}_chunks{st['chunks_migrated']}_"
              f"alive{int(not st['dead_detected'])}")
        print(f"figELA_ELASTIC-Recover_summary,,"
              f"recoveries{fr['recoveries']}_grows{fr['grows']}_"
              f"oracle{int(row['oracle_ok'])}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "INTEG-Recover":
        row = bench_integ_recover(iters=max(args.iters // 5, 4))
        co, fb = row["corrupt"], row["ckpt_fallback"]
        ci = co["integrity"]
        print(f"figINT_INTEG-Recover_corrupt,"
              f"{co['recovery_stall_s'] * 1e6:.1f},"
              f"cksum{ci['checksum_fail']}_retries{ci['retries']}_"
              f"bitwise{int(co['bitwise_identical'])}")
        print(f"figINT_INTEG-Recover_ckpt_fallback,,"
              f"verify_fail{fb['integrity']['ckpt_verify_fail']}_"
              f"detected{int(fb['corruption_detected'])}_"
              f"completed{int(fb['completed'])}")
        for r in row["verify_overhead"]:
            print(f"figINT_INTEG-Recover_verify_{r['bytes']},"
                  f"{r['verify_us']:.1f},"
                  f"{r['protocol']}_overhead{r['overhead_pct']:+.2f}pct")
        print(f"figINT_INTEG-Recover_summary,,"
              f"recoveries{co['recoveries']}_"
              f"corrupted{co['faults']['corrupted']}_"
              f"oracle{int(row['oracle_ok'])}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "TASK-Replay":
        row = bench_task_replay(steps=max(args.iters, 30))
        print(f"figTG_TASK-Replay_interpreted,"
              f"{1e6 / row['interpreted_tasks_per_s']:.1f},")
        print(f"figTG_TASK-Replay_replay,"
              f"{1e6 / row['replay_tasks_per_s']:.1f},"
              f"x{row['speedup']:.3f}_replays{row['replays']}")
        print(f"figTG_TASK-Replay_wake,,"
              f"pool{row['wake_pool_p50_us']:.1f}us_"
              f"thread{row['wake_thread_p50_us']:.1f}us")
        print(f"figTG_TASK-Replay_summary,,"
              f"bitwise{int(row['bitwise_identical'])}_"
              f"tasks{row['replayed_tasks']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "COLL-Allreduce":
        row = bench_coll_allreduce(iters=max(args.iters // 3, 10))
        lg, sm, kl = row["large"], row["small"], row["kill"]
        print(f"figCOLL_COLL-Allreduce_large_naive_{row['large_bytes']},"
              f"{lg['naive_ms'] * 1e3:.1f},")
        print(f"figCOLL_COLL-Allreduce_large_ring_{row['large_bytes']},"
              f"{lg['ring_ms'] * 1e3:.1f},x{lg['speedup']:.3f}")
        print(f"figCOLL_COLL-Allreduce_small_tree_{row['small_bytes']},"
              f"{sm['tree_us']:.1f},"
              f"overhead{sm['overhead_pct']:+.2f}pct")
        print(f"figCOLL_COLL-Allreduce_kill,,"
              f"kills{kl['kills']}_aborts{kl['aborts']}_"
              f"recovered{int(kl['recovered'])}")
        print(f"figCOLL_COLL-Allreduce_summary,,"
              f"x{lg['speedup']:.3f}_"
              f"bitwise{int(row['bitwise_identical'])}_"
              f"ring{len(row['shape']['ring'])}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    if args.only == "SCHED-Locality":
        row = bench_sched_locality(n=max(sizes), iters=max(args.iters, 20))
        for label in ("baseline", "gravity"):
            us = 1e6 / row[label]["its"]
            print(f"fig8_SCHED-Locality_{label}_{row['size']},{us:.1f},"
                  f"moved={row[label]['bytes_moved']}")
        print(f"fig8_SCHED-Locality_ratio_{row['size']},,"
              f"x{row['bytes_moved_ratio']}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(row, f, indent=2)
        return
    rows = run(sizes=sizes, iters=args.iters, only=args.only)
    for row in rows:
        n = row["size"]
        for name, _ in LADDER:
            if name not in row:
                continue
            us = 1e6 / row[name]
            print(f"fig8_{name}_{n},{us:.1f},x{row[name + '_vs_direct']:.3f}")
        print(f"fig8_direct_{n},{1e6 / row['direct_its']:.1f},x1.000")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
