"""ELASTIC-Recover rung: end-to-end elastic fault tolerance on the
distributed Jacobi proxy (paper §3.3 "dynamic load balancing and fault
tolerance" + ISSUE tentpole).

Two arms, both on a simulated network with a billed control VC so the
heartbeats and recovery control traffic cost simulated time like any
other message:

  fail_recover — 4 ranks, kill one AFTER an iteration's checkpoint
      commits, revive it a few iterations later. The run must finish
      WITHOUT a restart, with a bounded recovery stall, and the answer
      must be bit-identical to the same elastic run with no fault
      injected (the restore replays exact committed bytes and the
      per-shape jit kernels compute the same bits on any rank).

  straggler — over-decomposed (2 slabs/rank), one rank's network frozen
      while its compute keeps running. The monitor's slowdown fusion
      (heartbeat gap × EWMA latency × lane backlog) must flag it and
      live-migrate chunks OFF it without ever declaring it dead.

Run via ``tasking_overhead.py --only ELASTIC-Recover`` (the dry-run
sweep does this) or directly: ``python benchmarks/elastic_recover.py``.
"""
import argparse
import json
import tempfile
import time
from typing import Dict

import numpy as np

from repro.core import RuntimeConfig
from repro.distributed import Cluster
from repro.apps.jacobi3d import run_cluster_elastic, run_reference

_NET = dict(latency_s=100e-6, bw_bytes_per_s=4e9, ctrl_drain_per_s=2e5)


def _cfg() -> RuntimeConfig:
    return RuntimeConfig(memory_capacity=1 << 26)


def run_recover(n: int = 48, iters: int = 6, ranks: int = 4) -> Dict:
    rng = np.random.default_rng(0)
    u0 = rng.standard_normal((n, n // 2, n // 2)).astype(np.float32)
    row: Dict = {"n": n, "iters": iters, "ranks": ranks,
                 "ctrl_billed": True}

    # -- baseline: the same elastic machinery, no fault -----------------
    t0 = time.perf_counter()
    with Cluster(ranks, _cfg(), **_NET) as c:
        base, _ = run_cluster_elastic(u0, iters, c)
    row["baseline_s"] = round(time.perf_counter() - t0, 4)
    ref = run_reference(u0, iters)
    row["oracle_ok"] = bool(np.allclose(base, ref, rtol=1e-5, atol=1e-6))

    # -- arm A: kill + revive mid-run ----------------------------------
    kill_rank, kill_it = ranks - 2, 1
    revive_it = min(iters - 2, kill_it + 3)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        t0 = time.perf_counter()
        with Cluster(ranks, _cfg(), **_NET) as c:
            out, rep = run_cluster_elastic(
                u0, iters, c, ckpt_dir=ckpt_dir,
                kill=(kill_rank, kill_it), revive_at=(kill_rank, revive_it),
                heartbeat_interval_s=0.02, heartbeat_timeout_s=0.4)
        wall = time.perf_counter() - t0
    e = rep["elastic"]
    row["fail_recover"] = {
        "wall_s": round(wall, 4),
        "killed_rank": kill_rank, "kill_iter": kill_it,
        "revive_iter": revive_it,
        "recoveries": e["recoveries"], "grows": e["grows"],
        "dead_detected": e["dead"],
        "recovery_stall_s": round(e["recovery_stall_s"], 6),
        "bytes_migrated": e["bytes_migrated"],
        "chunks_migrated": e["chunks_migrated"],
        "heartbeats_missed": rep["monitor_stats"]["heartbeats_missed"],
        "retries": rep["monitor_stats"]["retries"],
        "epochs": rep["epochs"],
        "faults": rep["faults"],
        "bitwise_identical": bool(np.array_equal(out, base)),
    }

    # -- arm B: frozen-but-alive straggler -----------------------------
    frz_rank, frz_it, frz_s = 1, 1, 0.8
    t0 = time.perf_counter()
    with Cluster(ranks, _cfg(), **_NET) as c:
        out, rep = run_cluster_elastic(
            u0, iters, c, slabs=2 * ranks,
            freeze=(frz_rank, frz_it, frz_s),
            heartbeat_interval_s=0.02, heartbeat_timeout_s=3.0,
            straggler_factor=25.0)
    wall = time.perf_counter() - t0
    e = rep["elastic"]
    row["straggler"] = {
        "wall_s": round(wall, 4),
        "frozen_rank": frz_rank, "freeze_s": frz_s,
        "drains": e["drains"],
        "stragglers_flagged": e["stragglers"],
        "straggler_signals": {str(k): v for k, v in
                              e["straggler_signals"].items()},
        "dead_detected": e["dead"],        # must stay empty: alive!
        "chunks_migrated": e["chunks_migrated"],
        "bytes_migrated": e["bytes_migrated"],
        "epochs": rep["epochs"],
        "faults": rep["faults"],
        "oracle_ok": bool(np.allclose(out, ref, rtol=1e-5, atol=1e-6)),
    }
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--iters", type=int, default=6)
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    row = run_recover(n=args.n, iters=args.iters, ranks=args.ranks)
    print(json.dumps(row, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(row, f, indent=2)


if __name__ == "__main__":
    main()
