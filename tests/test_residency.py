"""Residency & placement engine: ledger bookkeeping, data-gravity cost
model (tie-breaking included), gravity scheduler re-keying, priority
transfer queues, configurable prefetch depth, and the pooled D2H staging
path.

conftest.py forces a 2-device CPU view for the jax-backed tests; the
prefetch-depth pipeline tests use a deterministic FakeDevice with
configurable upload/compute latencies instead of racing real jax dispatch.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import (HOST, DataGravityPolicy, HeteroObject, HeteroTask,
                        LoadOnlyPolicy, ResidencyLedger, Runtime,
                        RuntimeConfig)
from repro.core.device_api import Device, DeviceInfo
from repro.core.scheduler import GravityScheduler


def _obj(nbytes_floats=16, spaces=()):
    o = HeteroObject(None, value=np.zeros(nbytes_floats, np.float32))
    for s in spaces:
        o.copies[s] = o.copies[HOST]
    return o


def _task(*objs):
    t = HeteroTask()
    for o in objs:
        t.arg(o).read()
    return t


# ---------------------------------------------------------------------------
# ledger bookkeeping
# ---------------------------------------------------------------------------

def test_ledger_record_drop_and_gauges():
    led = ResidencyLedger({0: 1 << 20, 1: 1 << 20})
    a, b = _obj(256), _obj(64)
    led.record(0, a)
    led.record(0, b)
    led.record(1, a)
    assert led.devices_of(a) == {0, 1}
    assert led.usage(0) == a.nbytes + b.nbytes
    g = led.gauges()
    assert g["bytes_resident"] == {0: a.nbytes + b.nbytes, 1: a.nbytes}
    assert g["objects_resident"] == {0: 2, 1: 1}
    led.drop(1, a)
    assert led.devices_of(a) == {0}
    led.drop(0, a)
    assert led.devices_of(a) == set()
    assert led.usage(0) == b.nbytes
    # double record must not double count
    led.record(0, b)
    assert led.usage(0) == b.nbytes


def test_ledger_task_byte_queries():
    led = ResidencyLedger({0: 1 << 20, 1: 1 << 20})
    a, b = _obj(256), _obj(64)
    led.record(0, a)
    t = _task(a, b, a)          # duplicate arg counted once
    assert led.task_bytes_resident(t, 0) == a.nbytes
    assert led.task_bytes_to_move(t, 0) == b.nbytes
    assert led.task_bytes_resident(t, 1) == 0
    assert led.task_bytes_to_move(t, 1) == a.nbytes + b.nbytes


def test_ledger_lru_eviction_order():
    led = ResidencyLedger({0: 1000})
    objs = [_obj(64) for _ in range(3)]       # 256B each
    for o in objs:
        led.record(0, o)
    led.touch(0, objs[0])                     # objs[1] is now the LRU
    evicted = []

    def evict(obj, dev):
        evicted.append(obj)
        led.drop(dev, obj)
        return True

    assert led.ensure_capacity(0, 500, evict)
    assert evicted[0] is objs[1]
    assert led.evictions >= 1


def test_ledger_least_loaded_device():
    led = ResidencyLedger({0: 1 << 20, 1: 1 << 20, 2: 1 << 20})
    led.record(0, _obj(256))
    # no pressure info: fewest bytes resident, lowest id breaks the tie
    assert led.least_loaded_device() == 1
    # pressure dominates residency
    assert led.least_loaded_device(pressure={1: 5, 0: 0, 2: 3}.get) == 0
    # restriction to a subset
    assert led.least_loaded_device(among=[0, 2]) == 2


# ---------------------------------------------------------------------------
# placement cost model
# ---------------------------------------------------------------------------

def test_gravity_score_prefers_heavy_resident_bytes():
    pol = DataGravityPolicy(load_penalty_bytes=1)
    big, small = _obj(4096, spaces=[0]), _obj(16, spaces=[1])
    t = _task(big, small)
    # device 0 holds 16KB of the args, device 1 only 64B
    assert pol.choose(t, [0, 1], lambda d: 0) == 0
    # ...and the ledger-bound path agrees with the has_copy fallback
    led = ResidencyLedger({0: 1 << 20, 1: 1 << 20})
    led.record(0, big)
    led.record(1, small)
    pol.bind(led)
    assert pol.choose(t, [0, 1], lambda d: 0) == 0


def test_gravity_ties_break_deterministically_by_device_id():
    pol = DataGravityPolicy()
    t = _task(_obj(16))           # host-only arg: equal cost everywhere
    assert pol.choose(t, [2, 1, 0], lambda d: 0) == 0
    assert pol.choose(t, [2, 1], lambda d: 0) == 1


def test_gravity_pressure_penalty_overrides_small_residency():
    pol = DataGravityPolicy(load_penalty_bytes=1024)
    o = _obj(16, spaces=[0])      # 64 bytes resident on device 0
    t = _task(o)
    # 64B of gravity loses to one queued task (1024B penalty) on device 0
    assert pol.choose(t, [0, 1], {0: 1, 1: 0}.get) == 1
    # megabyte-scale residency wins against the same pressure gap
    heavy = _obj(1 << 18, spaces=[0])
    assert pol.choose(_task(heavy), [0, 1], {0: 1, 1: 0}.get) == 0


def test_load_only_policy_ignores_residency():
    pol = LoadOnlyPolicy()
    o = _obj(4096, spaces=[0])
    assert pol.choose(_task(o), [0, 1], {0: 3, 1: 1}.get) == 1


def test_gravity_scheduler_rekeys_queue_by_residency():
    s = GravityScheduler({0: "cpu", 1: "cpu"})
    o = _obj(1 << 16, spaces=[1])
    t = _task(o)
    s.push(t)
    assert s.queued[1] == 1 and s.queued[0] == 0
    # no stealing: device 0 cannot take the task placed with its data
    assert s.pop(0) is None
    got, dev = s.pop(1)
    assert got is t and dev == 1


def test_runtime_placement_override():
    cfg = RuntimeConfig(memory_capacity=1 << 26, placement="load_only")
    with Runtime(cfg) as rt:
        assert isinstance(rt.scheduler.placement, LoadOnlyPolicy)
        assert rt.scheduler.placement.ledger is rt.residency
        x = rt.hetero_object(np.ones(8, np.float32))
        rt.run(lambda v: v + 1, [(x, "rw")])
        rt.barrier()
        np.testing.assert_allclose(x.get(), 2.0)


def test_gravity_keeps_tasks_with_their_weights():
    """A stream of tasks each reading one of two resident megabyte-scale
    weights must stay on the weights' devices — no bouncing."""
    cfg = RuntimeConfig(memory_capacity=1 << 28)
    with Runtime(cfg) as rt:
        if len(rt.devices) < 2:
            pytest.skip("needs >= 2 (virtual) devices")
        w = [rt.hetero_object(np.ones((512, 512), np.float32))
             for _ in range(2)]
        rt._ensure_on_device(w[0], 0, will_write=False)
        rt._ensure_on_device(w[1], 1, will_write=False)
        h2d0 = rt.stats()["bytes_h2d"]
        tasks = []
        for i in range(12):
            y = rt.hetero_object(shape=(512,), dtype=np.float32)
            tasks.append((i % 2, rt.run(
                lambda a, out: a[:, 0] * 2.0, [(w[i % 2], "r"), (y, "w")])))
        rt.barrier()
        for want_dev, t in tasks:
            assert t.chosen_device == want_dev, \
                (want_dev, t.chosen_device)
        # the weights never moved again: the only new H2D traffic is the
        # 2KB output buffers, far below one 1MB weight re-upload
        s = rt.stats()
        assert s["bytes_h2d"] - h2d0 < w[0].nbytes
        assert s["bytes_d2d"] == 0


# ---------------------------------------------------------------------------
# priority transfer queues
# ---------------------------------------------------------------------------

def test_transfer_queue_orders_by_priority():
    """While the transfer thread is busy, later-enqueued priority-1 work
    must run before earlier-enqueued priority-2 staging."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 26)) as rt:
        gate = threading.Event()
        order = []
        rt._async_transfer(0, gate.wait)          # occupy the thread
        f_deep = rt._async_transfer(0, lambda: order.append("deep"),
                                    priority=2)
        f_next = rt._async_transfer(0, lambda: order.append("next"),
                                    priority=1)
        gate.set()
        f_deep.get(5)
        f_next.get(5)
        assert order == ["next", "deep"], order


# ---------------------------------------------------------------------------
# prefetch depth (deterministic FakeDevice timing)
# ---------------------------------------------------------------------------

class _Handle:
    __slots__ = ("value", "done_at")

    def __init__(self, value, done_at):
        self.value = value
        self.done_at = done_at


class FakeDevice(Device):
    """Deterministic latencies: uploads sleep ``upload_s``; kernels carry a
    ``compute_s`` attribute simulated as asynchronous completion time."""

    def __init__(self, device_id=0, upload_s=0.0):
        super().__init__(DeviceInfo(device_id, "cpu", 1 << 30, "fake"))
        self.upload_s = upload_s

    def upload(self, host_array):
        if self.upload_s:
            time.sleep(self.upload_s)
        return np.array(host_array)

    def download(self, dev_array):
        return np.asarray(dev_array)

    def transfer_from(self, src, dev_array):
        return np.array(dev_array)

    def launch(self, kernel, args, donate=()):
        value = kernel(*args)
        return _Handle(value, time.monotonic()
                       + getattr(kernel, "compute_s", 0.0))

    def synchronize(self, handle):
        time.sleep(max(0.0, handle.done_at - time.monotonic()))
        return handle

    def is_ready(self, handle):
        return time.monotonic() >= handle.done_at


def _run_depth_pipeline(depth: int):
    """Workload: [heavy-upload, light, light] × 4 on one device. A heavy
    task's 60 ms upload overlaps one 40 ms compute at depth 1 (always a
    20 ms stall) but two computes at depth 2 (done 20 ms early)."""
    def light_kernel(v):
        return float(v[0])
    light_kernel.compute_s = 0.04

    def heavy_kernel(v):
        return float(v[0])
    heavy_kernel.compute_s = 0.04

    dev = FakeDevice(0, upload_s=0.06)
    cfg = RuntimeConfig(memory_capacity=1 << 28, sync_dispatch=True,
                        prefetch=True, prefetch_depth=depth)
    with Runtime(cfg, devices=[dev]) as rt:
        shared = rt.hetero_object(np.ones(4, np.float32))
        rt._ensure_on_device(shared, 0, will_write=False)  # lights resident
        for _ in range(4):
            heavy = rt.hetero_object(np.ones(256, np.float32))
            rt.run(heavy_kernel, [(heavy, "r")])
            rt.run(light_kernel, [(shared, "r")])
            rt.run(light_kernel, [(shared, "r")])
        rt.barrier(timeout=60)
        return rt.stats()


def test_prefetch_depth2_overlaps_more_than_depth1():
    s1 = _run_depth_pipeline(depth=1)
    s2 = _run_depth_pipeline(depth=2)
    # depth 1 cannot hide a 60ms upload behind one 40ms compute: the heavy
    # staging always stalls. depth 2 stages it two computes ahead.
    assert s2["prefetch_hits"] > s1["prefetch_hits"], (s1, s2)
    assert s1["prefetch_stalls"] >= 2, s1
    assert s2["prefetch_hits"] >= 2, s2


# ---------------------------------------------------------------------------
# pooled D2H staging path
# ---------------------------------------------------------------------------

def test_download_stages_into_pool_no_aliasing():
    """The host copy of a device-written object must be a pooled private
    buffer, never a zero-copy view of the device buffer (which donation
    could recycle underneath it)."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        x = rt.hetero_object(np.arange(1024, dtype=np.float32))
        rt.run(lambda v: v + 1.0, [(x, "rw")])
        rt.barrier()
        fut = x.request_host(write=False)
        host = fut.get(5)
        try:
            with x.lock:
                dev_sp = next(s for s in x.copies if s != HOST)
                dev_view = np.asarray(x.copies[dev_sp])
            assert not np.may_share_memory(host, dev_view)
            assert getattr(x, "_pooled_host", False)
        finally:
            x.release()
        np.testing.assert_allclose(x.get(), np.arange(1024) + 1.0)


def test_download_buffers_recycle_through_pool():
    """Invalidation of a staged host copy must return the pool buffer:
    repeated write→read cycles hit the staging pool."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        x = rt.hetero_object(np.zeros((64, 64), np.float32))
        for i in range(4):
            rt.run(lambda v: v + 1.0, [(x, "rw")])   # invalidates host copy
            rt.barrier()
            np.testing.assert_allclose(x.get(), float(i + 1))
        assert rt.staging.hits > 0, rt.stats()


def test_pooled_host_buffer_recycles_after_pinned_drop():
    """Regression: dropping a pooled HOST copy while a pin still hands the
    buffer out (request → free → release) must not strand the buffer —
    release() returns it to the pool."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        x = rt.hetero_object(shape=(32, 32), dtype=np.float32)
        rt.run(lambda v: v + 1.0, [(x, "w")])
        rt.barrier()
        fut = x.request_host(write=False)     # pooled D2H staging
        fut.get(5)
        assert getattr(x, "_pooled_host", False)
        x.free()                              # drops HOST while pinned
        hits0 = rt.staging.hits
        x.release()                           # last pin: buffer → pool
        rt.staging.acquire((32, 32), np.float32)
        assert rt.staging.hits == hits0 + 1


def test_chunked_download_bit_exact():
    with Runtime(RuntimeConfig(memory_capacity=1 << 28,
                               staging_chunk_bytes=1 << 10)) as rt:
        data = np.random.default_rng(7).random((64, 64)).astype(np.float32)
        x = rt.hetero_object(data.copy())
        rt.run(lambda v: v * 3.0, [(x, "rw")])
        rt.barrier()
        np.testing.assert_allclose(x.get(), data * 3.0, rtol=1e-6)
        assert rt.stats()["transfers_d2h"] >= 1


def test_stats_surface_pool_and_residency_gauges():
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        x = rt.hetero_object(np.ones((32, 32), np.float32))
        rt.run(lambda v: v * 2.0, [(x, "rw")])
        rt.barrier()
        s = rt.stats()
        for key in ("staging_hits", "staging_misses", "request_pool_hits",
                    "request_pool_misses", "bytes_resident",
                    "objects_resident", "evictions", "prefetch_stalls",
                    "pinned_objects", "topology"):
            assert key in s, key
        assert sum(s["bytes_resident"].values()) >= x.nbytes
        assert x.resident_devices() <= set(s["bytes_resident"])


# ---------------------------------------------------------------------------
# ledger-owned pins (ROADMAP follow-up c)
# ---------------------------------------------------------------------------

def test_ledger_pin_blocks_eviction_without_object_locks():
    led = ResidencyLedger({0: 1000})
    a, b = _obj(64), _obj(64)                 # 256 B each
    led.record(0, a)
    led.record(0, b)
    led.pin(a)
    seen = []

    def evict(obj, dev):
        seen.append(obj)
        led.drop(dev, obj)
        return True

    led.ensure_capacity(0, 900, evict)
    assert a not in seen and b in seen        # pinned replica skipped
    assert led.pinned(a) and not led.pinned(b)
    led.unpin(a)
    assert not led.pinned(a)
    assert led.gauges()["pinned_objects"] == 0


def test_pin_counts_nest():
    led = ResidencyLedger({0: 1 << 20})
    a = _obj(16)
    led.pin(a)
    led.pin(a)
    led.unpin(a)
    assert led.pinned(a)
    led.unpin(a)
    assert not led.pinned(a)


def test_runtime_pins_during_host_access_and_tasks():
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        x = rt.hetero_object(np.ones((32,), np.float32))
        fut = x.request_host(write=False)
        fut.get(5)
        assert rt.residency.pinned(x)         # pinned until release
        x.release()
        assert not rt.residency.pinned(x)
        rt.run(lambda v: v + 1.0, [(x, "rw")])
        rt.barrier()
        assert not rt.residency.pinned(x)     # unpinned at task finish


def test_eviction_under_pressure_skips_pinned_and_stays_correct():
    """A pinned object's device replica survives capacity pressure; the
    unpinned one is evicted instead (spilled to host, data intact)."""
    cfg = RuntimeConfig(memory_capacity=350 << 10, topology_probe=False,
                        scheduler="fifo", dedicated_threads=False)
    with Runtime(cfg) as rt:
        keep = rt.hetero_object(np.ones((128, 128), np.float32))   # 64 KB
        spill = rt.hetero_object(np.full((128, 128), 2.0, np.float32))
        rt._ensure_on_device(keep, 0, will_write=False)
        rt._ensure_on_device(spill, 0, will_write=False)
        rt.residency.pin(keep)
        big = rt.hetero_object(np.zeros((256, 256), np.float32))   # 256 KB
        rt._ensure_on_device(big, 0, will_write=False)
        assert rt.residency.holds(0, keep)
        assert not rt.residency.holds(0, spill)
        rt.residency.unpin(keep)
        np.testing.assert_allclose(spill.get(), 2.0)


# ---------------------------------------------------------------------------
# re-score aged ready-queue entries on pop (ROADMAP follow-up a)
# ---------------------------------------------------------------------------

def test_gravity_pop_rescores_stale_placement():
    led = ResidencyLedger({0: 1 << 20, 1: 1 << 20})
    s = GravityScheduler({0: "cpu", 1: "cpu"})
    s.placement.bind(led)
    o = _obj(1 << 14)
    led.record(0, o)
    t = _task(o)
    s.push(t)
    assert s.queued[0] == 1                   # placed with its data
    led.drop(0, o)                            # residency shifts...
    led.record(1, o)
    assert s.pop(0) is None                   # stale head re-homed
    assert s.queued == {0: 0, 1: 1}
    got, dev = s.pop(1)
    assert got is t and dev == 1
    assert s.queued == {0: 0, 1: 0}


def test_gravity_pop_without_residency_change_is_untouched():
    led = ResidencyLedger({0: 1 << 20, 1: 1 << 20})
    s = GravityScheduler({0: "cpu", 1: "cpu"})
    s.placement.bind(led)
    o = _obj(1 << 14)
    led.record(0, o)
    t = _task(o)
    s.push(t)
    got, dev = s.pop(0)                       # version unchanged: O(1) pop
    assert got is t and dev == 0


def test_rescore_disabled_for_load_only_policies():
    from repro.core import LeastLoadedScheduler
    assert GravityScheduler.rescore_on_pop
    assert not LeastLoadedScheduler.rescore_on_pop
