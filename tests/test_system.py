"""End-to-end behaviour tests: the full system wired together — runtime +
distributed layer + training driver + serving engine + dry-run machinery."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_train_driver_end_to_end(tmp_path):
    """launch.train: fresh run, then resume from checkpoint — the production
    driver path."""
    from repro.launch.train import main as train_main
    args = ["--arch", "yi-9b", "--smoke", "--steps", "12",
            "--global-batch", "4", "--seq-len", "32", "--ckpt-every", "6",
            "--checkpoint-dir", str(tmp_path), "--log-every", "6"]
    state = train_main(args)
    assert int(state.opt.step) == 12
    # resume: driver must pick up from the last committed checkpoint
    state2 = train_main(args + ["--steps", "18"])
    assert int(state2.opt.step) == 18


def test_serve_engine_end_to_end():
    from repro.launch.serve import main as serve_main
    out = serve_main(["--arch", "recurrentgemma-9b", "--smoke",
                      "--batch", "2", "--prompt-len", "16", "--gen", "4"])
    assert out.shape == (2, 4)
    assert not bool(jnp.any(out < 0))


def test_prema_jacobi_pipeline_with_runtime():
    """The paper's proxy pipeline: over-decomposed Jacobi through the tasking
    runtime matches the reference and actually overlaps (more tasks than
    chunks·iters implies halo+update pipelines ran)."""
    from repro.apps.jacobi3d import run_reference, run_tasked
    from repro.core import Runtime, RuntimeConfig
    rng = np.random.default_rng(2)
    u0 = rng.random((8, 8, 8)).astype(np.float32)
    want = run_reference(u0, 2)
    with Runtime(RuntimeConfig(memory_capacity=1 << 26)) as rt:
        got = run_tasked(u0, 2, rt, over_decomposition=2)
        stats = rt.stats()
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    assert stats["tasks"] > 2 * 2  # halo tasks + update tasks per iteration


def test_dryrun_machinery_smoke():
    """lower_cell on the production mesh in a subprocess (512 virtual
    devices) — the smallest cell, end to end through the real dry-run path."""
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = "
        "'--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.dryrun import lower_cell\n"
        "r = lower_cell('olmoe_1b_7b', 'decode_32k')\n"
        "assert r['chips'] == 256, r['chips']\n"
        "assert r.get('flops_per_device', 0) > 0\n"
        "assert r['bottleneck'] in ('compute', 'memory', 'collective')\n"
        "print('dryrun ok', r['bottleneck'])\n"
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "dryrun ok" in out.stdout


def test_dryrun_results_all_pass():
    """If the sweep has been run, every produced cell must be error-free on
    both meshes (the multi-pod deliverable)."""
    import glob
    files = glob.glob(os.path.join(REPO, "benchmarks", "results", "dryrun",
                                   "*__baseline.json"))
    if not files:
        pytest.skip("dry-run sweep not yet executed")
    bad = []
    for f in files:
        d = json.load(open(f))
        if "error" in d:
            bad.append(os.path.basename(f))
    assert not bad, f"failed dry-run cells: {bad}"
