import os

# Tests must see exactly ONE device (the dry-run sets 512 in its own
# process); keep any user XLA_FLAGS out of the test environment.
os.environ.pop("XLA_FLAGS", None)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
