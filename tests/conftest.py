import os

# Tests run with a controlled TWO-device CPU view so the transfer engine's
# multi-device paths (direct D2D copies, per-device transfer queues,
# indexed scheduler placement) are exercised in-process. Any user-supplied
# XLA_FLAGS are dropped first (the dry-run sets 512 in its own subprocess;
# multi-device tests that need more spawn subprocesses with their own
# counts).
os.environ.pop("XLA_FLAGS", None)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def pytest_sessionfinish(session, exitstatus):
    """Session-end sanitizer gate (active under REPRO_SANITIZE=1): the
    whole suite is the false-positive corpus. Every lock acquisition of
    every test fed one global may-precede graph; a cycle anywhere is a
    potential deadlock and fails the run even though no test hung."""
    from repro.core import sanitizer

    san = sanitizer.current()
    if san is None:
        return
    snap = san.stats_snapshot()
    cycles = san.lock_order_cycles()
    print(f"\n[sanitizer] {snap}")
    if cycles:
        print(f"[sanitizer] lock-order cycles: {cycles}")
        print(f"[sanitizer] edges: {sorted(san.lock_order_edges())}")
        session.exitstatus = 1
        raise sanitizer.SanitizerError(
            f"lock-order cycles observed across the suite: {cycles}")
