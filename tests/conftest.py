import os

# Tests run with a controlled TWO-device CPU view so the transfer engine's
# multi-device paths (direct D2D copies, per-device transfer queues,
# indexed scheduler placement) are exercised in-process. Any user-supplied
# XLA_FLAGS are dropped first (the dry-run sets 512 in its own subprocess;
# multi-device tests that need more spawn subprocesses with their own
# counts).
os.environ.pop("XLA_FLAGS", None)
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
