"""Task-graph fast path: trace → compile → replay bit-identity and the
invalidation taxonomy (structural deviation / eviction / epoch bump /
host-access flush), plus the pooled-engine cluster acceptance test."""
import numpy as np
import pytest

from repro.apps.jacobi3d import run_reference, run_tasked
from repro.core import Runtime, RuntimeConfig
from repro.distributed.elastic import ElasticRuntime, OwnerMap
from repro.distributed.messaging import Cluster


def _rt(**kw):
    kw.setdefault("memory_capacity", 1 << 28)
    return Runtime(RuntimeConfig(**kw))


def bump(v):
    return v + 1.0


def axpy(av, yv):
    return yv + av


# ---------------------------------------------------------------------------
# bit-identity: replayed windows produce the same bits as interpreted ones
# ---------------------------------------------------------------------------

def test_jacobi_traced_bit_identical():
    rng = np.random.default_rng(0)
    u0 = rng.standard_normal((12, 12, 12)).astype(np.float32)
    iters = 8
    ref = run_reference(u0, iters)
    with _rt() as rt_i:
        interp = run_tasked(u0, iters, rt_i, over_decomposition=2)
    with _rt(trace_graphs=True, replay_after=3) as rt_t:
        traced = run_tasked(u0, iters, rt_t, over_decomposition=2)
        st = rt_t.stats()
    assert st["graphs_traced"] >= 1
    assert st["graph_replays"] >= 1
    assert st["replayed_tasks"] > 0
    # the fast path must be invisible: bit-identical, not just close
    np.testing.assert_array_equal(traced, interp)
    np.testing.assert_allclose(traced, ref, rtol=1e-6, atol=1e-6)


def _train_loop(rt, steps):
    """Toy microbatch train step: grad then in-place apply — the
    recurring two-task window of a training loop."""
    w = rt.hetero_object(np.full((64,), 0.5, np.float32), name="w")
    g = rt.hetero_object(np.zeros((64,), np.float32), name="g")
    x = rt.hetero_object(np.linspace(0.0, 1.0, 64, dtype=np.float32),
                         name="x")

    def grad(xv, wv, out):
        return (wv - xv) * 0.5

    def apply_(gv, wv):
        return wv - 0.1 * gv

    for _ in range(steps):
        rt.run(grad, [(x, "r"), (w, "r"), (g, "w")])
        rt.run(apply_, [(g, "r"), (w, "rw")])
        rt.step_boundary()
    rt.barrier()
    return np.asarray(w.get()).copy()


def test_microbatch_train_traced_bit_identical():
    with _rt() as rt_i:
        w_interp = _train_loop(rt_i, steps=10)
    with _rt(trace_graphs=True, replay_after=3) as rt_t:
        w_traced = _train_loop(rt_t, steps=10)
        st = rt_t.stats()
    assert st["graphs_traced"] == 1
    assert st["graph_replays"] >= 1
    np.testing.assert_array_equal(w_traced, w_interp)


# ---------------------------------------------------------------------------
# invalidation taxonomy
# ---------------------------------------------------------------------------

def test_invalidation_on_shape_change():
    with _rt(trace_graphs=True, replay_after=2) as rt:
        a = rt.hetero_object(np.ones((16,), np.float32))
        for _ in range(4):
            rt.run(bump, [(a, "rw")])
            rt.step_boundary()
        rt.barrier()
        st = rt.stats()
        assert st["graphs_traced"] == 1 and st["graph_replays"] >= 1
        # a different-shaped object in the same structural position is a
        # deviation (shape is part of the signature via object identity)
        b = rt.hetero_object(np.ones((32,), np.float32))
        rt.run(bump, [(b, "rw")])
        rt.step_boundary()
        rt.barrier()
        assert rt.stats()["graph_invalidations"] >= 1
        np.testing.assert_allclose(a.get(), 5.0)
        np.testing.assert_allclose(b.get(), 2.0)


def test_invalidation_on_eviction():
    with _rt(trace_graphs=True, replay_after=2) as rt:
        a = rt.hetero_object(np.ones((16,), np.float32))
        y = rt.hetero_object(np.zeros((16,), np.float32))
        for _ in range(3):
            rt.run(axpy, [(a, "r"), (y, "rw")])
            rt.step_boundary()
        rt.barrier()
        assert rt.stats()["graph_replays"] >= 1
        # evict the read-only entry replica the replay plan counted on
        devs = sorted(rt.residency.devices_of(a))
        assert devs, "compiled entry should be device-resident"
        assert rt._evict(a, devs[0])
        inv0 = rt.stats()["graph_invalidations"]
        rt.run(axpy, [(a, "r"), (y, "rw")])
        rt.step_boundary()
        rt.barrier()
        # the stale window still executed correctly (coherence walk) and
        # the plan was retired afterwards
        assert rt.stats()["graph_invalidations"] == inv0 + 1
        np.testing.assert_allclose(y.get(), 4.0)


def test_invalidation_on_epoch_bump():
    cfg = RuntimeConfig(memory_capacity=1 << 26, trace_graphs=True,
                        replay_after=2)
    with Cluster(2, cfg) as c:
        rt0 = c.ranks[0].runtime
        a = rt0.hetero_object(np.ones((8,), np.float32))
        for _ in range(3):
            rt0.run(bump, [(a, "rw")])
            rt0.step_boundary()
        rt0.barrier()
        assert rt0._tracer.graph() is not None
        er = ElasticRuntime(c, OwnerMap())
        er._bump_epoch()
        assert er.epoch == 1
        # placements captured under the old epoch are gone on every rank
        assert rt0._tracer.graph() is None
        assert rt0.stats()["graph_invalidations"] >= 1
        # recurrence detection restarts cleanly afterwards
        for _ in range(3):
            rt0.run(bump, [(a, "rw")])
            rt0.step_boundary()
        rt0.barrier()
        assert rt0.stats()["graphs_traced"] == 2
        np.testing.assert_allclose(a.get(), 7.0)


def test_host_read_flushes_but_keeps_graph():
    with _rt(trace_graphs=True, replay_after=2) as rt:
        a = rt.hetero_object(np.zeros((8,), np.float32))
        for _ in range(3):
            rt.run(bump, [(a, "rw")])
            rt.step_boundary()
        rt.barrier()
        assert rt.stats()["graph_replays"] == 1
        # mid-window host read: the parked task must flush so the read
        # observes its write — but the graph stays armed
        rt.run(bump, [(a, "rw")])
        np.testing.assert_allclose(a.get(), 4.0)       # flush + observe
        rt.step_boundary()
        rt.barrier()
        st = rt.stats()
        assert rt._tracer.graph() is not None
        assert st["graph_invalidations"] == 0
        # next full window replays again
        rt.run(bump, [(a, "rw")])
        rt.step_boundary()
        rt.barrier()
        assert rt.stats()["graph_replays"] == 2
        np.testing.assert_allclose(a.get(), 5.0)


# ---------------------------------------------------------------------------
# acceptance: pooled engine under sustained cluster barrier traffic
# ---------------------------------------------------------------------------

def test_cluster_barrier_200_iterations_pooled():
    cfg = RuntimeConfig(memory_capacity=1 << 26, pool_workers=4)
    with Cluster(2, cfg) as c:
        for _ in range(200):
            c.barrier(timeout=30.0)
