"""Elastic training end-to-end: failure → shrink → restore → continue,
bit-identical to an uninterrupted run (data pipeline is stateless)."""
import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_elastic_shrink_continues_identically(tmp_path):
    code = f"""
        import numpy as np
        from repro.launch.elastic_train import run_elastic
        # elastic run: 8 devices → fail 4 at step 4 → finish on 4
        losses_el, worlds = run_elastic(steps=8, fail_at=4,
                                        ckpt_dir={str(tmp_path / 'a')!r})
        assert worlds[:4] == [8] * 4 and worlds[4:] == [4] * 4, worlds
        # reference: same model/data on a fixed 4-device world, no failure
        losses_ref, _ = run_elastic(steps=8, fail_at=8,
                                    ckpt_dir={str(tmp_path / 'b')!r})
        # world size must not affect the math (global batch fixed):
        np.testing.assert_allclose(losses_el, losses_ref, rtol=1e-4)
        print('elastic == uninterrupted:', np.max(np.abs(
            np.array(losses_el) - np.array(losses_ref))))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=1200,
                         env=env)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "elastic == uninterrupted" in out.stdout
