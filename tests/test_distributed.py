"""PREMA distributed layer: messaging protocol, put/get, owner map,
over-decomposition, elastic control, Jacobi3D end-to-end."""
import threading
import time

import numpy as np
import pytest

from repro.core import Runtime, RuntimeConfig
from repro.distributed import (Cluster, ElasticController, OwnerMap,
                               block_distribution, handler, microbatch_plan,
                               plan_decomposition, rebalance_greedy)
from repro.apps.jacobi3d import (run_cluster, run_reference, run_spmd,
                                 run_tasked)

_received = {}
_lock = threading.Lock()


@handler(name="test_recv")
def _recv_handler(ctx, obj):
    with _lock:
        _received[ctx.message.src] = None if obj is None else obj.get()


@handler(name="test_pong")
def _pong_handler(ctx, obj):
    ctx.send(ctx.message.src, "test_recv", obj)


@handler(name="put_done")
def _put_done(ctx, obj):
    with _lock:
        _received["put_done"] = True


def _wait_for(pred, timeout=10.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        with _lock:
            if pred():
                return True
        time.sleep(0.005)
    return False


@pytest.fixture()
def cluster():
    cfg = RuntimeConfig(memory_capacity=1 << 26)
    with Cluster(2, cfg) as c:
        _received.clear()
        yield c


def test_handler_invocation_no_payload(cluster):
    cluster.ranks[0].send(1, "test_recv")
    assert _wait_for(lambda: 0 in _received)
    assert _received[0] is None


def test_hetero_object_payload_roundtrip(cluster):
    """mp_send with a hetero_object payload → handler sees the data; the
    two-phase metadata+payload protocol runs underneath."""
    data = np.arange(4096, dtype=np.float32).reshape(64, 64)
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "test_pong", obj)
    assert _wait_for(lambda: 1 in _received)
    np.testing.assert_allclose(_received[1], data)


def test_small_message_inline_path(cluster):
    """≤512B payloads ride inside the metadata message (paper §4.2.3)."""
    data = np.arange(8, dtype=np.float32)       # 32 bytes → inline
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "test_recv", obj)
    assert _wait_for(lambda: 0 in _received)
    np.testing.assert_allclose(_received[0], data)


def test_put_overwrites_remote_object(cluster):
    target = cluster.ranks[1].runtime.hetero_object(
        np.zeros((32,), np.float32))
    cluster.ranks[1].register_object("tgt", target)
    src = cluster.ranks[0].runtime.hetero_object(
        np.full((32,), 7.0, np.float32))
    cluster.ranks[0].put(1, "tgt", src, on_done="put_done")
    assert _wait_for(lambda: _received.get("put_done"))
    np.testing.assert_allclose(target.get(), 7.0)


def test_direct_path_device_payload_no_host_staging(cluster):
    """path='direct' (§3.2.3 Fig. 7): a device-resident payload travels as a
    device array and lands via one Device API transfer; both ends account
    the traffic as D2D, not staged."""
    data = np.arange(4096, dtype=np.float32).reshape(64, 64)
    rt0 = cluster.ranks[0].runtime
    obj = rt0.hetero_object(data)
    rt0.run(lambda v: v + 1.0, [(obj, "rw")])   # leaves a device-only copy
    rt0.barrier()
    cluster.ranks[0].send(1, "test_recv", obj, path="direct")
    assert _wait_for(lambda: 0 in _received)
    np.testing.assert_allclose(_received[0], data + 1.0)
    assert cluster.ranks[0].stats["bytes_d2d"] >= data.nbytes
    assert cluster.ranks[1].stats["bytes_d2d"] >= data.nbytes
    assert cluster.ranks[0].stats["bytes_staged"] == 0


def test_direct_send_survives_subsequent_donating_writer(cluster):
    """Regression: a DIRECT send snapshots the device copy; a writer task
    submitted right after must not delete the payload via buffer donation
    (the send pins the view, and the payload is a private clone)."""
    data = np.arange(4096, dtype=np.float32).reshape(64, 64)
    rt0 = cluster.ranks[0].runtime
    for trial in range(5):
        _received.pop(0, None)
        obj = rt0.hetero_object(data.copy())
        rt0.run(lambda v: v + 1.0, [(obj, "rw")])
        rt0.barrier()
        cluster.ranks[0].send(1, "test_recv", obj, path="direct")
        # donation-eligible writer racing the in-flight snapshot
        rt0.run(lambda v: v * 0.0, [(obj, "rw")])
        rt0.barrier()
        assert _wait_for(lambda: 0 in _received), f"trial {trial}: lost"
        np.testing.assert_allclose(_received[0], data + 1.0,
                                   err_msg=f"trial {trial}")


@handler(name="test_keep")
def _keep_handler(ctx, obj):
    with _lock:
        _received["kept"] = obj


def test_direct_payload_lands_on_consumer_device(cluster):
    """Consumer-routed delivery (ROADMAP follow-up d): a DIRECT payload
    with a consumer_device hint must land on that device — not on the
    historical hardwired device 0."""
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    data = np.arange(4096, dtype=np.float32).reshape(64, 64)
    rt0 = cluster.ranks[0].runtime
    obj = rt0.hetero_object(data)
    rt0.run(lambda v: v + 1.0, [(obj, "rw")])   # leaves a device-only copy
    rt0.barrier()
    cluster.ranks[0].send(1, "test_keep", obj, path="direct",
                          consumer_device=1)
    assert _wait_for(lambda: "kept" in _received)
    landed = _received["kept"]
    assert landed.resident_devices() == {1}, landed.valid_spaces()
    np.testing.assert_allclose(landed.get(), data + 1.0)


def test_direct_payload_route_to_registration(cluster):
    """The receiver-side route_to(handler, device) registration routes
    DIRECT payloads without any sender-side hint."""
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    cluster.ranks[1].route_to("test_keep", 1)
    try:
        data = np.full((64, 64), 3.0, np.float32)
        rt0 = cluster.ranks[0].runtime
        obj = rt0.hetero_object(data)
        rt0.run(lambda v: v * 2.0, [(obj, "rw")])
        rt0.barrier()
        cluster.ranks[0].send(1, "test_keep", obj, path="direct")
        assert _wait_for(lambda: "kept" in _received)
        assert _received["kept"].resident_devices() == {1}
    finally:
        cluster.ranks[1].routes.clear()


def test_invalid_consumer_hint_falls_through_to_route(cluster):
    """A consumer_device naming a nonexistent device must not shadow the
    receiver's route_to registration (documented fall-through chain)."""
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    cluster.ranks[1].route_to("test_keep", 1)
    try:
        data = np.full((64, 64), 5.0, np.float32)
        rt0 = cluster.ranks[0].runtime
        obj = rt0.hetero_object(data)
        rt0.run(lambda v: v + 1.0, [(obj, "rw")])
        rt0.barrier()
        cluster.ranks[0].send(1, "test_keep", obj, path="direct",
                              consumer_device=99)
        assert _wait_for(lambda: "kept" in _received)
        assert _received["kept"].resident_devices() == {1}
    finally:
        cluster.ranks[1].routes.clear()


def test_direct_payload_fallback_is_least_loaded(cluster):
    """With no consumer known, the landing device comes from the residency
    ledger (least pressure, then fewest bytes resident) — loading device 0
    with resident bytes must steer the payload to device 1."""
    rt1 = cluster.ranks[1].runtime
    if len(rt1.devices) < 2:
        pytest.skip("needs >= 2 (virtual) devices")
    ballast = rt1.hetero_object(np.ones((128, 128), np.float32))
    rt1._ensure_on_device(ballast, 0, will_write=False)   # device 0 heavier
    data = np.arange(1024, dtype=np.float32)
    rt0 = cluster.ranks[0].runtime
    obj = rt0.hetero_object(data)
    rt0.run(lambda v: v + 1.0, [(obj, "rw")])
    rt0.barrier()
    cluster.ranks[0].send(1, "test_keep", obj, path="direct")
    assert _wait_for(lambda: "kept" in _received)
    assert _received["kept"].resident_devices() == {1}


def test_direct_path_host_only_falls_back_to_staged(cluster):
    """A direct send of an object with no device copy degrades gracefully
    to the host-staged protocol."""
    data = np.arange(1024, dtype=np.float32)
    obj = cluster.ranks[0].runtime.hetero_object(data)
    cluster.ranks[0].send(1, "test_recv", obj, path="direct")
    assert _wait_for(lambda: 0 in _received)
    np.testing.assert_allclose(_received[0], data)
    assert cluster.ranks[0].stats["bytes_staged"] >= data.nbytes


def test_get_remote_object(cluster):
    src_obj = cluster.ranks[1].runtime.hetero_object(
        np.full((16,), 3.0, np.float32))
    cluster.ranks[1].register_object("src", src_obj)
    cluster.ranks[0].get(1, "src", "test_recv")
    assert _wait_for(lambda: 1 in _received)
    np.testing.assert_allclose(_received[1], 3.0)


# ---------------------------------------------------------------------------
# owner map / over-decomposition / elastic
# ---------------------------------------------------------------------------

def test_block_distribution_balanced():
    d = block_distribution(16, 4)
    counts = {r: sum(1 for v in d.values() if v == r) for r in range(4)}
    assert all(c == 4 for c in counts.values())


def test_rebalance_moves_from_hot_rank():
    owner = OwnerMap()
    for i in range(8):
        owner.assign(i, 0 if i < 6 else 1)
    loads = {0: 6.0, 1: 2.0}
    plan = rebalance_greedy(loads, owner, {i: 1.0 for i in range(8)})
    assert plan, "expected at least one migration"
    assert all(src == 0 and dst == 1 for _, src, dst in plan)
    c0 = len(owner.owned_by(0))
    assert 3 <= c0 <= 5


def test_elastic_shrink_reassigns_dead_chunks():
    owner = OwnerMap()
    for i in range(12):
        owner.assign(i, i % 3)
    ec = ElasticController([0, 1, 2], heartbeat_timeout=0.01)
    ec.heartbeat(0)
    ec.heartbeat(1)
    ec.health[2].last_heartbeat -= 1.0   # rank 2 went silent
    dead = ec.detect_failures()
    assert dead == [2]
    plan = ec.shrink_plan(owner, dead)
    assert len(plan) == 4
    assert not owner.owned_by(2)


def test_straggler_mitigation_drains_slow_rank():
    owner = OwnerMap()
    for i in range(8):
        owner.assign(i, i % 2)
    ec = ElasticController([0, 1])
    ec.heartbeat(0, slowdown=4.0)   # rank 0 is 4x slower
    ec.heartbeat(1, slowdown=1.0)
    plan = ec.straggler_plan(owner)
    assert plan and all(src == 0 for _, src, dst in plan)


def test_decomposition_geometry():
    plan = plan_decomposition((32, 16, 16), n_workers=2, over_decomposition=2)
    assert len(plan.chunks) == 4
    covered = np.zeros((32, 16, 16), bool)
    for c in plan.chunks:
        covered[c.lo[0]:c.hi[0], c.lo[1]:c.hi[1], c.lo[2]:c.hi[2]] = True
    assert covered.all()
    # neighbor symmetry
    for c in plan.chunks:
        for tag, other in plan.neighbors(c.cid).items():
            if other is None:
                continue
            opp = {"lo": "hi", "hi": "lo"}[tag[:2]] + tag[2]
            assert plan.neighbors(other)[opp] == c.cid


def test_microbatch_plan():
    assert microbatch_plan(256, 4) == [64, 64, 64, 64]


# ---------------------------------------------------------------------------
# Jacobi3D end-to-end (paper §4.3)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("od", [1, 2, 4])
def test_jacobi_tasked_matches_reference(od):
    rng = np.random.default_rng(0)
    u0 = rng.random((16, 8, 8)).astype(np.float32)
    want = run_reference(u0, 3)
    with Runtime(RuntimeConfig(memory_capacity=1 << 26)) as rt:
        got = run_tasked(u0, 3, rt, over_decomposition=od)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_jacobi_cluster_matches_reference():
    """The distributed Jacobi proxy on the message engine (scatter via
    send, halos via put, gather via send) matches the oracle."""
    rng = np.random.default_rng(2)
    u0 = rng.random((16, 8, 8)).astype(np.float32)
    want = run_reference(u0, 3)
    with Cluster(2, RuntimeConfig(memory_capacity=1 << 26)) as c:
        got = run_cluster(u0, 3, c)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_jacobi_cluster_large_slabs_ride_rendezvous():
    """With slabs above the eager threshold the scatter/gather legs use
    the chunk-streamed rendezvous protocol — numerics must be identical."""
    rng = np.random.default_rng(3)
    u0 = rng.random((32, 32, 32)).astype(np.float32)   # 64 KB slabs
    want = run_reference(u0, 2)
    cfg = RuntimeConfig(memory_capacity=1 << 28, eager_threshold=16 << 10,
                        chunk_bytes=16 << 10)
    with Cluster(2, cfg) as c:
        got = run_cluster(u0, 2, c)
        assert c.ranks[0].stats["rendezvous"] >= 1     # scatter leg
        assert c.ranks[1].stats["rendezvous"] >= 1     # gather leg
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("bulk_sync", [False, True])
def test_jacobi_spmd_matches_reference(bulk_sync):
    from repro.launch.mesh import make_smoke_mesh
    rng = np.random.default_rng(1)
    u0 = rng.random((8, 8, 8)).astype(np.float32)
    want = run_reference(u0, 3)
    mesh = make_smoke_mesh(1, 1)
    got = run_spmd(u0, 3, mesh, axis="data", bulk_sync=bulk_sync)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
