"""Transfer engine (paper §3.2.3 + §4.1.3): direct D2D coherence moves,
argument prefetch pipeline, per-device transfer queues, indexed scheduler
ready queues, and staging/request pool recycling.

conftest.py forces a 2-device CPU view, so every test here exercises real
cross-device movement in-process.
"""
import numpy as np
import pytest

from repro.core import (HOST, HeteroTask, Runtime, RuntimeConfig, TaskState)
from repro.core.device_api import discover_devices, transfer
from repro.core.scheduler import (SCHEDULERS, FifoScheduler,
                                  LeastLoadedScheduler,
                                  LocalityAwareScheduler,
                                  RoundRobinScheduler)


class _RoundRobinNoSteal(RoundRobinScheduler):
    """Deterministic cross-device placement for the D2D chain test: without
    stealing, a task indexed to device 1 always runs on device 1."""
    steals = False


SCHEDULERS.setdefault("_test_rr_nosteal", _RoundRobinNoSteal)


def _two_device_rt(**overrides) -> Runtime:
    cfg = RuntimeConfig(memory_capacity=1 << 28, **overrides)
    rt = Runtime(cfg)
    if len(rt.devices) < 2:
        rt.shutdown()
        pytest.skip("needs >= 2 (virtual) devices")
    return rt


# ---------------------------------------------------------------------------
# direct device-to-device path
# ---------------------------------------------------------------------------

def test_device_api_transfer_roundtrip():
    devs = discover_devices(memory_capacity=1 << 28)
    if len(devs) < 2:
        pytest.skip("needs >= 2 devices")
    host = np.arange(256, dtype=np.float32).reshape(16, 16)
    on0 = devs[0].upload(host)
    on1 = transfer(devs[0], devs[1], on0)
    np.testing.assert_array_equal(devs[1].download(on1), host)


def test_ensure_on_device_prefers_d2d():
    """With a device copy present and no host copy, the coherence walk must
    move data device→device — zero D2H and zero extra H2D traffic."""
    with _two_device_rt() as rt:
        x = rt.hetero_object(np.arange(64, dtype=np.float32))
        rt._ensure_on_device(x, 0, will_write=False)
        h2d_before = rt.stats()["transfers_h2d"]
        with x.lock:
            rt._drop_copy(x, HOST)      # device 0 now holds the only copy
        rt._ensure_on_device(x, 1, will_write=False)
        s = rt.stats()
        assert s["transfers_d2d"] == 1
        assert s["bytes_d2d"] == x.nbytes
        assert s["transfers_h2d"] == h2d_before   # no re-upload
        assert s["transfers_d2h"] == 0            # and no host bounce
        np.testing.assert_array_equal(x.get(),
                                      np.arange(64, dtype=np.float32))


def test_d2d_disabled_falls_back_to_host_staging():
    with _two_device_rt(d2d=False) as rt:
        x = rt.hetero_object(np.ones(64, dtype=np.float32))
        rt._ensure_on_device(x, 0, will_write=False)
        with x.lock:
            rt._drop_copy(x, HOST)
        rt._ensure_on_device(x, 1, will_write=False)
        s = rt.stats()
        assert s["transfers_d2d"] == 0
        assert s["transfers_d2h"] == 1      # staged: device→host→device
        np.testing.assert_array_equal(x.get(), 1.0)


def test_cross_device_producer_consumer_chain_uses_d2d():
    """Acceptance: a producer→consumer chain spanning two devices moves the
    intermediate via the D2D path with no D2H+H2D bounce for that hop."""
    with _two_device_rt(scheduler="_test_rr_nosteal") as rt:
        x = rt.hetero_object(np.full((32, 32), 2.0, np.float32))
        y = rt.hetero_object(shape=(32, 32), dtype=np.float32)
        t1 = rt.run(lambda v: v + 1.0, [(x, "rw")])           # → device 0
        t2 = rt.run(lambda a, out: a * 10.0, [(x, "r"), (y, "w")])  # → dev 1
        rt.barrier()
        assert t1.chosen_device != t2.chosen_device, \
            (t1.chosen_device, t2.chosen_device)
        s = rt.stats()
        assert s["transfers_d2d"] >= 1
        assert s["transfers_d2h"] == 0      # the hop never touched host
        assert s["bytes_d2h"] == 0
        np.testing.assert_allclose(y.get(), 30.0)
        np.testing.assert_allclose(x.get(), 3.0)


def test_coherence_after_mixed_d2d_and_host_writes():
    """D2D replication then a host write must invalidate device copies;
    subsequent device reads see the host data (MESI-like single rule)."""
    with _two_device_rt() as rt:
        x = rt.hetero_object(np.zeros(16, dtype=np.float32))
        rt.run(lambda v: v + 5.0, [(x, "rw")])
        rt.barrier()
        # replicate across both devices via the D2D path
        rt._ensure_on_device(x, 0, will_write=False)
        rt._ensure_on_device(x, 1, will_write=False)
        # host write invalidates every device copy
        fut = x.request_host(write=True)
        arr = fut.get(5)
        arr[...] = 7.0
        x.release()
        assert x.valid_spaces() == {HOST}
        rt.run(lambda v: v * 2.0, [(x, "rw")])
        rt.barrier()
        np.testing.assert_allclose(x.get(), 14.0)


# ---------------------------------------------------------------------------
# argument prefetch pipeline + pool recycling
# ---------------------------------------------------------------------------

def test_prefetch_pipeline_counts_hits_and_recycles_futures():
    """Every staged argument copy is accounted either as a hit (transfer
    completed during the previous task's compute) or a stall (claimed
    early but still awaited) — the pipeline must have engaged for this
    workload of non-resident arguments."""
    with _two_device_rt(prefetch=True) as rt:
        objs = [rt.hetero_object(np.ones((64, 64), np.float32))
                for _ in range(30)]
        for o in objs:
            rt.run(lambda v: (v @ v.T).astype(v.dtype), [(o, "rw")])
        rt.barrier()
        s = rt.stats()
        assert s["prefetch_hits"] + s["prefetch_stalls"] > 0, s
        # consumed transfer futures must return to the request pool
        assert len(rt.futures._free) > 0
        for o in objs:
            np.testing.assert_allclose(o.get(), 64.0)


def test_prefetch_disabled_counts_nothing():
    with _two_device_rt(prefetch=False) as rt:
        x = rt.hetero_object(np.ones(8, np.float32))
        for _ in range(5):
            rt.run(lambda v: v + 1, [(x, "rw")])
        rt.barrier()
        s = rt.stats()
        assert s["prefetch_hits"] == 0
        assert s["prefetch_stalls"] == 0
        assert s["prefetch_misses"] == 0
        np.testing.assert_allclose(x.get(), 6.0)


def test_staging_pool_buffers_are_recycled():
    """Regression (seed leak): StagingPool.release was never called, so the
    pool missed forever. Dropping a pooled host copy must recycle it."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28)) as rt:
        for _ in range(4):
            c = rt.hetero_object(shape=(32, 32), dtype=np.float32)
            rt.run(lambda v: v + 1.0, [(c, "w")])
            rt.barrier()
            np.testing.assert_allclose(c.get(), 1.0)
        assert rt.stats()["staging_hits"] > 0, rt.stats()


def test_chunked_host_upload_through_staging_pool():
    """Uploads above staging_chunk_bytes stream through pooled buffers and
    still produce a bit-exact device copy."""
    with Runtime(RuntimeConfig(memory_capacity=1 << 28,
                               staging_chunk_bytes=1 << 12)) as rt:
        data = np.random.default_rng(0).random((64, 64)).astype(np.float32)
        x = rt.hetero_object(data.copy())
        rt.run(lambda v: v * 1.0, [(x, "rw")])
        rt.barrier()
        np.testing.assert_allclose(x.get(), data, rtol=1e-6)
        assert rt.staging.hits + rt.staging.misses > 1   # chunked acquires


# ---------------------------------------------------------------------------
# indexed scheduler ready queues
# ---------------------------------------------------------------------------

def _task(device_type=None):
    t = HeteroTask()
    t.device(device_type)
    t.state = TaskState.READY
    return t


def test_fifo_overflow_is_shared_and_ordered():
    s = FifoScheduler({0: "cpu", 1: "cpu"})
    tasks = [_task() for _ in range(4)]
    for t in tasks:
        s.push(t)
    assert len(s) == 4
    got, dev = s.pop(1)
    assert got is tasks[0] and dev == 1     # O(1) head pop, any device
    got, dev = s.pop(0)
    assert got is tasks[1] and dev == 0


def test_least_loaded_places_per_device_at_push():
    s = LeastLoadedScheduler({0: "cpu", 1: "cpu"})
    tasks = [_task() for _ in range(4)]
    for t in tasks:
        s.push(t)
    # 4 untyped tasks spread 2/2 over the indexed queues
    assert s.queued[0] == 2 and s.queued[1] == 2
    got, dev = s.pop(0)
    assert dev == 0 and s.queued[0] == 1


def test_idle_device_steals_oldest():
    s = LeastLoadedScheduler({0: "cpu", 1: "cpu"})
    s.load[1] = 10                  # device 1 looks busy → all go to 0
    t1, t2 = _task(), _task()
    s.push(t1)
    s.push(t2)
    assert s.queued[0] == 2
    got, dev = s.pop(1)             # idle device 1 steals the oldest
    assert got is t1 and dev == 1
    assert s.queued[0] == 1


def test_locality_scheduler_does_not_steal():
    s = LocalityAwareScheduler({0: "cpu", 1: "cpu"})
    t = _task()
    s.push(t)
    placed = next(d for d in (0, 1) if s.queued[d] == 1)
    other = 1 - placed
    assert s.pop(other) is None     # no stealing: locality is preserved
    got, dev = s.pop(placed)
    assert got is t and dev == placed


def test_device_type_restricted_task_waits_in_overflow():
    s = LeastLoadedScheduler({0: "cpu", 1: "cpu"})
    t = _task(device_type="tpu")    # no eligible device present
    s.push(t)
    assert s.pop(0) is None and s.pop(1) is None and s.pop() is None
    assert len(s) == 1


def test_peek_and_assign_hooks():
    s = FifoScheduler({0: "cpu"})
    t1, t2 = _task(), _task()
    s.push(t1)
    s.push(t2)
    assert s.peek(0) is t1          # peek does not remove
    assert len(s) == 2
    got, dev = s.assign(0)          # assign removes, like pop
    assert got is t1 and dev == 0
    assert s.peek(0) is t2


def test_indexed_pop_scales_flat():
    """Smoke for the O(1) claim: draining 20k tasks through hinted pops
    must not show the seed's O(n²) full-queue rescans (which took minutes
    at this size)."""
    import time
    s = LeastLoadedScheduler({0: "cpu", 1: "cpu"})
    for _ in range(20000):
        s.push(_task())
    t0 = time.perf_counter()
    n = 0
    while s.pop(n % 2) is not None:
        n += 1
    assert n == 20000
    assert time.perf_counter() - t0 < 5.0


# ---------------------------------------------------------------------------
# honest device capacity + jit cache keying
# ---------------------------------------------------------------------------

def test_discover_devices_reports_positive_capacity():
    devs = discover_devices()
    assert devs and all(d.info.memory_capacity > 0 for d in devs)
    with open("/proc/meminfo") as f:
        total = int(f.readline().split()[1]) * 1024
    assert all(d.info.memory_capacity <= total for d in devs)
    # explicit override still wins
    devs = discover_devices(memory_capacity=12345)
    assert all(d.info.memory_capacity == 12345 for d in devs)


def test_jit_cache_keys_on_kernel_object():
    dev = discover_devices(memory_capacity=1 << 28)[0]

    def k1(x):
        return x + 1

    def k2(x):
        return x + 2

    f1 = dev._get_jit(k1, ())
    f2 = dev._get_jit(k2, ())
    assert f1 is not f2
    assert dev._get_jit(k1, ()) is f1          # cache hit on same object
    # the cache holds a strong ref: the key can never be a recycled id()
    assert any(k is k1 for k, _ in dev._jit_cache)
