"""End-to-end data integrity: fold64 digests at every boundary, seeded
wire/disk corruption injection, digest-validated checkpoint restores with
older-step fallback, lineage-based recompute of lost objects, and
injected kernel faults absorbed by task retry."""
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import Checkpointer, CheckpointIntegrityError
from repro.core import (InjectedTaskFault, Runtime, RuntimeConfig,
                        digest_array, verify_array)
from repro.distributed import Cluster, FaultInjector, handler

_got = {}
_lock = threading.Lock()


@handler(name="it_recv")
def _it_recv(ctx, obj):
    with _lock:
        _got.setdefault(ctx.message.user["tag"], []).append(
            None if obj is None else np.asarray(obj.get()))


def _wait(pred, timeout=30.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        with _lock:
            if pred():
                return True
        time.sleep(0.005)
    return False


@pytest.fixture(autouse=True)
def _clear_got():
    with _lock:
        _got.clear()
    yield


def _cfg(**kw):
    return RuntimeConfig(memory_capacity=1 << 26, **kw)


def _leak_gauges(rank):
    """Protocol-state leak gauges only: the cumulative checksum counters
    are EXPECTED nonzero after a corruption test."""
    return {k: v for k, v in rank.state_gauges().items()
            if k not in ("checksum_fail", "chunks_rejected")}


# ---------------------------------------------------------------------------
# fold64 digest
# ---------------------------------------------------------------------------

def test_digest_detects_every_single_bitflip_position():
    rng = np.random.default_rng(0)
    arr = rng.random(257).astype(np.float64)    # odd tail: exercises padding
    d0 = digest_array(arr)
    assert d0 == digest_array(arr.copy())       # content, not identity
    raw = arr.view(np.uint8).copy()
    for bit in (0, 7, 777, raw.size * 8 - 1):   # first, last, interior
        flipped = raw.copy()
        flipped[bit >> 3] ^= 1 << (bit & 7)
        assert digest_array(flipped.view(np.float64)) != d0, bit
    assert verify_array(arr, d0)                # clean passes
    bad = raw.copy()
    bad[0] ^= 1
    assert not verify_array(bad.view(np.float64), d0)


def test_digest_is_dtype_and_shape_stable():
    a = np.arange(64, dtype=np.float32).reshape(8, 8)
    assert digest_array(a) == digest_array(np.ascontiguousarray(a))
    assert digest_array(a) == digest_array(a.reshape(64))   # same bytes
    assert digest_array(a) != digest_array(a.astype(np.float64))


# ---------------------------------------------------------------------------
# wire corruption: checksums + retransmit converge bit-identically
# ---------------------------------------------------------------------------

def test_eager_bitflips_converge_bit_identical():
    """Seeded bit-flips on eager payloads: every flipped message is
    rejected by the receiver's digest check, the ack-timeout retransmit
    re-sends clean bytes (corruption copies, never mutates, the retained
    Message), and every payload lands bit-perfect."""
    cfg = _cfg(retry_backoff_s=0.02, retry_tick_s=0.002)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=11)
        fi.set_link(0, 1, corrupt=0.4)
        rng = np.random.default_rng(3)
        sent = []
        for i in range(6):
            arr = rng.random(256).astype(np.float32)     # 1 KiB → eager
            sent.append(arr)
            obj = c.ranks[0].runtime.hetero_object(arr)
            c.ranks[0].send(1, "it_recv", obj, user={"tag": f"e{i}"})
        assert _wait(lambda: all(_got.get(f"e{i}") for i in range(6)))
        for i, arr in enumerate(sent):
            np.testing.assert_array_equal(_got[f"e{i}"][0], arr)
        assert fi.stats["corrupted"] >= 1
        assert c.ranks[1].stats["checksum_fail"] >= 1
        assert c.ranks[0].stats["retries"] >= 1
        assert c.ranks[0].stats["send_failures"] == 0
        fi.clear_link(0, 1)
        c.barrier(timeout=60)
        for r in c.ranks:
            g = _leak_gauges(r)
            assert all(v == 0 for v in g.values()), (r.rank, g)


def test_rendezvous_chunk_bitflips_converge_bit_identical():
    """A flipped chunk of a rendezvous stream is treated exactly like a
    never-arrived chunk: rejected on digest (chunks_rejected), repaired
    by NACK/tail-resend, and the reassembled payload is bit-perfect."""
    cfg = _cfg(chunk_bytes=32 << 10, retry_backoff_s=0.02,
               retry_tick_s=0.002)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=13)
        fi.set_link(0, 1, corrupt=0.25)   # data direction only; acks clean
        big = np.random.default_rng(5).random((128, 1024)).astype(
            np.float32)                   # 512 KiB → 16 chunks
        obj = c.ranks[0].runtime.hetero_object(big)
        c.ranks[0].send(1, "it_recv", obj, user={"tag": "rdzv"})
        assert _wait(lambda: _got.get("rdzv"))
        np.testing.assert_array_equal(_got["rdzv"][0], big)
        assert fi.stats["corrupted"] >= 1
        assert c.ranks[1].stats["chunks_rejected"] >= 1
        fi.clear_link(0, 1)
        c.barrier(timeout=60)
        for r in c.ranks:
            g = _leak_gauges(r)
            assert all(v == 0 for v in g.values()), (r.rank, g)


def test_corruption_injection_deterministic_under_seed():
    """Same seed + same message order → identical flip decisions (and a
    different seed diverges) — the property every seeded-corruption test
    above depends on."""
    from repro.distributed.messaging import Message

    def run(seed):
        fi = FaultInjector(None, seed=seed)
        fi.set_link(0, 1, corrupt=0.5)
        out = []
        for i in range(64):
            msg = Message(msg_id=i, kind="data", src=0, dst=1,
                          inline=bytes(range(32)))
            out.append(fi.maybe_corrupt(msg).inline)
        return out

    assert run(7) == run(7)
    assert run(7) != run(8)


# ---------------------------------------------------------------------------
# checkpoint integrity: detect, fall back, surface async failures
# ---------------------------------------------------------------------------

def test_corrupted_leaf_detected_and_falls_back_to_older_step(tmp_path):
    ckpt = Checkpointer(str(tmp_path), keep=3, async_save=False)
    rng = np.random.default_rng(9)
    arrs = {s: rng.random((32, 8)).astype(np.float32) for s in (1, 2)}
    for s, arr in arrs.items():
        ckpt.save(s, {"w": arr})
    fi = FaultInjector(None, seed=0)
    fi.corrupt_checkpoint_leaf(str(tmp_path), 2, "w")
    assert fi.stats["ckpt_corrupted"] == 1
    with pytest.raises(CheckpointIntegrityError, match="digest"):
        ckpt.restore_leaf(2, "w")
    assert ckpt.stats["ckpt_verify_fail"] == 1
    # fallback walks to the newest step whose leaf still verifies
    step, arr = ckpt.restore_leaf_fallback("w")
    assert step == 1
    np.testing.assert_array_equal(arr, arrs[1])
    # with every copy corrupted, the failure is explicit — never garbage
    fi.corrupt_checkpoint_leaf(str(tmp_path), 1, "w")
    with pytest.raises(CheckpointIntegrityError, match="no committed step"):
        ckpt.restore_leaf_fallback("w")


def test_restore_validates_manifest_shape_and_dtype(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=False)
    ckpt.save(0, {"w": np.ones((4, 4), np.float32)})
    # overwrite the leaf with a well-formed npy of the WRONG shape: the
    # digest never runs — shape/dtype validation rejects it first
    np.save(os.path.join(str(tmp_path), "step_0", "w.npy"),
            np.ones((2, 2), np.float32))
    with pytest.raises(CheckpointIntegrityError, match="shape"):
        ckpt.restore_leaf(0, "w")
    assert ckpt.stats["ckpt_verify_fail"] == 1


def test_async_save_failure_recorded_and_reraised(tmp_path):
    ckpt = Checkpointer(str(tmp_path), async_save=True)
    ckpt.save(0, {"w": np.ones(8, np.float32)})
    ckpt.wait()
    # break the write destination out from under the async writer: a
    # regular FILE where the directory should be makes makedirs raise
    ckpt.dir = str(tmp_path / "blocked")
    with open(ckpt.dir, "w") as f:
        f.write("not a directory")
    ckpt.save(1, {"w": np.ones(8, np.float32)})      # async: no raise yet
    with pytest.raises(RuntimeError, match="async checkpoint save failed"):
        ckpt.save(2, {"w": np.ones(8, np.float32)})  # surfaced HERE
    assert ckpt.stats["save_errors"] == 1
    assert ckpt._error is None                       # raised once, cleared


# ---------------------------------------------------------------------------
# lineage: replay the producer chain when every replica is gone
# ---------------------------------------------------------------------------

def _add_one(x, out):
    return x + 1.0


def _scale(x, out):
    return x * 2.0


@pytest.fixture()
def rt():
    r = Runtime(RuntimeConfig(memory_capacity=1 << 28))
    yield r
    r.shutdown()


def test_lineage_recompute_bit_identical(rt):
    x = rt.hetero_object(np.arange(64, dtype=np.float32))
    y = rt.hetero_object(shape=(64,), dtype=np.float32)
    rt.run(_add_one, [(x, "r"), (y, "w")])
    rt.barrier()
    expect = np.asarray(y.get()).copy()
    rt._free_object(y)                  # evicted-and-lost: no copy anywhere
    got = np.asarray(y.get())           # coherence replays the producer
    np.testing.assert_array_equal(got, expect)
    st = rt.stats()
    assert st["lineage_recomputes"] == 1
    assert st["recompute_depth_peak"] == 1


def test_lineage_recompute_chains_to_depth(rt):
    x = rt.hetero_object(np.arange(16, dtype=np.float32))
    y = rt.hetero_object(shape=(16,), dtype=np.float32)
    z = rt.hetero_object(shape=(16,), dtype=np.float32)
    rt.run(_add_one, [(x, "r"), (y, "w")])
    rt.run(_scale, [(y, "r"), (z, "w")])
    rt.barrier()
    expect = np.asarray(z.get()).copy()
    rt._free_object(y)                  # BOTH links of the chain lost
    rt._free_object(z)
    got = np.asarray(z.get())           # z needs y needs x: depth 2
    np.testing.assert_array_equal(got, expect)
    st = rt.stats()
    assert st["lineage_recomputes"] == 2    # y replayed, then z
    assert st["recompute_depth_peak"] == 2


def test_lineage_refuses_stale_generation(rt):
    """A producer record is valid for exactly one generation of its
    inputs: overwrite the input and the chain must refuse to replay
    (silent wrong-answer recompute is worse than an explicit zero)."""
    x = rt.hetero_object(np.ones(16, np.float32))
    y = rt.hetero_object(shape=(16,), dtype=np.float32)
    rt.run(_add_one, [(x, "r"), (y, "w")])
    rt.barrier()
    rt.run(lambda v: v * 2.0, [(x, "rw")])   # bump x's generation
    rt.barrier()
    rt._free_object(y)
    assert rt._lineage_recover(y) is False
    assert rt.stats()["lineage_recomputes"] == 0


# ---------------------------------------------------------------------------
# injected kernel faults: absorbed by retry, surfaced when exhausted
# ---------------------------------------------------------------------------

def test_task_fault_absorbed_by_retry_budget():
    cfg = _cfg(task_retries=2, strict_errors=True)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=0)
        fi.fail_task(1, times=2)
        rt = c.ranks[1].runtime
        x = rt.hetero_object(np.zeros(32, np.float32))
        y = rt.hetero_object(shape=(32,), dtype=np.float32)
        rt.run(_add_one, [(x, "r"), (y, "w")])
        rt.barrier()                     # both faults absorbed: no raise
        np.testing.assert_array_equal(np.asarray(y.get()),
                                      np.ones(32, np.float32))
        st = rt.stats()
        assert st["task_retries"] == 2
        assert st["tasks_failed"] == 0
        assert fi.stats["task_faults"] == 2


def test_task_fault_exhausts_retries_and_surfaces_strict():
    cfg = _cfg(task_retries=1, strict_errors=True)
    with Cluster(2, cfg) as c:
        fi = c.fault_injector(seed=0)
        fi.fail_task(0, times=2)         # one more fault than the budget
        rt = c.ranks[0].runtime
        x = rt.hetero_object(np.zeros(32, np.float32))
        y = rt.hetero_object(shape=(32,), dtype=np.float32)
        rt.run(_add_one, [(x, "r"), (y, "w")])
        with pytest.raises(RuntimeError) as ei:
            rt.barrier()
        assert isinstance(ei.value.__cause__, InjectedTaskFault)
        assert "injected kernel fault" in repr(ei.value.__cause__)
        st = rt.stats()
        assert st["task_retries"] == 1 and st["tasks_failed"] == 1


# ---------------------------------------------------------------------------
# the whole stack at once: jacobi under seeded wire corruption
# ---------------------------------------------------------------------------

def test_jacobi_wire_corruption_bit_identical():
    """The INTEG-Recover corrupt arm in miniature (tier-1 sized): every
    directed link flips host-staged payloads, replication streams run
    every iteration — and the answer is bit-identical to the clean run
    because every flipped payload was rejected and retransmitted."""
    from repro.apps.jacobi3d import run_cluster_elastic
    rng = np.random.default_rng(21)
    u0 = rng.standard_normal((24, 16, 16)).astype(np.float32)
    iters = 3
    # eager_threshold shrunk so the 8 KiB slabs host-stage as rendezvous
    # streams (the corruptible wire path) instead of riding the DIRECT
    # device-view fast path, which never exposes host bytes to the link
    kw = dict(retry_backoff_s=0.02, retry_tick_s=0.002,
              eager_threshold=2 << 10, chunk_bytes=4 << 10)
    with Cluster(3, _cfg(**kw)) as c:
        clean, _ = run_cluster_elastic(u0, iters, c, replicate=True)
    with Cluster(3, _cfg(**kw)) as c:
        c.fault_injector(seed=17)
        out, rep = run_cluster_elastic(u0, iters, c, replicate=True,
                                       corrupt_links=0.15)
    assert np.array_equal(out, clean)
    ig = rep["integrity"]
    assert ig["checksum_fail"] + ig["chunks_rejected"] >= 1
    assert ig["retries"] >= 1
    assert rep["faults"]["corrupted"] >= 1


# ---------------------------------------------------------------------------
# checked-in benchmark rung stays well-formed
# ---------------------------------------------------------------------------

def test_integ_recover_rung_json_wellformed():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "benchmarks", "results", "dryrun",
                        "rt_ladder__INTEG-Recover__dev2.json")
    if not os.path.exists(path):
        pytest.skip("INTEG-Recover rung JSON not generated")
    with open(path) as f:
        row = json.load(f)
    assert "error" not in row, row
    need = {"n", "iters", "ranks", "corrupt_p", "ctrl_billed", "clean",
            "oracle_ok", "corrupt", "ckpt_fallback", "verify_overhead"}
    assert not (need - set(row)), row
    assert all(v == 0 for v in row["clean"]["integrity"].values()), row
    co = row["corrupt"]
    assert co["bitwise_identical"] is True, co
    assert co["integrity"]["checksum_fail"] >= 1, co
    assert co["integrity"]["retries"] >= 1, co
    assert co["recoveries"] >= 1, co
    assert co["faults"]["corrupted"] >= 1, co
    assert co["faults"]["ckpt_corrupted"] == 1, co
    cf = row["ckpt_fallback"]
    assert cf["corruption_detected"] is True and cf["completed"] is True, cf
    for r in row["verify_overhead"]:
        assert r["verify_us"] > 0 and r["noverify_us"] > 0, r
