"""Property tests of the model substrate's mathematical invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import attention as A  # noqa: E402
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import rglru as R
from repro.configs.base import RGLRUConfig, SSMConfig

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, mask):
    """q: [B,S,K,G,D]; k,v: [B,T,K,D]; mask: [S,T] bool."""
    sc = jnp.einsum("bskgd,btkd->bskgt", q, k) * (q.shape[-1] ** -0.5)
    sc = jnp.where(mask[None, :, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bskgt,btkd->bskgd", p, v)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([32, 64, 96]), w=st.sampled_from([8, 16, 32]))
def test_window_attention_equals_masked_full(s, w):
    b, kh, g, d = 2, 2, 2, 8
    ks = jax.random.split(jax.random.fold_in(KEY, s * 100 + w), 3)
    q = jax.random.normal(ks[0], (b, s, kh, g, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    pos = jnp.arange(s)
    got = A.window_attention(q, k, v, positions=pos, window=w)
    mask = (pos[None, :] <= pos[:, None]) & (pos[:, None] - pos[None, :] < w)
    want = _naive_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(s=st.sampled_from([32, 64]), t=st.sampled_from([32, 64, 128]),
       causal=st.booleans())
def test_flash_equals_naive(s, t, causal):
    if causal:
        t = s
    b, kh, g, d = 2, 2, 1, 8
    ks = jax.random.split(jax.random.fold_in(KEY, s * 1000 + t), 3)
    q = jax.random.normal(ks[0], (b, s, kh, g, d))
    k = jax.random.normal(ks[1], (b, t, kh, d))
    v = jax.random.normal(ks[2], (b, t, kh, d))
    got = A.flash_attention(q, k, v, q_positions=jnp.arange(s),
                            kv_positions=jnp.arange(t), causal=causal,
                            q_block=16, kv_block=16)
    mask = (jnp.arange(t)[None, :] <= jnp.arange(s)[:, None]) if causal \
        else jnp.ones((s, t), bool)
    want = _naive_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_attention_permutation_equivariance_over_batch():
    """Permuting the batch permutes the output (no cross-request leakage)."""
    b, s, kh, g, d = 4, 16, 2, 2, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, kh, g, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    out = A.flash_attention(q, k, v, q_positions=jnp.arange(s),
                            kv_positions=jnp.arange(s), causal=True)
    perm = jnp.array([2, 0, 3, 1])
    out_p = A.flash_attention(q[perm], k[perm], v[perm],
                              q_positions=jnp.arange(s),
                              kv_positions=jnp.arange(s), causal=True)
    np.testing.assert_allclose(np.asarray(out[perm]), np.asarray(out_p),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# SSD vs naive recurrence
# ---------------------------------------------------------------------------

def _naive_ssd(x, dt, Av, B, C):
    """Sequential state recurrence oracle. x: [b,s,h,p]; B,C: [b,s,1,n]."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    st = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dA = jnp.exp(dt[:, t] * Av[None, :])                     # [b,h]
        st = st * dA[:, :, None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", x[:, t], B[:, t, 0], dt[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", st, C[:, t, 0]))
    return jnp.stack(ys, axis=1), st


@pytest.mark.parametrize("s,chunk", [(16, 4), (24, 8), (32, 32)])
def test_ssd_chunked_equals_naive_recurrence(s, chunk):
    b, h, p, n = 2, 2, 4, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    Av = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, 1, n))
    C = jax.random.normal(ks[4], (b, s, 1, n))
    got_y, got_st = S._ssd_chunked(x, dt, Av, B, C, chunk)
    want_y, want_st = _naive_ssd(x, dt, Av, B, C)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_st), np.asarray(want_st),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU: associative scan vs sequential loop
# ---------------------------------------------------------------------------

def test_rglru_scan_equals_sequential():
    d, w, s, b = 8, 8, 24, 2
    cfg = RGLRUConfig(lru_width=w, conv_width=4)
    params = jax.tree.map(lambda bx: bx.value,
                          R.rglru_init(KEY, d, cfg, n_blocks=2,
                                       dtype=jnp.float32),
                          is_leaf=lambda x: isinstance(x, L.Boxed))
    u = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, d))
    full, _ = R.rglru_layer(params, u, rcfg=cfg, mode="train")
    # sequential: feed one token at a time through decode
    cache = {"conv": jnp.zeros((b, 3, w)), "state": jnp.zeros((b, w))}
    outs = []
    for t in range(s):
        y, cache = R.rglru_layer(params, u[:, t:t + 1], rcfg=cfg,
                                 mode="decode", cache=cache)
        outs.append(y)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(seq),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# losses / numerics
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6))
def test_chunked_ce_equals_direct(nchunks):
    from repro.configs import get_smoke_config
    from repro.models.transformer import Flags, chunked_ce_loss
    import dataclasses
    cfg = get_smoke_config("yi_9b")
    b, s, dm = 2, 16 * nchunks, cfg.d_model
    ks = jax.random.split(jax.random.fold_in(KEY, nchunks), 3)
    x = jax.random.normal(ks[0], (b, s, dm))
    w = jax.random.normal(ks[1], (dm, cfg.vocab)) * 0.05
    labels = jax.random.randint(ks[2], (b, s), 0, cfg.vocab)
    params = {"unembed": w}
    flags = Flags(loss_chunk=16, param_dtype=jnp.float32)
    got = chunked_ce_loss(params, x, labels, cfg, flags)
    logits = x @ w
    want = L.softmax_cross_entropy(logits, labels)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 512), st.floats(1e3, 1e6))
def test_rope_preserves_norm(pos, theta):
    x = jax.random.normal(KEY, (1, 1, 2, 16))
    y = L.apply_rope(x, jnp.array([[pos]]), theta)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


def test_rms_norm_scale_equivariance():
    x = jax.random.normal(KEY, (2, 8, 16))
    g = jnp.ones((16,))
    a = L.rms_norm(x, g)
    b = L.rms_norm(x * 42.0, g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# MoE invariants
# ---------------------------------------------------------------------------

def test_moe_dense_routing_invariants():
    from repro.configs import MoEConfig
    from repro.models import moe as M
    mcfg = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16)
    p = jax.tree.map(lambda b: b.value,
                     M.moe_init(KEY, 8, mcfg, True, dtype=jnp.float32),
                     is_leaf=lambda x: isinstance(x, L.Boxed))
    x = jax.random.normal(KEY, (2, 8, 8))
    out, aux = M.moe_dense(p, x, mcfg, True)
    assert out.shape == x.shape
    assert float(aux) >= 0
    w, idx, _ = M._route(p["router"], x.reshape(-1, 8), mcfg)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < mcfg.num_experts


def test_moe_tiny_capacity_drops_gracefully():
    """With capacity_factor→tiny the EP path must drop tokens (finite,
    smaller-magnitude output), never crash. Run inside shard_map on a
    1×1 mesh so _ep_local sees a real axis."""
    from jax.sharding import PartitionSpec as PS
    from repro.configs import MoEConfig
    from repro.models import moe as M
    mcfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=8)
    p = jax.tree.map(lambda b: b.value,
                     M.moe_init(KEY, 8, mcfg, True, dtype=jnp.float32),
                     is_leaf=lambda x: isinstance(x, L.Boxed))
    xf = jax.random.normal(KEY, (16, 8))
    mesh = jax.make_mesh((1,), ("model",))

    def run(cf):
        body = lambda xloc: M._ep_local(p, xloc, mcfg, True, "model", cf)[0]
        return jax.shard_map(body, mesh=mesh, in_specs=PS(),
                             out_specs=PS(), check_vma=False)(xf)

    full = run(8.0)
    tiny = run(0.05)
    assert np.isfinite(np.asarray(tiny)).all()
    assert float(jnp.abs(tiny).sum()) < float(jnp.abs(full).sum())


def test_pallas_flash_flag_matches_scan_path():
    """use_pallas_flash routes global attention through the Pallas kernel —
    same logits as the scan-based path."""
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import build_smoke
    from repro.models.layers import unbox
    cfg = get_smoke_config("yi_9b")
    m0 = build_smoke(cfg)
    m1 = build_smoke(cfg, use_pallas_flash=True)
    params, _ = unbox(m0.init(KEY))
    batch = {"tokens": jax.random.randint(KEY, (2, 128), 0, cfg.vocab)}
    x0, _, _ = m0.apply(params, dict(batch), mode="train")
    x1, _, _ = m1.apply(params, dict(batch), mode="train")
    np.testing.assert_allclose(np.asarray(x0), np.asarray(x1),
                               rtol=2e-4, atol=2e-4)
