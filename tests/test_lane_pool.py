"""Shared lane worker pool: per-lane serial ordering, lifecycle parity
with the legacy thread-per-lane mode, and thread-count scaling."""
import threading
import time

import numpy as np
import pytest

from repro.core import Runtime, RuntimeConfig
from repro.core.futures import HFuture
from repro.core.progress import ProgressEngine


def test_single_lane_never_interleaves():
    """Two (or fifty) submits to one lane must never overlap — the run
    token gives exactly one worker the lane at a time, and FIFO order
    within a priority level is preserved."""
    eng = ProgressEngine(name="t", pool_workers=4)
    try:
        lane = eng.lane("transfer", 0)
        lock = threading.Lock()
        active = 0
        max_active = 0
        order = []

        def job(i):
            def run():
                nonlocal active, max_active
                with lock:
                    active += 1
                    max_active = max(max_active, active)
                time.sleep(0.001)
                with lock:
                    order.append(i)
                    active -= 1
            return run

        futs = [lane.submit(job(i), HFuture()) for i in range(50)]
        for f in futs:
            f.get(timeout=30)
        assert max_active == 1
        assert order == list(range(50))
    finally:
        eng.shutdown()


def test_parallel_lanes_make_progress_past_blockers():
    """A lane blocked inside a long job must not starve sibling lanes:
    overflow workers keep the pool making progress."""
    eng = ProgressEngine(name="t", pool_workers=2)
    try:
        release = threading.Event()
        blocked = [eng.lane("link", i) for i in range(2)]
        for ln in blocked:
            ln.submit(release.wait, HFuture())
        free = eng.lane("transfer", 9)
        fut = free.submit(lambda: "ran", HFuture())
        assert fut.get(timeout=10) == "ran"   # despite 2/2 base blocked
        release.set()
    finally:
        eng.shutdown()


def test_thread_count_does_not_scale_with_lane_count():
    """Creating lanes spawns no threads; servicing them uses the shared
    pool, not one thread per lane."""
    eng = ProgressEngine(name="t", pool_workers=4)
    try:
        lanes = [eng.lane("transfer", i) for i in range(64)]
        assert eng.worker_threads() == 0      # idle lanes cost nothing
        for ln in lanes:                       # serial submit + wait
            ln.submit(lambda: None, HFuture()).get(timeout=10)
        # workers are pooled: far fewer than one per lane (transient
        # overflow may briefly exceed the base width of 4)
        assert eng.worker_threads() <= 8
    finally:
        eng.shutdown()


@pytest.mark.parametrize("workers", [0, 4])
def test_submit_after_stop_raises(workers):
    """submit-after-stop raises RuntimeError identically in pooled and
    legacy thread-per-lane modes, and resolves the job future with the
    error so no caller hangs."""
    eng = ProgressEngine(name="t", pool_workers=workers)
    try:
        lane = eng.lane("net-send", 1)
        assert lane.submit(lambda: 7, HFuture()).get(timeout=10) == 7
        lane.stop()
        fut = HFuture()
        with pytest.raises(RuntimeError):
            lane.submit(lambda: None, fut)
        with pytest.raises(RuntimeError):
            fut.get(timeout=10)
    finally:
        eng.shutdown()


def test_stop_during_inflight_job_drains_cleanly():
    """stop() while a job is executing: the accepted job finishes (the
    sentinel sorts behind every queued job), stop returns, and later
    submits raise."""
    eng = ProgressEngine(name="t", pool_workers=4)
    try:
        lane = eng.lane("transfer", 0)
        started = threading.Event()
        release = threading.Event()
        done = []

        def slow():
            started.set()
            release.wait(timeout=10)
            done.append(True)

        lane.submit(slow, HFuture())
        tail = lane.submit(lambda: done.append("tail"), HFuture())
        assert started.wait(timeout=10)
        stopper = threading.Thread(target=lane.stop)
        stopper.start()
        release.set()
        stopper.join(timeout=10)
        assert not stopper.is_alive()
        tail.get(timeout=10)                  # queued-before-stop job ran
        assert done == [True, "tail"]
        with pytest.raises(RuntimeError):
            lane.submit(lambda: None)
    finally:
        eng.shutdown()


def test_shutdown_with_parked_replay_window_returns_promptly():
    """Runtime.shutdown during a traced window (tasks parked for replay,
    boundary never reached) must not deadlock the pooled engine."""
    rt = Runtime(RuntimeConfig(memory_capacity=1 << 26, trace_graphs=True,
                               replay_after=2))
    a = rt.hetero_object(np.zeros((8,), np.float32))

    def bump(v):
        return v + 1.0

    for _ in range(3):
        rt.run(bump, [(a, "rw")])
        rt.step_boundary()
    rt.barrier()
    assert rt.stats()["graph_replays"] == 1
    rt.run(bump, [(a, "rw")])      # parked; no boundary follows
    t0 = time.time()
    rt.shutdown()
    assert time.time() - t0 < 30.0
